"""Scheduler edge cases: deterministic ordering (sjf/fcfs tie-breaks,
priority, backpressured head-of-line), PagePoolExhausted requeue ordering
without starvation, and the deadline/priority preemption state machine
(a preempted request retires with the same tokens as an uninterrupted
run-to-completion decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.config import DecodeConfig
from repro.core import decode as D
from repro.models import cache as cache_lib
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    EngineConfig,
    Request,
    Scheduler,
)

pytestmark = pytest.mark.serving


# ---------------------------------------------------------------------------
# Pure queue-ordering tests: no device work, just the admission order.
# ---------------------------------------------------------------------------


class _OneGroupEngine:
    """Just enough engine surface for queue-ordering tests: one slot group
    named "exact" and a static config for submit()'s bounds checks."""

    class _G:
        name = "exact"

    ecfg = EngineConfig(num_slots=2, max_prompt_len=32, max_new_cap=16)

    def group_for(self, policy):
        return self._G


def _mk(rid, max_new, arrival, **kw):
    return Request(rid=rid, prompt=np.arange(1, 4), max_new=max_new,
                   arrival=arrival, **kw)


def _drain_order(sched, now=100.0):
    order = []
    while True:
        r = sched._pop_next(now, group="exact")
        if r is None:
            return order
        order.append(r.rid)


def test_sjf_tie_break_deterministic():
    """sjf orders by (max_new, arrival, rid) — equal-length jobs pop in
    arrival order, simultaneous arrivals pop in rid order, and the result
    is independent of submission order."""
    reqs = [_mk(3, 8, 0.0), _mk(1, 8, 0.0), _mk(2, 8, 1.0),
            _mk(0, 4, 2.0), _mk(4, 12, 0.0), _mk(5, 4, 2.0)]
    expected = [0, 5, 1, 3, 2, 4]
    rng = np.random.default_rng(0)
    for _ in range(4):                      # shuffle-invariant
        sched = Scheduler(_OneGroupEngine(), policy="sjf")
        for i in rng.permutation(len(reqs)):
            sched.submit(reqs[int(i)])
        assert _drain_order(sched) == expected


def test_fcfs_order():
    """fcfs orders by (arrival, rid): rid breaks simultaneous arrivals."""
    reqs = [_mk(3, 8, 0.0), _mk(1, 8, 0.0), _mk(2, 8, 1.0),
            _mk(0, 4, 2.0), _mk(4, 12, 0.0), _mk(5, 4, 2.0)]
    sched = Scheduler(_OneGroupEngine(), policy="fcfs")
    for r in reqs:
        sched.submit(r)
    assert _drain_order(sched) == [1, 3, 4, 2, 0, 5]


def test_priority_then_backpressure_beat_sjf_size():
    """Priority dominates everything; within a priority level the
    backpressured flag grants head-of-line ownership even to the LONGEST
    job under sjf (the anti-starvation guarantee)."""
    a = _mk(0, 4, 0.0)                      # shortest, earliest
    b = _mk(1, 16, 5.0)                     # longest, latest, backpressured
    b.backpressured = 1
    c = _mk(2, 2, 6.0, priority=1)          # higher priority, latest still
    sched = Scheduler(_OneGroupEngine(), policy="sjf")
    for r in (a, b, c):
        sched.submit(r)
    assert _drain_order(sched) == [2, 1, 0]


def test_future_arrivals_invisible():
    sched = Scheduler(_OneGroupEngine(), policy="fcfs")
    sched.submit(_mk(0, 4, 10.0))
    sched.submit(_mk(1, 4, 0.0))
    assert sched._pop_next(5.0, group="exact").rid == 1
    assert sched._pop_next(5.0, group="exact") is None   # rid 0 not arrived
    assert sched._pop_next(10.0, group="exact").rid == 0


def test_submit_rejects_bad_requests():
    sched = Scheduler(_OneGroupEngine())
    with pytest.raises(ValueError, match="outside"):
        sched.submit(Request(rid=0, prompt=np.zeros((0,), np.int32),
                             max_new=4))
    with pytest.raises(ValueError, match="outside"):
        sched.submit(Request(rid=1, prompt=np.arange(33), max_new=4))
    with pytest.raises(ValueError, match="not in"):
        Scheduler(_OneGroupEngine(), policy="priority")


# ---------------------------------------------------------------------------
# Engine-backed tests: preemption token identity + paged backpressure.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    # eos -1: every request runs its full budget, so slot occupancy during
    # the preemption window is deterministic
    dec = DecodeConfig(max_new_tokens=16, block_k=4)
    return params, cfg, dec


@pytest.fixture(scope="module")
def dense_engine(model):
    params, cfg, dec = model
    # max_prompt_len large enough that any continuation prompt
    # (prompt + committed tokens <= 6 + 16) stays admissible
    return ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=2, max_prompt_len=24,
                                       max_new_cap=16))


def _reference(params, cfg, dec, prompt, max_new):
    d1 = dec.replace(max_new_tokens=max_new)
    bt, bs = D.bpd_decode(params, cfg, d1,
                          {"tokens": jnp.asarray(prompt)[None]})
    n = int(bs["text_len"][0])
    return np.asarray(bt[0, len(prompt):n])


def _drive(sched, start, step_s=1.0, max_steps=200):
    now, fin = start, []
    while not sched.drained():
        assert now < start + max_steps * step_s, "scheduler did not drain"
        fin += sched.step(now=now)
        now += step_s
    return fin


def test_preemption_token_identity(model, dense_engine):
    """An urgent past-deadline request evicts a lower-priority victim; the
    victim re-admits as a continuation and still retires with EXACTLY the
    tokens of an uninterrupted bpd_decode run."""
    params, cfg, dec = model
    sched = Scheduler(dense_engine)
    rng = np.random.default_rng(7)
    prompts = {i: rng.integers(0, cfg.vocab_size, size=n)
               for i, n in enumerate((6, 5, 4))}
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=16, arrival=0.0))
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=16, arrival=0.0))
    # arrives at t=5 with its deadline already reached -> must preempt
    urgent = Request(rid=2, prompt=prompts[2], max_new=4, arrival=5.0,
                     priority=1, deadline=5.0)
    sched.submit(urgent)
    sched.step(now=0.0)                     # admits rid 0 and 1
    sched.step(now=1.0)                     # both still far from finishing
    fin = _drive(sched, start=5.0)

    assert sched.preemptions == 1
    by_rid = {f.rid: f for f in fin}
    assert sorted(by_rid) == [0, 1, 2]
    preempted = [f for f in fin if f.preempted]
    assert len(preempted) == 1 and preempted[0].preempted == 1
    assert preempted[0].rid in (0, 1)
    # the urgent request was admitted in the preemption pass at t=5.0
    assert by_rid[2].admit_time == 5.0
    for f in fin:
        ref = _reference(params, cfg, dec, prompts[f.rid],
                         min(16, 16 if f.rid != 2 else 4))
        np.testing.assert_array_equal(f.tokens, ref)
        assert f.generated == len(ref)
        assert f.prompt_len == len(prompts[f.rid])
    # stitched record: one extra prefill on top of the uninterrupted run
    assert preempted[0].invocations >= 3
    assert preempted[0].mean_accepted > 0


def test_no_preempt_equal_priority(model, dense_engine):
    """A past-deadline request never evicts an equal-priority slot —
    victims must be STRICTLY lower priority."""
    params, cfg, dec = model
    sched = Scheduler(dense_engine)
    rng = np.random.default_rng(11)
    prompts = {i: rng.integers(0, cfg.vocab_size, size=n)
               for i, n in enumerate((6, 5, 4))}
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=16, arrival=0.0))
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=16, arrival=0.0))
    sched.submit(Request(rid=2, prompt=prompts[2], max_new=4, arrival=5.0,
                         priority=0, deadline=5.0))   # same priority
    sched.step(now=0.0)
    fin = _drive(sched, start=5.0)
    assert sched.preemptions == 0
    by_rid = {f.rid: f for f in fin}
    assert all(f.preempted == 0 for f in fin)
    assert by_rid[2].admit_time > 5.0       # waited for a natural finish
    for f in fin:
        ref = _reference(params, cfg, dec, prompts[f.rid],
                         16 if f.rid != 2 else 4)
        np.testing.assert_array_equal(f.tokens, ref)


def test_no_preempt_when_deadline_not_at_risk(model, dense_engine):
    """A far-future deadline does not preempt even when the group is full
    (the seeded-at-zero tpot estimate only fires once the deadline is
    actually reached)."""
    params, cfg, dec = model
    sched = Scheduler(dense_engine)
    rng = np.random.default_rng(13)
    prompts = {i: rng.integers(0, cfg.vocab_size, size=n)
               for i, n in enumerate((6, 5, 4))}
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=16, arrival=0.0))
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=16, arrival=0.0))
    sched.submit(Request(rid=2, prompt=prompts[2], max_new=4, arrival=5.0,
                         priority=1, deadline=1e9))
    sched.step(now=0.0)
    fin = _drive(sched, start=5.0)
    assert sched.preemptions == 0
    assert all(f.preempted == 0 for f in fin)
    for f in fin:
        ref = _reference(params, cfg, dec, prompts[f.rid],
                         16 if f.rid != 2 else 4)
        np.testing.assert_array_equal(f.tokens, ref)


def test_backpressure_requeue_order_no_starvation(model):
    """A tight paged pool bounces the large request; its backpressured flag
    then blocks later-arriving small sjf requests from leapfrogging it —
    admission order is (small co-arrival, bounced large, then the rest),
    and everyone finishes with reference tokens."""
    params, cfg, dec = model
    decp = dec.replace(cache_backend="paged", page_size=8)
    ecfg = EngineConfig(num_slots=2, max_prompt_len=16, max_new_cap=16)
    context_len = cfg.num_meta_tokens + ecfg.max_prompt_len + ecfg.max_new_cap
    # pool = one worst-case request (+ trash page): two full-budget
    # admissions cannot coexist
    pool = 1 + cache_lib.pages_per_row(context_len, decp.block_k,
                                       decp.page_size)
    engp = ContinuousBatchingEngine(
        params, cfg, decp, dataclasses.replace(ecfg, page_pool_pages=pool))
    sched = Scheduler(engp, policy="sjf")
    rng = np.random.default_rng(17)
    prompts = {i: rng.integers(0, cfg.vocab_size, size=8) for i in range(4)}
    budgets = {0: 16, 1: 14, 2: 12, 3: 12}
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=16, arrival=0.0))
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=14, arrival=0.0))
    sched.submit(Request(rid=2, prompt=prompts[2], max_new=12, arrival=1.0))
    sched.submit(Request(rid=3, prompt=prompts[3], max_new=12, arrival=1.0))

    fin = _drive(sched, start=0.0)
    by_rid = {f.rid: f for f in fin}
    assert sorted(by_rid) == [0, 1, 2, 3]   # nobody starved
    assert sched.backpressure_events >= 2
    # t=0: sjf admits rid 1 (14 < 16), rid 0 bounces off the pool
    assert by_rid[1].admit_time == 0.0
    assert by_rid[0].queue_delay > 0
    # head-of-line: the bounced large request admits BEFORE the small
    # later arrivals, despite losing to them on sjf length
    assert by_rid[0].admit_time < by_rid[2].admit_time
    assert by_rid[0].admit_time < by_rid[3].admit_time
    for f in fin:
        # paged + requeued output still equals the dense run-to-completion
        # reference — backpressure is a scheduling delay, not a decode change
        ref = _reference(params, cfg, dec, prompts[f.rid], budgets[f.rid])
        np.testing.assert_array_equal(f.tokens, ref)
