"""Policy-equivalence harness for per-request decode policies.

The gate for the serving engine's policy slot grouping: a MIXED-policy
engine run — heterogeneous ``Request.policy`` fields served by per-policy
slot groups, with admission and eviction interleaved mid-flight — must be
per-request token-identical to a single-policy ``DecodeSession`` run of
the same request.  Covered mixes: {exact, topk, input_copy, topk_tree,
draft_model} × {fcfs, sjf} on a single device, and a 2×2
("data", "model") mesh variant (skips on 1-device hosts, runs in the CI
``sharded`` job).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.config import DecodeConfig, ModelConfig
from repro.core.bundle import ModelBundle
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    DecodeSession,
    EngineConfig,
    Request,
    Scheduler,
)

pytestmark = pytest.mark.serving

# one slot group per policy in the mix; input_copy drafts from Request.src
# (defaulting to the prompt) and draft_model runs the auxiliary bundle
MIX = ("exact", "topk", "input_copy", "topk_tree", "draft_model")


@pytest.fixture(scope="module")
def stack():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    # top_k=2 makes the topk group genuinely diverge from exact tokens
    dec = DecodeConfig(max_new_tokens=12, block_k=4, top_k=2)
    dcfg = ModelConfig(name="tiny-draft", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=cfg.vocab_size, bpd_enabled=False,
                       max_seq_len=512, dtype="float32")
    dparams = M.init(jax.random.PRNGKey(9), dcfg)
    bundles = {"draft": ModelBundle(dparams, dcfg)}
    return cfg, params, dec, bundles


def _workload(cfg, ecfg, n_per_policy=2, seed=7):
    """n_per_policy requests per policy in MIX, mixed prompt lengths and
    budgets — more requests than slots, so groups evict and re-admit."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_per_policy):
        for p in MIX:
            plen = int(rng.integers(3, ecfg.max_prompt_len + 1))
            reqs.append(Request(
                rid=len(reqs), policy=p,
                prompt=rng.integers(0, cfg.vocab_size, size=plen),
                max_new=int(rng.integers(4, ecfg.max_new_cap + 1))))
    return reqs


_REF_CACHE = {}  # (policy, prompt, src, max_new) -> result; the fcfs and
                 # sjf parametrizations verify the identical workload, so
                 # memoizing halves the suite's reference decodes


def _single_policy_reference(stack, req, ecfg):
    """The gate's reference: a SINGLE-policy DecodeSession run of exactly
    this request (its own policy, its own budget, no other traffic)."""
    cfg, params, dec, bundles = stack
    pol = req.policy or dec.criterion
    max_new = min(req.max_new, ecfg.max_new_cap)
    src_key = None if req.src is None else req.src.tobytes()
    key = (pol, req.prompt.tobytes(), src_key, max_new)
    if key in _REF_CACHE:
        return _REF_CACHE[key]
    d = dec.replace(max_new_tokens=max_new)
    sess = DecodeSession(params, cfg, d, policy=pol,
                         bundles=bundles if pol == "draft_model" else None)
    batch = {"tokens": jnp.asarray(req.prompt)[None]}
    if pol == "input_copy":
        # the engine's admission pads src to the admission geometry — feed
        # the reference the identical padded row so even draft contents
        # (and therefore iteration counts) line up
        src = np.zeros((ecfg.max_prompt_len,), np.int32)
        toks = req.prompt if req.src is None else req.src
        src[:len(toks)] = toks
        batch["src"] = jnp.asarray(src)[None]
    out, stats = sess.decode(batch)
    n = int(stats["text_len"][0])
    _REF_CACHE[key] = (np.asarray(out[0, len(req.prompt):n]),
                       int(stats["generated"][0]))
    return _REF_CACHE[key]


def _check_all(stack, ecfg, finished, reqs):
    by_rid = {f.rid: f for f in finished}
    assert sorted(by_rid) == [r.rid for r in reqs]
    for r in reqs:
        f = by_rid[r.rid]
        assert f.policy == (r.policy or "exact")
        ref_toks, ref_gen = _single_policy_reference(stack, r, ecfg)
        np.testing.assert_array_equal(
            f.tokens, ref_toks,
            err_msg=f"rid={r.rid} policy={r.policy}: mixed-policy engine "
                    f"tokens diverge from the single-policy session run")
        assert f.generated == ref_gen, (r.rid, r.policy)


@pytest.mark.parametrize("sched_policy", ["fcfs", "sjf"])
def test_mixed_policy_engine_token_identical(stack, sched_policy):
    """5-policy mix, 1 slot per group, 2 requests per policy: every group
    evicts its first request and admits its second while other groups are
    mid-decode — admission/eviction interleave across heterogeneous
    policies, and every request still decodes exactly like a lone
    single-policy session run."""
    cfg, params, dec, bundles = stack
    ecfg = EngineConfig(num_slots=len(MIX), max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg, bundles=bundles,
                                   policies={p: 1 for p in MIX})
    sched = Scheduler(eng, policy=sched_policy)
    reqs = _workload(cfg, ecfg)
    for r in reqs:
        sched.submit(r)
    finished = sched.run()
    _check_all(stack, ecfg, finished, reqs)
    # every distinct (policy, geometry) compiled exactly once under all
    # that admission/eviction traffic
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()
    # every group's device-side state is stamped with its own group id
    # (SlotBatch.group metadata survives admit/step/evict round trips)
    for g in eng.groups:
        assert np.all(np.asarray(g.state.group) == g.gid), g.name


def test_midflight_admission_across_groups(stack):
    """Engine-level interleaving: requests admitted while OTHER policy
    groups are mid-decode (and after their own group evicted a finished
    request) keep their single-policy decode exactly."""
    cfg, params, dec, bundles = stack
    ecfg = EngineConfig(num_slots=3, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, ecfg, bundles=bundles,
        policies={"exact": 1, "topk_tree": 1, "draft_model": 1})
    rng = np.random.default_rng(11)
    mk = lambda rid, pol, mn: Request(  # noqa: E731
        rid=rid, policy=pol, max_new=mn,
        prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 7))))
    reqs = [mk(0, "exact", 12), mk(1, "topk_tree", 4), mk(2, "draft_model", 6),
            mk(3, "topk_tree", 8), mk(4, "exact", 5)]
    done = []
    eng.admit(reqs[0])
    done += eng.step()                      # exact is mid-decode...
    eng.admit(reqs[1])                      # ...when topk_tree admits
    eng.admit(reqs[2])
    while not eng.free_slots("topk_tree"):  # rid 1 evicts mid-flight
        done += eng.step()
    eng.admit(reqs[3])                      # re-admission into the freed slot
    while not eng.free_slots("exact"):      # rid 0 evicts while 3 decodes
        done += eng.step()
    eng.admit(reqs[4])
    while eng.has_active():
        done += eng.step()
    _check_all(stack, ecfg, done, reqs)


def test_unconfigured_policy_is_rejected(stack):
    cfg, params, dec, bundles = stack
    ecfg = EngineConfig(num_slots=2, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg,
                                   policies={"exact": 1, "topk_tree": 1})
    req = Request(rid=0, prompt=np.ones(4, np.int32), max_new=4,
                  policy="adaptive")
    with pytest.raises(ValueError, match="no slot group"):
        eng.admit(req)
    # unknown names fail with the registry's message, not a KeyError
    with pytest.raises(ValueError, match="unknown decode policy"):
        eng.admit(dataclasses.replace(req, policy="nope"))
    # the scheduler rejects at submit time, before a drain could abort
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="no slot group"):
        sched.submit(req)
    assert not sched.queue


def test_caller_supplied_policy_object_is_served(stack):
    """A hand-built / modified DecodePolicy OBJECT passed as the session
    default must actually be served — not silently replaced by the
    registry entry of the same name (regression: the default group once
    re-resolved the policy by NAME)."""
    from repro.config import get_policy
    from repro.core.policy import TopKAcceptor

    cfg, params, dec, _ = stack
    custom = dataclasses.replace(get_policy(dec, "topk"),
                                 acceptor=TopKAcceptor(top_k=7),
                                 name="custom")
    ecfg = EngineConfig(num_slots=1, max_prompt_len=6, max_new_cap=8)
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg, policy=custom)
    assert eng.groups[0].policy.acceptor.top_k == 7
    assert eng.policy_names() == ["custom"]
    # ...and requests route to it by default and by its custom name
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab_size, size=5)
    eng.admit(Request(rid=0, prompt=prompt, max_new=8))
    done = []
    while eng.has_active():
        done += eng.step()
    sess = DecodeSession(params, cfg, dec.replace(max_new_tokens=8),
                         policy=custom)
    out, stats = sess.decode({"tokens": jnp.asarray(prompt)[None]})
    n = int(stats["text_len"][0])
    np.testing.assert_array_equal(done[0].tokens, np.asarray(out[0, 5:n]))


def test_group_partition_validation(stack):
    cfg, params, dec, _ = stack
    ecfg = EngineConfig(num_slots=4, max_prompt_len=6, max_new_cap=12)
    with pytest.raises(ValueError, match="partition"):
        ContinuousBatchingEngine(params, cfg, dec, ecfg,
                                 policies={"exact": 1, "topk_tree": 1})
    with pytest.raises(ValueError, match="at least one"):
        ContinuousBatchingEngine(params, cfg, dec, ecfg,
                                 policies={"exact": 4, "topk_tree": 0})


# ---------------------------------------------------------------------------
# Locality-aware image decoding (2-D progressive-lattice drafter/schedule)
# ---------------------------------------------------------------------------


def _locality_stack(stack):
    """The same tiny model on a 4×4/stride-2 grid geometry — the drafter
    interpolates committed neighbors and the schedule clamps blocks at
    refinement-class boundaries."""
    cfg, params, dec, bundles = stack
    return cfg, params, dec.replace(image_height=4, image_width=4,
                                    locality_stride=2), bundles


def test_locality_requires_grid_geometry(stack):
    from repro.config import get_policy

    cfg, params, dec, _ = stack
    with pytest.raises(ValueError, match="image_height"):
        get_policy(dec, "locality")


def test_locality_policy_lossless(stack):
    """Under exact acceptance the locality drafter moves iteration counts,
    never tokens: its stream equals the heads-drafted exact stream."""
    cfg, params, decl, _ = _locality_stack(stack)
    d = decl.replace(max_new_tokens=12)
    rng = np.random.default_rng(67)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 4)))
    outs = {}
    for pol in ("exact", "locality"):
        out, stats = DecodeSession(params, cfg, d, policy=pol).decode(
            {"tokens": prompts})
        outs[pol] = np.asarray(out)
    np.testing.assert_array_equal(outs["locality"], outs["exact"])


def test_locality_engine_token_identical(stack):
    """The ``locality`` group in a mixed engine — admissions and evictions
    interleaved with an exact group — matches the single-policy
    DecodeSession reference per request, tokens AND generated counts."""
    cfg, params, decl, bundles = _locality_stack(stack)
    ecfg = EngineConfig(num_slots=2, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(params, cfg, decl, ecfg, bundles=bundles,
                                   policies={"locality": 1, "exact": 1})
    sched = Scheduler(eng)
    rng = np.random.default_rng(61)
    reqs = [Request(rid=i, policy=pol,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 7))),
                    max_new=int(rng.integers(4, 13)))
            for i, pol in enumerate(["locality", "exact"] * 3)]
    for r in reqs:
        sched.submit(r)
    finished = sched.run()
    _check_all((cfg, params, decl, bundles), ecfg, finished, reqs)
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode: token identity with the dense references
# ---------------------------------------------------------------------------


def test_disagg_engine_token_identical_across_policies(stack):
    """The disaggregated engine (dedicated prefill workers + KV-handoff
    queue) across {exact, topk_tree, draft_model} groups with more
    requests than slots — admission, eviction and worker prefills all
    interleave mid-flight, and every stream still matches its
    single-policy unified-session reference byte-for-byte."""
    cfg, params, dec, bundles = stack
    pols = ("exact", "topk_tree", "draft_model")
    ecfg = EngineConfig(num_slots=3, max_prompt_len=6, max_new_cap=12,
                        prefill_slots=2, handoff_cap=6)
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg, bundles=bundles,
                                   policies={p: 1 for p in pols})
    sched = Scheduler(eng)
    rng = np.random.default_rng(43)
    reqs = [Request(rid=i, policy=pols[i % 3],
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 7))),
                    max_new=int(rng.integers(4, 13)))
            for i in range(9)]
    for r in reqs:
        sched.submit(r)
    finished = sched.run()
    _check_all(stack, ecfg, finished, reqs)
    assert eng.num_prefill_batches > 0       # admissions used the workers
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()


def test_disagg_preemption_token_identical(stack):
    """Deadline preemption against the disaggregated engine: full-budget
    low-priority requests occupy every slot, then an urgent already-late
    request forces an eviction.  The victim requeues through the handoff
    path and restarts — and every finished stream (victim included) still
    equals its single-policy reference."""
    cfg, params, dec, bundles = stack
    # max_prompt_len leaves room for prompt + committed tokens: a victim is
    # only feasible while its continuation still fits the admission shape
    ecfg = EngineConfig(num_slots=2, max_prompt_len=24, max_new_cap=12,
                        prefill_slots=2, handoff_cap=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, ecfg, bundles=bundles,
        policies={"exact": 1, "topk_tree": 1})
    sched = Scheduler(eng)
    rng = np.random.default_rng(47)
    mk = lambda rid, pol, mn, **kw: Request(  # noqa: E731
        rid=rid, policy=pol, max_new=mn,
        prompt=rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 7))), **kw)
    low = [mk(0, "exact", 12), mk(1, "topk_tree", 12)]
    for r in low:
        sched.submit(r)
    for _ in range(64):                      # tick until both are admitted
        if not eng.free_slots():
            break
        sched.step()
    assert not eng.free_slots(), "low-priority fill never admitted"
    urgent = mk(2, "exact", 4, priority=1, deadline=time.monotonic())
    sched.submit(urgent)
    finished = sched.run()
    assert sched.preemptions >= 1
    _check_all(stack, ecfg, finished, low + [urgent])


# ---------------------------------------------------------------------------
# Paged KV cache backend: token identity with the dense references
# ---------------------------------------------------------------------------


def test_paged_engine_token_identical_across_policies(stack):
    """The paged KV cache is a pure layout change: the 5-policy mixed
    engine with ``cache_backend="paged"`` — page allocation at admission,
    CoW prefix sharing, release on harvest, all interleaved mid-flight —
    produces exactly the tokens of the dense single-policy reference runs
    for every request."""
    cfg, params, dec, bundles = stack
    decp = dec.replace(cache_backend="paged", page_size=8)
    ecfg = EngineConfig(num_slots=len(MIX), max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(params, cfg, decp, ecfg, bundles=bundles,
                                   policies={p: 1 for p in MIX})
    sched = Scheduler(eng)
    reqs = _workload(cfg, ecfg)
    for r in reqs:
        sched.submit(r)
    finished = sched.run()
    _check_all(stack, ecfg, finished, reqs)
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()
    # after the drain every group's pool is fully released and consistent
    for g in eng.groups:
        assert g.pages is not None, g.name
        g.pages.check_invariants()
        assert g.pages.live_pages() == 0, g.name
        assert g.pages.available_pages() == g.pages.num_pages - 1, g.name


def test_paged_engine_shares_identical_prefixes(stack):
    """Two requests with the same prompt map the prompt-covering page once
    (CoW) and still decode exactly like the dense reference."""
    cfg, params, dec, bundles = stack
    decp = dec.replace(cache_backend="paged", page_size=8)
    # prompt spans exactly one page: max_prompt_len == page_size
    ecfg = EngineConfig(num_slots=2, max_prompt_len=8, max_new_cap=12)
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new=6 + 3 * i)
            for i in range(2)]
    eng = ContinuousBatchingEngine(params, cfg, decp, ecfg, bundles=bundles)
    for r in reqs:
        eng.admit(r)
    alloc = eng.groups[0].pages
    pages = {s: list(p) for s, p in alloc.slot_pages.items()}
    assert pages[0][0] == pages[1][0], "prefix page not shared"
    assert alloc.refcount[pages[0][0]] == 2
    done = []
    while eng.has_active():
        done += eng.step()
    _check_all(stack, ecfg, done, reqs)
    alloc.check_invariants()
    # the shared prefix stays cached for future hits after release
    assert len(alloc.prefix_map) >= 1 and alloc.live_pages() == 0


# ---------------------------------------------------------------------------
# Fused verification fast path (DecodeConfig.fused_verify) + tree + carry-over
# ---------------------------------------------------------------------------


def _decode_once(stack, pol, *, fused=False, policy_obj=None, mesh=None,
                 seed=31, max_new=12):
    cfg, params, dec, bundles = stack
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(3, 5))
    d = dec.replace(max_new_tokens=max_new, fused_verify=fused)
    sess = DecodeSession(params, cfg, d, policy=policy_obj or pol, mesh=mesh,
                         bundles=bundles if pol == "draft_model" else None)
    batch = {"tokens": jnp.asarray(prompts)}
    if pol == "input_copy":
        batch["src"] = jnp.asarray(prompts)
    out, stats = sess.decode(batch)
    return np.asarray(out), np.asarray(stats["iterations"])


@pytest.mark.parametrize("pol", ["exact", "topk", "distance", "input_copy",
                                 "topk_tree", "draft_model"])
def test_fused_verify_token_identical(stack, pol):
    """The one-pass Pallas accept kernel (fused_verify=True) is a drop-in:
    tokens AND iteration counts match the unfused acceptor path for every
    policy, including tree verification and the draft-model drafter."""
    out0, it0 = _decode_once(stack, pol, fused=False)
    out1, it1 = _decode_once(stack, pol, fused=True)
    np.testing.assert_array_equal(out0, out1)
    np.testing.assert_array_equal(it0, it1)


def test_tree_verification_lossless(stack):
    """Tree verification commits exactly the greedy stream: topk_tree
    tokens == exact tokens (drafters move iteration counts, never tokens
    under exact acceptance — now across a branching candidate tree)."""
    out_exact, _ = _decode_once(stack, "exact")
    out_tree, _ = _decode_once(stack, "topk_tree")
    np.testing.assert_array_equal(out_tree, out_exact)


def test_draft_carry_over_token_identical_fewer_steps(stack):
    """Suffix carry-over folds the catch-up token into the first draft
    extension: token-identical to the legacy k-step draft loop with
    strictly fewer sequential draft-model forwards."""
    from repro.core import decode as D
    from repro.core import policy as policy_lib

    cfg, params, dec, bundles = stack
    dcfg = bundles["draft"].cfg
    calls = {"n": 0}

    def counting_factory(c, kv_chunk):
        be = D.causal_lm_backend(c, kv_chunk=kv_chunk)
        inner = be.decode_block

        def counted(p, h, caches, ln, tree=None):
            calls["n"] += 1
            return inner(p, h, caches, ln)

        return be._replace(decode_block=counted)

    rng = np.random.default_rng(37)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 5)))}
    d = dec.replace(max_new_tokens=12, policy="draft_model")

    def run(carry):
        pol = policy_lib.resolve_policy(d)
        pol = dataclasses.replace(
            pol, drafter=dataclasses.replace(pol.drafter, carry_over=carry))
        b = {"draft": ModelBundle(bundles["draft"].params, dcfg,
                                  backend_factory=counting_factory)}
        calls["n"] = 0
        with jax.disable_jit():   # count real calls, not traces
            toks, stats = D.bpd_decode(params, cfg, d, batch, policy=pol,
                                       bundles=b)
        return np.asarray(toks), int(stats["iterations"]), calls["n"]

    t_new, it_new, n_new = run(True)
    t_old, it_old, n_old = run(False)
    np.testing.assert_array_equal(t_new, t_old)
    assert it_new == it_old
    assert n_new < n_old, (n_new, n_old)
    # per-iteration: k-1 vs k sequential draft forwards
    from repro.core.draft import DraftModelDrafter

    drafter = DraftModelDrafter()
    k = d.block_k
    assert drafter.draft_steps_per_iter(k) == k - 1
    assert dataclasses.replace(
        drafter, carry_over=False).draft_steps_per_iter(k) == k


# ---------------------------------------------------------------------------
# Sharded variant (CI `sharded` job; skips on 1-device hosts)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 host devices: run with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8")
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(data=2, model=2, require=True)


@pytest.mark.sharded
def test_mixed_policy_engine_sharded_token_identical(stack, mesh):
    """The mixed-policy engine on a 2×2 ("data", "model") mesh: each
    group's slot view shards the data axis on its own (2 slots / group),
    and every request still matches its single-device single-policy
    reference byte-for-byte."""
    cfg, params, dec, bundles = stack
    ecfg = EngineConfig(num_slots=4, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, ecfg, mesh=mesh, bundles=bundles,
        policies={"exact": 2, "topk_tree": 2})
    rng = np.random.default_rng(13)
    reqs = []
    for i, pol in enumerate(["exact", "topk_tree", "exact", "topk_tree",
                             "exact", "topk_tree"]):
        reqs.append(Request(
            rid=i, policy=pol,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 7))),
            max_new=int(rng.integers(4, 13))))
    done = []
    eng.admit(reqs[0])
    done += eng.step()                      # mid-flight across groups
    for r in reqs[1:4]:
        eng.admit(r)
    while len(done) < 2:
        done += eng.step()
    for r in reqs[4:]:                      # re-admission into freed slots
        while not eng.free_slots(r.policy):
            done += eng.step()
        eng.admit(r)
    while eng.has_active():
        done += eng.step()
    _check_all(stack, ecfg, done, reqs)
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()
    # per-group slot views genuinely shard: data over slots, model over kv
    for g in eng.groups:
        k = g.state.caches[0]["attn"]["k"]
        axes = {a for e in k.sharding.spec if e
                for a in (e if isinstance(e, tuple) else (e,))}
        assert {"data", "model"} <= axes, (g.name, k.sharding)


@pytest.mark.sharded
def test_locality_engine_sharded_token_identical(stack, mesh):
    """The locality group's grid-buffer drafter state (B, n+k) and schedule
    position counter shard over the data axis like any slot-leading state:
    the 2×2 mesh run matches the single-device single-policy references."""
    cfg, params, decl, bundles = _locality_stack(stack)
    ecfg = EngineConfig(num_slots=4, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(
        params, cfg, decl, ecfg, mesh=mesh, bundles=bundles,
        policies={"locality": 2, "exact": 2})
    sched = Scheduler(eng)
    rng = np.random.default_rng(71)
    reqs = [Request(rid=i, policy=pol,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 7))),
                    max_new=int(rng.integers(4, 13)))
            for i, pol in enumerate(["locality", "exact"] * 3)]
    for r in reqs:
        sched.submit(r)
    _check_all((cfg, params, decl, bundles), ecfg, sched.run(), reqs)


@pytest.mark.sharded
def test_paged_engine_sharded_token_identical(stack, mesh):
    """Paged backend on the 2×2 ("data", "model") mesh: the page pool is
    replicated over data (shared across rows) with kv heads over the model
    axis, block tables shard with the slots — and every request still
    matches its dense single-device single-policy reference."""
    cfg, params, dec, bundles = stack
    decp = dec.replace(cache_backend="paged", page_size=8)
    ecfg = EngineConfig(num_slots=4, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(
        params, cfg, decp, ecfg, mesh=mesh, bundles=bundles,
        policies={"exact": 2, "topk_tree": 2})
    rng = np.random.default_rng(29)
    reqs = [Request(rid=i, policy=pol,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 7))),
                    max_new=int(rng.integers(4, 13)))
            for i, pol in enumerate(["exact", "topk_tree"] * 3)]
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    finished = sched.run()
    _check_all(stack, ecfg, finished, reqs)
    for g in eng.groups:
        tbl = g.state.caches[0]["attn"]["tbl"]
        assert any(e for e in tbl.sharding.spec), (g.name, tbl.sharding)
        g.pages.check_invariants()
        assert g.pages.live_pages() == 0, g.name


@pytest.mark.sharded
@pytest.mark.parametrize("pol", ["exact", "topk", "topk_tree"])
def test_fused_verify_sharded_token_identical(stack, mesh, pol):
    """fused_verify on a 2×2 ("data", "model") mesh: the Pallas accept
    kernel (interpret mode on host devices) under GSPMD still matches the
    unfused single-device decode byte-for-byte."""
    out0, it0 = _decode_once(stack, pol, fused=False)
    out1, it1 = _decode_once(stack, pol, fused=True, mesh=mesh)
    np.testing.assert_array_equal(out0, out1)
    np.testing.assert_array_equal(it0, it1)


@pytest.mark.sharded
def test_tree_verification_sharded_lossless(stack, mesh):
    """Tree verification on the 2×2 mesh == single-device exact tokens."""
    out_exact, _ = _decode_once(stack, "exact")
    out_tree, _ = _decode_once(stack, "topk_tree", mesh=mesh)
    np.testing.assert_array_equal(out_tree, out_exact)


@pytest.mark.sharded
def test_group_mesh_divisibility(stack, mesh):
    """Each group's slot view must divide the data axes on its own."""
    cfg, params, dec, _ = stack
    ecfg = EngineConfig(num_slots=4, max_prompt_len=6, max_new_cap=12)
    with pytest.raises(ValueError, match="divisible"):
        ContinuousBatchingEngine(params, cfg, dec, ecfg, mesh=mesh,
                                 policies={"exact": 3, "topk_tree": 1})


# ---------------------------------------------------------------------------
# Pod mesh ("pod", "data", "model"): disaggregated serving at cluster shape
# (CI `sharded` job with 8 forced host devices; skips elsewhere)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pod_mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs >=8 host devices: run with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8")
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(data=2, model=2, pod=2, require=True)


@pytest.mark.sharded
def test_disagg_pod_mesh_token_identical(stack, pod_mesh):
    """Disaggregated engine on the (2,2,2) ("pod","data","model") mesh:
    prefill packets shard over the pod axis, the decode slot slab over
    pod×data, and the attach-time resharding is the measured KV handoff.
    Every stream must still equal its SINGLE-DEVICE single-policy
    reference — the pod mesh and the handoff move bytes, never tokens."""
    cfg, params, dec, bundles = stack
    ecfg = EngineConfig(num_slots=8, max_prompt_len=6, max_new_cap=12,
                        prefill_slots=4, handoff_cap=8)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, ecfg, mesh=pod_mesh, bundles=bundles,
        policies={"exact": 4, "topk_tree": 4})
    sched = Scheduler(eng)
    rng = np.random.default_rng(53)
    reqs = [Request(rid=i, policy=pol,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 7))),
                    max_new=int(rng.integers(4, 13)))
            for i, pol in enumerate(["exact", "topk_tree"] * 6)]
    for r in reqs:
        sched.submit(r)
    finished = sched.run()
    _check_all(stack, ecfg, finished, reqs)
    assert eng.num_prefill_batches > 0
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()
    # the slot slab genuinely spans the pod axis (pod×data over slots,
    # model over kv heads) — the cluster shape, not a degenerate layout
    for g in eng.groups:
        k = g.state.caches[0]["attn"]["k"]
        axes = {a for e in k.sharding.spec if e
                for a in (e if isinstance(e, tuple) else (e,))}
        assert {"pod", "data", "model"} <= axes, (g.name, k.sharding)


@pytest.mark.sharded
def test_unified_pod_mesh_token_identical(stack, pod_mesh):
    """The unified engine on the same pod mesh — the equal-device-count
    baseline of the disaggregation claim stays token-exact too."""
    cfg, params, dec, bundles = stack
    ecfg = EngineConfig(num_slots=8, max_prompt_len=6, max_new_cap=12)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, ecfg, mesh=pod_mesh, bundles=bundles,
        policies={"exact": 4, "topk_tree": 4})
    sched = Scheduler(eng)
    rng = np.random.default_rng(59)
    reqs = [Request(rid=i, policy=pol,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 7))),
                    max_new=int(rng.integers(4, 13)))
            for i, pol in enumerate(["exact", "topk_tree"] * 5)]
    for r in reqs:
        sched.submit(r)
    _check_all(stack, ecfg, sched.run(), reqs)
