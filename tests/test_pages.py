"""Property tests for the paged-KV host allocator (serving/pages.py).

Random admit/release traffic driven by hypothesis (skipped on minimal
installs via the tests/_hyp.py shim) plus deterministic pins for the CoW
prefix cache, rollback-on-exhaustion, and the error split between
back-pressure (PagePoolExhausted) and never-satisfiable requests
(ValueError).  ``check_invariants`` runs after every operation: no page is
ever double-freed, lost, or held by two states at once.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.serving.pages import PageAllocator, PagePoolExhausted

PS = 8  # page size for every case here


def _mk(num_pages=12, P=4, prefix_len=0):
    return PageAllocator(num_pages, PS, P, prefix_len=prefix_len)


def _prompt(rng, n):
    return rng.integers(1, 97, n)


# ---------------------------------------------------------------------------
# Deterministic pins
# ---------------------------------------------------------------------------


def test_trash_page_never_allocated():
    a = _mk()
    rng = np.random.default_rng(0)
    for slot in range(3):
        tbl, _ = a.plan_admit(slot, _prompt(rng, 5), 5, 8)
        assert 0 not in a.slot_pages[slot]
        # unmapped tail entries point at trash 0, mapped ones never do
        n = a.pages_needed(5, 8)
        assert (tbl[:n] > 0).all() and (tbl[n:] == 0).all()
    a.check_invariants()


def test_release_returns_all_pages():
    a = _mk()
    rng = np.random.default_rng(1)
    for slot in range(3):
        a.plan_admit(slot, _prompt(rng, 6), 6, 10)
    assert a.available_pages() < a.num_pages - 1
    for slot in range(3):
        a.release(slot)
        a.check_invariants()
    assert a.live_pages() == 0
    assert a.available_pages() == a.num_pages - 1


def test_release_unknown_slot_is_noop():
    a = _mk()
    assert a.release(7) == 0
    a.check_invariants()


def test_double_admit_same_slot_rejected():
    a = _mk()
    a.plan_admit(0, _prompt(np.random.default_rng(2), 4), 4, 4)
    with pytest.raises(RuntimeError, match="already holds"):
        a.plan_admit(0, _prompt(np.random.default_rng(3), 4), 4, 4)


def test_cow_fork_shares_and_preserves_prefix_page():
    """Identical prompts map the same physical prefix page; the second
    admission must NOT rewrite it (write_mask False) — that is what keeps
    the first request's prefix bytes intact on device."""
    a = _mk()
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, PS)  # exactly one shareable page
    t0, w0 = a.plan_admit(0, prompt, PS, 4)
    t1, w1 = a.plan_admit(1, prompt, PS, 4)
    assert t0[0] == t1[0]
    assert w0[0] and not w1[0]            # first writes, the fork must not
    assert a.refcount[t0[0]] == 2
    # a different prompt gets its own page
    t2, w2 = a.plan_admit(2, _prompt(rng, PS), PS, 4)
    assert t2[0] != t0[0] and w2[0]
    a.check_invariants()


def test_prefix_cache_survives_release_until_reclaimed():
    a = _mk(num_pages=4, P=2)             # 3 allocatable pages
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, PS)
    t0, _ = a.plan_admit(0, prompt, PS, 4)
    a.release(0)
    assert a.live_pages() == 0
    # the cached prefix is still a hit after release...
    t1, w1 = a.plan_admit(1, prompt, PS, 4)
    assert t1[0] == t0[0] and not w1[0]
    a.release(1)
    # ...until pool pressure reclaims it (LRU): drain the free list with
    # one 2-page admission, then a 1-page admission must evict the cache
    a.plan_admit(2, _prompt(rng, 3), 3, PS)         # takes both free pages
    t3, w3 = a.plan_admit(3, _prompt(rng, 3), 3, 2)  # 1 page: reclaims
    assert t3[0] == t0[0] and w3[0]       # reclaimed — now writable
    assert not a.prefix_map               # its cache entry is gone
    a.check_invariants()


def test_exhaustion_rolls_back_and_raises():
    a = _mk(num_pages=4, P=3)             # 3 allocatable pages
    rng = np.random.default_rng(6)
    a.plan_admit(0, _prompt(rng, 4), 4, 8)          # 2 pages
    before = dict(a.refcount)
    with pytest.raises(PagePoolExhausted):
        a.plan_admit(1, _prompt(rng, 4), 4, 12)     # needs 2, only 1 left
    assert a.refcount == before           # partial mapping rolled back
    assert 1 not in a.slot_pages
    a.check_invariants()
    a.release(0)
    a.plan_admit(1, _prompt(rng, 4), 4, 12)         # now it fits
    a.check_invariants()


def test_failed_plan_unregisters_its_prefix_cache():
    """Regression: a plan that exhausts the pool mid-way must unregister
    the prefix pages IT registered — their bytes were never written (the
    admit prefill never ran), so a later identical prompt must get a
    writable page, not a phantom CoW hit against garbage KV."""
    a = _mk(num_pages=5, P=4)             # 4 allocatable pages
    rng = np.random.default_rng(8)
    a.plan_admit(0, _prompt(rng, 4), 4, 16)      # 3 pages, 1 left
    prompt = _prompt(rng, PS)             # first page fully covered
    with pytest.raises(PagePoolExhausted):
        a.plan_admit(1, prompt, PS, 8)    # maps 1 (registered), needs 2
    # the phantom prefix is gone: nothing reclaimable, nothing cached
    assert not a.prefix_map and not a.page_key and not a.reclaimable
    a.check_invariants()
    a.release(0)
    # retry with the SAME prompt: every mapped page must be written
    tbl, wm = a.plan_admit(1, prompt, PS, 8)
    n = a.pages_needed(PS, 8)
    assert wm[:n].all()
    a.check_invariants()


def test_never_satisfiable_is_config_error_not_backpressure():
    a = _mk(num_pages=4, P=8)
    rng = np.random.default_rng(7)
    # needs 5 pages; the pool only has 3 even when drained — admitting it
    # later could never succeed, so this must not look like back-pressure
    with pytest.raises(ValueError, match="page_pool_pages"):
        a.plan_admit(0, _prompt(rng, 8), 8, 32)
    with pytest.raises(ValueError, match="rows address only"):
        _mk(num_pages=64, P=2).plan_admit(0, _prompt(rng, 8), 8, 32)


def test_prefix_len_offsets_sharing():
    """With a model prefix (meta tokens), a page is shareable only once the
    *prompt* tokens under it are known — the first page covers prefix
    positions plus the prompt's head."""
    a = _mk(prefix_len=4)
    rng = np.random.default_rng(8)
    p1, p2 = _prompt(rng, 4), _prompt(rng, 4)
    t0, _ = a.plan_admit(0, p1, 4, 4)     # prefix 4 + prompt 4 = page 0 full
    t1, _ = a.plan_admit(1, p1, 4, 4)
    t2, _ = a.plan_admit(2, p2, 4, 4)
    assert t0[0] == t1[0] != t2[0]
    a.check_invariants()


# ---------------------------------------------------------------------------
# Property tests: random admit/release traffic
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), num_pages=st.integers(4, 24),
       steps=st.integers(5, 40))
def test_allocator_traffic_never_corrupts(seed, num_pages, steps):
    """Arbitrary interleavings of admit (with prompt-dedup CoW) / release /
    exhaustion keep every invariant: pages partition into free ∪ held ∪
    reclaimable, refcounts equal slot multiplicity, and releasing
    everything restores the whole pool."""
    rng = np.random.default_rng(seed)
    P = 4
    a = PageAllocator(num_pages, PS, P, prefix_len=0)
    prompts = [_prompt(rng, int(rng.integers(1, 2 * PS))) for _ in range(4)]
    live, next_slot = [], 0
    for _ in range(steps):
        if live and rng.random() < 0.4:
            a.release(live.pop(int(rng.integers(len(live)))))
        else:
            pr = prompts[int(rng.integers(len(prompts)))]
            mn = int(rng.integers(1, 2 * PS))
            if a.pages_needed(len(pr), mn) > min(P, num_pages - 1):
                continue  # never-satisfiable: ValueError by design
            try:
                a.plan_admit(next_slot, pr, len(pr), mn)
                live.append(next_slot)
                next_slot += 1
            except PagePoolExhausted:
                assert next_slot not in a.slot_pages  # rolled back
        a.check_invariants()
    for s in live:
        a.release(s)
        a.check_invariants()
    assert a.live_pages() == 0
    assert a.available_pages() == a.num_pages - 1


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), plen=st.integers(1, 16),
       prefix_len=st.integers(0, 6))
def test_cow_fork_always_preserves_prefix_bytes(seed, plen, prefix_len):
    """For every geometry: a fork of an identical prompt (1) shares every
    shareable page, (2) never asks the device to rewrite a shared page
    (write_mask False on hits — the bytes the first request wrote stay),
    and (3) differing prompts never share."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(32, PS, 6, prefix_len=prefix_len)
    prompt = _prompt(rng, plen)
    t0, w0 = a.plan_admit(0, prompt, plen, 4)
    t1, w1 = a.plan_admit(1, prompt, plen, 4)
    shareable = (np.arange(1, 7) * PS) <= prefix_len + plen  # per page i
    n = a.pages_needed(plen, 4)
    for i in range(n):
        if shareable[i]:
            assert t1[i] == t0[i] and not w1[i], i
        else:
            assert t1[i] != t0[i] and w1[i], i
    # a prompt differing in its LAST token shares no page covering it
    other = prompt.copy()
    other[-1] = (other[-1] + 1) % 97
    t2, _ = a.plan_admit(2, other, plen, 4)
    covers_last = (np.arange(1, 7) * PS) > prefix_len + plen - 1
    for i in range(n):
        if shareable[i] and covers_last[i]:
            assert t2[i] != t0[i], i
    a.check_invariants()


# ---------------------------------------------------------------------------
# EngineConfig page-pool geometry validation
# ---------------------------------------------------------------------------


def test_engine_config_rejects_bad_page_geometry():
    from repro.config import DecodeConfig
    from repro.serving.types import EngineConfig

    dec = DecodeConfig(max_new_tokens=16, block_k=4, cache_backend="paged",
                       page_size=6)
    ecfg = EngineConfig(num_slots=2, max_prompt_len=8, max_new_cap=16)
    with pytest.raises(ValueError, match="multiple of 8"):
        ecfg.validate(dec)
    dec = dec.replace(page_size=8)
    ecfg.validate(dec)                    # auto pool: fine
    # a pool too small for even one max-size request names the fix
    tiny = EngineConfig(num_slots=2, max_prompt_len=8, max_new_cap=16,
                        page_pool_pages=3)
    with pytest.raises(ValueError, match="page_pool_pages to at least 4"):
        tiny.validate(dec)
    EngineConfig(num_slots=2, max_prompt_len=8, max_new_cap=16,
                 page_pool_pages=4).validate(dec)   # exactly one request
