"""Mesh-sharded decode sessions: token-identical to the single-device paths.

Runs on a forced multi-device host:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        pytest -q tests/test_sharded.py

On a plain 1-device host every test skips (the mesh fixture checks the
device count at runtime), so the tier-1 command stays environment-agnostic.

Coverage: DecodeSession-backed bpd_decode / greedy_decode /
bpd_decode_seq2seq and the continuous-batching engine under mid-flight
admission, all on a ("data", "model") = (2, 2) mesh, asserting

  * outputs byte-identical to the unsharded reference paths,
  * param and KV-cache shardings genuinely split on the model axis
    (not silently replicated),
  * compile-once device functions survive sharding,
  * EngineConfig mesh validation (num_slots % data-axis product).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_seq2seq
from repro.config import DecodeConfig
from repro.core import decode as D
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import seq2seq as S
from repro.serving import (
    ContinuousBatchingEngine,
    DecodeSession,
    EngineConfig,
    Request,
)

pytestmark = pytest.mark.sharded


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 host devices: run with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8")
    return make_host_mesh(data=2, model=2, require=True)


@pytest.fixture(scope="module")
def dense():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dec = DecodeConfig(max_new_tokens=16, block_k=4)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                          cfg.vocab_size)}
    return cfg, params, dec, batch


@pytest.fixture(scope="module")
def session(mesh, dense):
    cfg, params, dec, _ = dense
    return DecodeSession(params, cfg, dec, mesh=mesh)


def _spec_axes(sharding):
    out = set()
    for entry in sharding.spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            out.add(ax)
    return out


def test_params_sharded_on_model_axis(session):
    """device_put params actually split on the model axis — the Megatron
    scheme is live, not silently replicated by a divisibility fallback."""
    leaves = jax.tree_util.tree_leaves_with_path(session.params)
    model_sharded = [jax.tree_util.keystr(k) for k, v in leaves
                     if "model" in _spec_axes(v.sharding)]
    assert len(model_sharded) >= 4, model_sharded
    # attention projections are the canonical tensor-parallel weights
    assert any("attn" in name for name in model_sharded)
    for _, v in leaves:
        assert v.sharding.mesh.shape == session.mesh.shape


def test_bpd_decode_token_identical(session, dense):
    cfg, params, dec, batch = dense
    ref_toks, ref_stats = D.bpd_decode(params, cfg, dec, batch)
    toks, stats = D.bpd_decode(params, cfg, dec, batch, session=session)
    np.testing.assert_array_equal(np.asarray(ref_toks), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(ref_stats["generated"]),
                                  np.asarray(stats["generated"]))
    np.testing.assert_array_equal(np.asarray(ref_stats["text_len"]),
                                  np.asarray(stats["text_len"]))
    # outputs stay data-sharded — the session's explicit out_shardings
    assert "data" in _spec_axes(toks.sharding)


def test_bpd_decode_per_row_budgets_token_identical(session, dense):
    cfg, params, dec, batch = dense
    budgets = jnp.asarray([3, 16, 9, 5], jnp.int32)
    ref, _ = D.bpd_decode(params, cfg, dec, batch, max_new_rows=budgets)
    out, stats = session.decode(batch, max_new_rows=budgets)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(stats["generated"]),
                                  np.asarray(budgets))


def test_greedy_token_identical(session, dense):
    cfg, params, dec, batch = dense
    ref, _ = D.greedy_decode(params, cfg, dec, batch)
    out, _ = D.greedy_decode(params, cfg, dec, batch, session=session)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("dec_kw", [
    dict(criterion="topk", top_k=2),       # legacy string aliases …
    dict(criterion="distance", epsilon=2.0),
    dict(policy="adaptive"),               # … and policy-native names
    dict(policy="topk_tree"),
])
def test_policies_token_identical_sharded(mesh, dense, dec_kw):
    """Every criterion alias / registered policy decodes token-identically
    through a mesh-backed session (policy state sharded with the loop)."""
    cfg, params, dec, batch = dense
    d = dec.replace(**dec_kw)
    ref_t, ref_s = D.bpd_decode(params, cfg, d, batch)
    out_t, out_s = D.bpd_decode(params, cfg, d, batch, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(out_t))
    np.testing.assert_array_equal(np.asarray(ref_s["generated"]),
                                  np.asarray(out_s["generated"]))
    assert int(ref_s["iterations"]) == int(out_s["iterations"])


def test_draft_model_policy_sharded(mesh, dense):
    """The speculative draft-model policy — a second ModelBundle with its
    own params, shardings, and loop-carried KV cache inside policy_state —
    decodes token-identically through a mesh-backed session, and its draft
    cache genuinely shards (data over slots, kv-heads over model)."""
    from repro.core.bundle import ModelBundle
    from repro.config import ModelConfig

    cfg, params, dec, batch = dense
    dcfg = ModelConfig(name="tiny-draft", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=cfg.vocab_size, bpd_enabled=False,
                       max_seq_len=512, dtype="float32")
    dparams = M.init(jax.random.PRNGKey(9), dcfg)
    bundles = {"draft": ModelBundle(dparams, dcfg)}

    ref_t, ref_s = D.bpd_decode(params, cfg, dec, batch,
                                policy="draft_model", bundles=bundles)
    sess = DecodeSession(params, cfg, dec, mesh=mesh, policy="draft_model",
                         bundles={"draft": ModelBundle(dparams, dcfg)})
    out_t, out_s = sess.decode(batch)
    np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(out_t))
    np.testing.assert_array_equal(np.asarray(ref_s["generated"]),
                                  np.asarray(out_s["generated"]))
    assert int(ref_s["iterations"]) == int(out_s["iterations"])
    # ... and greedy equivalence survives the mesh (exact acceptance)
    greedy_t, _ = D.greedy_decode(params, cfg, dec, batch)
    w = batch["tokens"].shape[1] + dec.max_new_tokens
    np.testing.assert_array_equal(np.asarray(greedy_t[:, :w]),
                                  np.asarray(out_t[:, :w]))
    # the draft bundle's params are mesh-placed like the primary's
    for _, v in jax.tree_util.tree_leaves_with_path(sess.aux_params["draft"]):
        assert v.sharding.mesh.shape == sess.mesh.shape


def test_engine_draft_model_sharded_midflight(mesh, dense):
    """Sharded engine + draft-model policy: admission prefills the draft
    cache (scattered into the slot row), steps run the draft model inside
    the jitted step, outputs match the single-device reference."""
    from repro.core.bundle import ModelBundle
    from repro.config import ModelConfig

    cfg, params, dec, _ = dense
    dcfg = ModelConfig(name="tiny-draft", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=cfg.vocab_size, bpd_enabled=False,
                       max_seq_len=512, dtype="float32")
    dparams = M.init(jax.random.PRNGKey(9), dcfg)
    eng = ContinuousBatchingEngine(
        params, cfg, dec,
        EngineConfig(num_slots=4, max_prompt_len=8, max_new_cap=16),
        mesh=mesh, policy="draft_model",
        bundles={"draft": ModelBundle(dparams, dcfg)})

    dk = eng.state.policy_state.drafter["caches"][0]["attn"]["k"]
    assert "data" in _spec_axes(dk.sharding), dk.sharding
    assert "model" in _spec_axes(dk.sharding), dk.sharding

    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab_size, size=8)
    p1 = rng.integers(0, cfg.vocab_size, size=5)
    done = []
    eng.admit(Request(rid=0, prompt=p0, max_new=16))
    for _ in range(2):
        done += eng.step()
    eng.admit(Request(rid=1, prompt=p1, max_new=10))
    while eng.has_active():
        done += eng.step()

    by_rid = {f.rid: f for f in done}
    for rid, prompt, mn in ((0, p0, 16), (1, p1, 10)):
        t, s = D.bpd_decode(
            params, cfg, dec.replace(max_new_tokens=mn),
            {"tokens": jnp.asarray(prompt)[None]},
            policy="draft_model",
            bundles={"draft": ModelBundle(dparams, dcfg)})
        n = int(s["text_len"][0])
        np.testing.assert_array_equal(by_rid[rid].tokens,
                                      np.asarray(t[0, len(prompt):n]))
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()


def test_input_copy_policy_sharded_seq2seq(mesh):
    """The source-drafting policy (loop-carried drafter state holding the
    src batch) survives sharding token-identically."""
    cfg = tiny_seq2seq()
    params = S.init(jax.random.PRNGKey(4), cfg)
    dec = DecodeConfig(max_new_tokens=12, block_k=4, policy="input_copy")
    batch = {"src": jax.random.randint(jax.random.PRNGKey(5), (2, 6), 1,
                                       cfg.vocab_size)}
    ref, ref_s = D.bpd_decode_seq2seq(params, cfg, dec, batch)
    out, out_s = D.bpd_decode_seq2seq(params, cfg, dec, batch, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    assert int(ref_s["iterations"]) == int(out_s["iterations"])


def test_seq2seq_token_identical(mesh):
    cfg = tiny_seq2seq()
    params = S.init(jax.random.PRNGKey(2), cfg)
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    batch = {"src": jax.random.randint(jax.random.PRNGKey(3), (2, 5), 0,
                                       cfg.vocab_size)}
    ref, ref_stats = D.bpd_decode_seq2seq(params, cfg, dec, batch)
    out, stats = D.bpd_decode_seq2seq(params, cfg, dec, batch, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(ref_stats["generated"]),
                                  np.asarray(stats["generated"]))


def _reference(params, cfg, dec, prompt, max_new):
    d1 = dec.replace(max_new_tokens=max_new)
    t, s = D.bpd_decode(params, cfg, d1, {"tokens": jnp.asarray(prompt)[None]})
    return np.asarray(t[0, len(prompt):int(s["text_len"][0])])


def test_engine_sharded_midflight_admission(mesh, dense):
    """The sharded engine serves the same tokens as the single-device
    reference, including a request admitted while another is mid-decode,
    and its slot KV caches genuinely shard on the model axis."""
    cfg, params, dec, _ = dense
    eng = ContinuousBatchingEngine(
        params, cfg, dec,
        EngineConfig(num_slots=4, max_prompt_len=8, max_new_cap=16),
        mesh=mesh)

    k = eng.state.caches[0]["attn"]["k"]
    assert "model" in _spec_axes(k.sharding), k.sharding
    assert "data" in _spec_axes(k.sharding), k.sharding

    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab_size, size=8)
    p1 = rng.integers(0, cfg.vocab_size, size=5)
    done = []
    eng.admit(Request(rid=0, prompt=p0, max_new=16))
    for _ in range(2):                      # progress request 0 first
        done += eng.step()
    eng.admit(Request(rid=1, prompt=p1, max_new=10))
    while eng.has_active():
        done += eng.step()

    by_rid = {f.rid: f for f in done}
    np.testing.assert_array_equal(by_rid[0].tokens,
                                  _reference(params, cfg, dec, p0, 16))
    np.testing.assert_array_equal(by_rid[1].tokens,
                                  _reference(params, cfg, dec, p1, 10))
    # compile-once survives sharding (admit twice, step many, evict twice)
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()


def test_engine_config_mesh_validation(mesh, dense):
    cfg, params, dec, _ = dense
    with pytest.raises(ValueError, match="divisible"):
        ContinuousBatchingEngine(
            params, cfg, dec,
            EngineConfig(num_slots=3, max_prompt_len=8, max_new_cap=16),
            mesh=mesh)


def test_engine_config_validation_is_mesh_independent(dense):
    """Construction-time EngineConfig checks fire without a mesh too."""
    cfg, params, dec, _ = dense
    for bad in (EngineConfig(num_slots=0),
                EngineConfig(max_prompt_len=0),
                EngineConfig(max_new_cap=0),
                EngineConfig(max_new_cap=dec.max_new_tokens + 1)):
        with pytest.raises(ValueError):
            ContinuousBatchingEngine(params, cfg, dec, bad)
