"""Model-substrate consistency: the cached decode path must agree with the
full parallel forward at the same absolute positions — this is what makes
the BPD verify substep mathematically equal to scoring a longer prefix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FAMILY_CONFIGS
from repro.config import DecodeConfig
from repro.models import model as M
from repro.models.attention import make_causal_mask
from repro.models.layers import embed_apply


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
def test_cached_decode_matches_full_forward(family):
    """Prefill P tokens, then decode-step the next k: hidden states must match
    a single full forward over P+k tokens."""
    cfg = FAMILY_CONFIGS[family]()
    params = M.init(jax.random.PRNGKey(0), cfg)
    p_len, k = 7, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, p_len + k), 0,
                                cfg.vocab_size)
    batch_full = {"tokens": tokens}
    batch_pre = {"tokens": tokens[:, :p_len]}
    prefix = M.prefix_len(cfg, batch_full)

    # full parallel forward (full-capacity MoE routing, matching the decode
    # path, which never drops tokens)
    h_full = M.embed_inputs(params, cfg, batch_full)
    pos = jnp.arange(h_full.shape[1], dtype=jnp.int32)
    hid_full, _, _ = M.forward_hidden(params, cfg, h_full, positions=pos,
                                      moe_full_capacity=True)

    # prefill + cached block step
    caches = M.init_caches(cfg, 2, prefix + p_len + k + 8, k)
    h_pre = M.embed_inputs(params, cfg, batch_pre)
    pos_pre = jnp.arange(h_pre.shape[1], dtype=jnp.int32)
    _, _, caches = M.forward_hidden(params, cfg, h_pre, positions=pos_pre,
                                    caches=caches, moe_full_capacity=True)
    h_blk = embed_apply(params["embed"], tokens[:, p_len:]).astype(
        cfg.compute_dtype)
    length = jnp.full((2,), p_len + prefix, jnp.int32)
    hid_blk, _ = M.decode_block_step(params, cfg, h_blk, caches, length)

    want = np.asarray(hid_full[:, prefix + p_len:, :], np.float32)
    got = np.asarray(hid_blk, np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cache_rollback_reproduces_rejected_positions():
    """Write a speculative block, commit only k̂=2 of 4, then re-decode from
    the rollback point: results must equal a fresh decode of the accepted
    prefix (the BPD rejection path)."""
    cfg = FAMILY_CONFIGS["dense"]()
    params = M.init(jax.random.PRNGKey(0), cfg)
    b, p_len, k = 2, 6, 4
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, p_len), 0,
                                cfg.vocab_size)
    spec1 = jax.random.randint(jax.random.PRNGKey(3), (b, k), 0,
                               cfg.vocab_size)
    spec2 = jax.random.randint(jax.random.PRNGKey(4), (b, k), 0,
                               cfg.vocab_size)

    def prefill():
        caches = M.init_caches(cfg, b, p_len + 3 * k, k)
        h = M.embed_inputs(params, cfg, {"tokens": tokens})
        pos = jnp.arange(p_len, dtype=jnp.int32)
        _, _, caches = M.forward_hidden(params, cfg, h, positions=pos,
                                        caches=caches, moe_full_capacity=True)
        return caches

    khat = jnp.asarray([2, 2], jnp.int32)

    # path A: speculate spec1 (rejected beyond 2), roll back, then spec2
    caches = prefill()
    e1 = embed_apply(params["embed"], spec1).astype(cfg.compute_dtype)
    _, staged = M.decode_block_step(params, cfg, e1, caches,
                                    jnp.full((b,), p_len, jnp.int32))
    caches = M.commit_caches(cfg, staged, khat)
    e2 = embed_apply(params["embed"], spec2).astype(cfg.compute_dtype)
    hidA, _ = M.decode_block_step(params, cfg, e2, caches,
                                  jnp.full((b,), p_len + 2, jnp.int32))

    # path B: the accepted prefix was spec1[:, :2] — decode spec2 directly
    caches = prefill()
    acc = spec1[:, :2]
    ea = embed_apply(params["embed"], acc).astype(cfg.compute_dtype)
    _, staged = M.decode_block_step(params, cfg, ea, caches,
                                    jnp.full((b,), p_len, jnp.int32))
    caches = M.commit_caches(cfg, staged, jnp.full((b,), 2, jnp.int32))
    hidB, _ = M.decode_block_step(params, cfg, e2, caches,
                                  jnp.full((b,), p_len + 2, jnp.int32))

    np.testing.assert_allclose(np.asarray(hidA, np.float32),
                               np.asarray(hidB, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_mask():
    qp = jnp.asarray([[5, 6]])
    kp = jnp.asarray([jnp.arange(8)])
    m = make_causal_mask(qp, kp, window=3, num_meta=1)
    want_q5 = [True, False, False, True, True, True, False, False]
    want_q6 = [True, False, False, False, True, True, True, False]
    np.testing.assert_array_equal(np.asarray(m[0, 0]), want_q5)
    np.testing.assert_array_equal(np.asarray(m[0, 1]), want_q6)


def test_stale_positions_masked():
    m = make_causal_mask(jnp.asarray([[4]]), jnp.asarray([[-1, 2, 4, 9]]))
    np.testing.assert_array_equal(np.asarray(m[0, 0]),
                                  [False, True, True, False])


def test_chunked_attend_matches_dense():
    from repro.models.attention import attn_full, attn_init

    cfg = FAMILY_CONFIGS["dense"]()
    p = attn_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, cfg.d_model))
    y_dense = attn_full(p, cfg, x)
    y_chunk = attn_full(p, cfg, x, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


def test_moe_full_capacity_routes_all_tokens():
    from repro.models.moe import moe_apply, moe_init

    cfg = FAMILY_CONFIGS["moe"]()
    p = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
    y, metrics = moe_apply(p, cfg, x, full_capacity=True)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(metrics["moe_dropped_frac"]) == 0.0


def test_vocab_padding_masked_logits():
    cfg = FAMILY_CONFIGS["dense"](vocab_size=97)
    assert cfg.padded_vocab_size == 256
    params = M.init(jax.random.PRNGKey(0), cfg)
    h = jax.random.normal(jax.random.PRNGKey(1), (3, cfg.d_model))
    logits = M.project_vocab(params, cfg, h)
    assert logits.shape[-1] == 256
    assert float(jnp.max(logits[:, 97:])) <= -1e8
