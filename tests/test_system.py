"""End-to-end system tests: train a small combined scoring/proposal model on
a synthetic task, then show the paper's effect — BPD needs fewer model
invocations than greedy while producing the identical output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.config import DecodeConfig, TrainConfig
from repro.core import decode as D
from repro.data.synthetic import MarkovLM
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import optimizer_init


@pytest.fixture(scope="module")
def trained_lm():
    """Small dense LM trained on a low-entropy Markov chain (predictable
    enough that the heads learn to forecast several tokens)."""
    cfg = tiny_dense(vocab_size=32, bpd_k=4, d_model=96, d_ff=192)
    tc = TrainConfig(global_batch=16, seq_len=48, lr=3e-3, warmup_steps=20,
                     head_loss="mean")
    task = MarkovLM(vocab=cfg.vocab_size, temperature=0.12, seed=3)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc))
    gen = task.batches(batch=tc.global_batch, seq_len=tc.seq_len, seed=1)
    key = jax.random.PRNGKey(1)
    for i in range(250):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step(params, opt, batch, sub)
    return cfg, params, task, float(metrics["loss"])


def test_training_converged(trained_lm):
    _, _, _, loss = trained_lm
    assert loss < 2.4           # well below log(32) ~ 3.47


def test_bpd_speedup_and_equivalence_after_training(trained_lm):
    cfg, params, task, _ = trained_lm
    prompts = jnp.asarray(task.sample(np.random.default_rng(9), 8, 12))
    dec = DecodeConfig(max_new_tokens=32, block_k=4, criterion="exact")
    bt, bs = D.bpd_decode(params, cfg, dec, {"tokens": prompts})
    gt, gs = D.greedy_decode(params, cfg, dec, {"tokens": prompts})
    np.testing.assert_array_equal(np.asarray(bt[:, :44]),
                                  np.asarray(gt[:, :44]))
    mean_k = float(bs["mean_accepted"])
    assert mean_k > 1.5, f"trained heads should accept blocks, got {mean_k}"
    assert int(bs["invocations"]) < int(gs["invocations"])


def test_invocation_accounting(trained_lm):
    """Paper §4: a combined model needs ~ m/k̂ + 1 invocations for m tokens."""
    cfg, params, task, _ = trained_lm
    prompts = jnp.asarray(task.sample(np.random.default_rng(10), 4, 12))
    dec = DecodeConfig(max_new_tokens=24, block_k=4)
    _, bs = D.bpd_decode(params, cfg, dec, {"tokens": prompts})
    mean_k = float(bs["mean_accepted"])
    invocations = int(bs["invocations"])
    bound = 24 / mean_k + 1
    assert invocations <= bound * 1.35 + 1   # per-row k̂ variance slack


def test_checkpoint_roundtrip_preserves_decode(trained_lm, tmp_path):
    from repro.checkpoint import restore, save

    cfg, params, task, _ = trained_lm
    save(str(tmp_path), 1, params)
    restored, _ = restore(str(tmp_path), params)
    prompts = jnp.asarray(task.sample(np.random.default_rng(11), 2, 10))
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    t1, _ = D.bpd_decode(params, cfg, dec, {"tokens": prompts})
    t2, _ = D.bpd_decode(restored, cfg, dec, {"tokens": prompts})
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
