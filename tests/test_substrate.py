"""Optimizer / checkpoint / data / sharding substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import tiny_dense
from repro.config import TrainConfig
from repro.data.synthetic import CipherMT, MarkovLM, MaskedFrames, OrdinalCurves
from repro.models import model as M
from repro.optim import (
    adafactor_init,
    adafactor_update,
    make_schedule,
    optimizer_init,
    optimizer_update,
)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    tc = TrainConfig(lr=0.1, warmup_steps=1, schedule="constant",
                     weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = optimizer_init(params, tc)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = optimizer_update(g, opt, params, tc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adafactor_reduces_quadratic():
    tc = TrainConfig(optimizer="adafactor", lr=0.1, warmup_steps=1,
                     schedule="constant", weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.ones((4, 8)) * 3.0}
    opt = optimizer_init(params, tc)
    for _ in range(300):
        g = jax.tree_util.tree_map(lambda w: 2 * w, params)
        params, opt, _ = optimizer_update(g, opt, params, tc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_inv_sqrt_schedule_shape():
    tc = TrainConfig(lr=1.0, warmup_steps=100, schedule="inv_sqrt")
    sched = make_schedule(tc)
    lr10, lr100, lr400 = (float(sched(s)) for s in (10, 100, 400))
    assert lr10 < lr100                      # warming up
    np.testing.assert_allclose(lr400, lr100 / 2, rtol=1e-5)  # 1/sqrt(4x)


def test_grad_clip_bounds_update_norm():
    tc = TrainConfig(lr=1.0, warmup_steps=1, schedule="constant",
                     grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = optimizer_init(params, tc)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, metrics = optimizer_update(g, opt, params, tc)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore, save

    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    save(str(tmp_path), 12, params, extra={"arch": cfg.name, "step": 12})
    save(str(tmp_path), 20, params, extra={"arch": cfg.name, "step": 20})
    assert latest_step(str(tmp_path)) == 20
    restored, extra = restore(str(tmp_path), params, step=12)
    assert extra["step"] == 12
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        params, restored)


def test_checkpoint_rotation(tmp_path):
    from repro.checkpoint import latest_step, save

    params = {"w": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, params, keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


# ---------------------------------------------------------------------------
# Synthetic data
# ---------------------------------------------------------------------------


def test_markov_determinism_and_range():
    t1 = MarkovLM(vocab=32, seed=5).sample(np.random.default_rng(1), 4, 64)
    t2 = MarkovLM(vocab=32, seed=5).sample(np.random.default_rng(1), 4, 64)
    np.testing.assert_array_equal(t1, t2)
    assert t1.min() >= 0 and t1.max() < 32


def test_markov_temperature_controls_entropy():
    def bigram_entropy(t):
        joint = np.zeros((32, 32))
        for row in t:
            for a, b in zip(row[:-1], row[1:]):
                joint[a, b] += 1
        p = joint / joint.sum()
        nz = p[p > 0]
        return -(nz * np.log(nz)).sum()

    rng = np.random.default_rng(0)
    cold = MarkovLM(vocab=32, temperature=0.1).sample(rng, 16, 128)
    hot = MarkovLM(vocab=32, temperature=3.0).sample(rng, 16, 128)
    assert bigram_entropy(cold) < bigram_entropy(hot)


def test_cipher_mt_is_invertible():
    task = CipherMT(vocab=50)
    src, tgt = task.make_pair(np.random.default_rng(0), 4, 10)
    assert (tgt != 0).all()
    # applying the cipher to reversed src reproduces tgt
    np.testing.assert_array_equal(task.cipher[src[:, ::-1]], tgt)


def test_ordinal_curves_smooth():
    t = OrdinalCurves(levels=256).sample(np.random.default_rng(0), 8, 128)
    steps = np.abs(np.diff(t.astype(int), axis=1))
    assert t.min() >= 0 and t.max() < 256
    assert np.median(steps) <= 8      # smooth curves: small local deltas


def test_masked_frames_shapes():
    mf = MaskedFrames(d_model=32, codebook=100)
    b = mf.sample(np.random.default_rng(0), 2, 40)
    assert b["frame_embeds"].shape == (2, 40, 32)
    assert b["mask"].any() and not b["mask"].all()
    assert b["targets"].max() < 100


# ---------------------------------------------------------------------------
# Sharding policy (1-device property checks: specs must be consistent)
# ---------------------------------------------------------------------------


def test_param_specs_cover_every_leaf():
    from jax.sharding import PartitionSpec as P

    from repro.sharding import param_specs

    cfg = tiny_dense()
    params = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = param_specs(params, mesh)
    leaves = jax.tree_util.tree_leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(jax.tree_util.tree_leaves(params))
    assert all(isinstance(s, P) for s in leaves)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(dim0=st.integers(1, 64), dim1=st.integers(1, 64),
       axis=st.sampled_from([2, 4, 8]))
def test_divisibility_property(dim0, dim1, axis):
    """_divisible never returns a spec whose sharded dim does not divide."""
    import math

    from jax.sharding import PartitionSpec as P

    from repro.sharding.policy import _divisible

    class FakeMesh:
        shape = {"model": axis, "data": 2}

    spec = _divisible(P("model", "data"), (dim0, dim1), FakeMesh())
    if spec[0] == "model":
        assert dim0 % axis == 0
    if len(spec) > 1 and spec[1] == "data":
        assert dim1 % 2 == 0


def test_batch_axes_replicates_indivisible_batch():
    from repro.sharding.policy import batch_axes

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert batch_axes(mesh, 4) is not None     # divisible by 1
