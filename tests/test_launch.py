"""Launcher-layer units: input specs, shape-grid adaptation, collective-HLO
parsing, buffer padding — everything the dry-run relies on that can be
checked without 512 devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, DecodeConfig, get_config
from repro.launch import steps as steps_lib
from repro.launch.dryrun import collective_bytes
from repro.models.cache import attn_buf_len


def test_input_specs_cover_grid():
    for arch in ("granite-3-8b", "llava-next-34b", "hubert-xlarge"):
        cfg = get_config(arch, smoke=True)
        for shape in INPUT_SHAPES:
            spec = steps_lib.input_specs(cfg, shape)
            b = INPUT_SHAPES[shape]["global_batch"]
            for name, s in spec.items():
                assert s.shape[0] == b, (arch, shape, name)


def test_input_specs_audio_train_has_mask_and_targets():
    cfg = get_config("hubert-xlarge", smoke=True)
    spec = steps_lib.input_specs(cfg, "train_4k")
    assert set(spec) == {"frame_embeds", "mask", "targets"}
    spec = steps_lib.input_specs(cfg, "prefill_32k")
    assert set(spec) == {"frame_embeds"}


def test_vlm_text_len_subtracts_patches():
    cfg = get_config("llava-next-34b", smoke=True)
    spec = steps_lib.input_specs(cfg, "train_4k")
    s = INPUT_SHAPES["train_4k"]["seq_len"]
    assert spec["tokens"].shape[1] == s - cfg.num_patch_tokens
    assert spec["patch_embeds"].shape[1] == cfg.num_patch_tokens


def test_adapt_config_skips_encoder_only_decode():
    cfg = get_config("hubert-xlarge")
    assert steps_lib.adapt_config(cfg, "decode_32k") is None
    assert steps_lib.adapt_config(cfg, "long_500k") is None
    assert steps_lib.adapt_config(cfg, "train_4k") is not None


def test_adapt_config_long_context_windows_dense():
    dense = get_config("granite-3-8b")
    adapted = steps_lib.adapt_config(dense, "long_500k")
    assert adapted.sliding_window == steps_lib.LONG_WINDOW
    # sub-quadratic archs run long_500k natively
    for arch in ("rwkv6-1.6b", "hymba-1.5b"):
        cfg = get_config(arch)
        assert steps_lib.adapt_config(cfg, "long_500k").sliding_window == \
            cfg.sliding_window
    # starcoder2 has a native sliding window already
    sc = get_config("starcoder2-7b")
    assert steps_lib.adapt_config(sc, "long_500k").sliding_window == \
        sc.sliding_window


def test_attn_buf_len_padded_and_window_capped():
    cfg = get_config("granite-3-8b")
    n = attn_buf_len(cfg, 0, 32768 + 64, 8)
    assert n % 256 == 0 and n >= 32768 + 64 + 8
    sw = cfg.replace(sliding_window=8192)
    n = attn_buf_len(sw, 0, 524288 + 64, 8)
    assert n % 256 == 0
    assert n <= 8192 + 8 + 255 + 1  # window-capped, not context-sized


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%p, %q)
  %mm = f32[8,8]{1,0} dot(%a, %b)
  %ags = bf16[4,256]{1,0} all-gather-start(%z), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["bytes_by_op"]["all-gather"] == 8 * 128 * 2 + 4 * 256 * 2
    assert out["bytes_by_op"]["all-reduce"] == 1024 * 4
    assert out["bytes_by_op"]["all-to-all"] == 2 * 16 * 16 * 4
    assert out["counts"]["all-gather"] == 2
    assert "dot" not in out["bytes_by_op"]


def test_serve_state_struct_matches_materialized():
    cfg = get_config("granite-3-8b", smoke=True).replace(dtype="float32")
    dec = DecodeConfig(max_new_tokens=8)
    struct = steps_lib.serve_state_struct(cfg, dec, batch=2, seq_len=16,
                                          max_new=8)
    state = steps_lib.materialize_serve_state(cfg, dec, batch=2, seq_len=16,
                                              max_new=8)
    s_shapes = jax.tree_util.tree_map(lambda s: (s.shape, s.dtype), struct)
    m_shapes = jax.tree_util.tree_map(lambda a: (a.shape, a.dtype), state)
    assert jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda a, b: a == b, s_shapes, m_shapes))


def test_decode_with_chunked_prefill_matches_plain():
    """kv_chunk changes the prefill computation order, not the result."""
    from repro.core import decode as D
    from repro.models import model as M

    cfg = get_config("granite-3-8b", smoke=True).replace(dtype="float32")
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                          cfg.vocab_size)}
    dec = DecodeConfig(max_new_tokens=8)
    t1, _ = D.bpd_decode(params, cfg, dec, batch, kv_chunk=0)
    t2, _ = D.bpd_decode(params, cfg, dec, batch, kv_chunk=8)
    np.testing.assert_array_equal(np.asarray(t1[:, :32]),
                                  np.asarray(t2[:, :32]))


def test_ring_buffer_wraparound_generation():
    """Generate past the sliding window: the ring buffer must wrap without
    corrupting decode (BPD still equals greedy)."""
    from repro.core import decode as D
    from repro.models import model as M

    cfg = get_config("starcoder2-7b", smoke=True).replace(
        dtype="float32", sliding_window=16, max_seq_len=256)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    dec = DecodeConfig(max_new_tokens=40)   # >> window of 16
    bt, _ = D.bpd_decode(params, cfg, dec, batch)
    gt, _ = D.greedy_decode(params, cfg, dec, batch)
    np.testing.assert_array_equal(np.asarray(bt[:, :48]),
                                  np.asarray(gt[:, :48]))
