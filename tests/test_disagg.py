"""Disaggregated prefill/decode: token identity under ANY interleaving.

The contract under test: a disaggregated engine (dedicated prefill
workers batching prompts, bounded KV-handoff queue, attach into freed
decode slots) produces per-request token streams bitwise identical to the
unified engine — for every interleaving of prefill-completion and
decode-admission orders the host could produce, including handoff-queue-
full back-pressure and page-pool attach stalls.  Identity holds by
construction (``admit ≡ attach ∘ prefill`` at width 1, and batch-size
invariance makes width-W worker batches safe); these tests check the
construction empirically, plus the async-stream observables: phase
timers, ``num_overlap_harvests``, and the one-fused-sync-per-group-step
accounting that PR 5 pinned.
"""
import dataclasses

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import tiny_dense
from repro.config import DecodeConfig
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    EngineConfig,
    Request,
    Scheduler,
)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def stack():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dec = DecodeConfig(max_new_tokens=10, block_k=4)
    return cfg, params, dec


ECFG = EngineConfig(num_slots=2, max_prompt_len=6, max_new_cap=10,
                    prefill_slots=2, handoff_cap=3)


@pytest.fixture(scope="module")
def disagg(stack):
    cfg, params, dec = stack
    return ContinuousBatchingEngine(params, cfg, dec, ECFG)


@pytest.fixture(scope="module")
def unified(stack):
    cfg, params, dec = stack
    ecfg = dataclasses.replace(ECFG, prefill_slots=0, handoff_cap=0)
    return ContinuousBatchingEngine(params, cfg, dec, ecfg)


def _workload(cfg, seed, n=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, arrival=0.0,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(2, 7))),
                    max_new=int(rng.integers(3, 11)))
            for i in range(n)]


def _drive_unified(eng, reqs):
    """Simple greedy unified run — admission order does not move tokens
    (the commit stream is a deterministic function of the prompt), so any
    one unified run is THE reference for every disagg interleaving."""
    todo, done = list(reqs), []
    while todo or eng.has_active():
        while todo and eng.free_slots():
            eng.admit(todo.pop(0))
        done += eng.step()
    return {f.rid: f for f in done}


_REF = {}   # workload seed -> unified reference streams


def _reference(unified_eng, cfg, seed):
    if seed not in _REF:
        _REF[seed] = _drive_unified(unified_eng, _workload(cfg, seed))
    return _REF[seed]


def _check_identical(done, ref):
    assert sorted(f.rid for f in done) == sorted(ref)
    for f in done:
        r = ref[f.rid]
        np.testing.assert_array_equal(
            f.tokens, r.tokens,
            err_msg=f"rid={f.rid}: disagg stream diverged from unified")
        assert f.generated == r.generated, f.rid
        assert f.invocations == r.invocations, f.rid


# ---------------------------------------------------------------------------
# Property: every interleaving of stage/prefill/attach/step is identical
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**16),
       ops=st.lists(st.sampled_from("qqpas"), min_size=4, max_size=40))
def test_any_interleaving_token_identical(stack, disagg, unified, seed, ops):
    """Hypothesis drives the disaggregated engine through an ARBITRARY
    op sequence — (q)ueue into the handoff, run worker (p)refills,
    (a)ttach parked rows, decode (s)tep — then drains.  Whatever order
    prefill completions and decode admissions land in (including ops that
    bounce off the full handoff queue), every request's stream matches
    the unified engine bitwise."""
    cfg, _, _ = stack
    reqs = _workload(cfg, seed)
    todo, done, full_bounces = list(reqs), [], 0
    for op in ops:
        if op == "q" and todo:
            if disagg.handoff_free() <= 0:
                # the bounded queue rejects instead of growing without
                # limit — the op is a no-op and the request waits
                with pytest.raises(RuntimeError, match="handoff"):
                    disagg.queue_prefill(todo[0])
                full_bounces += 1
            else:
                disagg.queue_prefill(todo.pop(0))
        elif op == "p":
            disagg.run_prefills()
        elif op == "a":
            disagg.attach_ready()
        elif op == "s" and disagg.has_active():
            done += disagg.step()
    # drain whatever the random schedule left behind
    while todo or disagg.handoff_backlog() or disagg.has_active():
        while todo and disagg.handoff_free() > 0:
            disagg.queue_prefill(todo.pop(0))
        disagg.run_prefills()
        disagg.attach_ready()
        if disagg.has_active():
            done += disagg.step()
    _check_identical(done, _reference(unified, cfg, seed))
    # the module-scoped engine is reused across examples: geometry never
    # changes, so nothing may ever recompile
    assert all(v == 1 for v in disagg.compile_counts().values()), \
        disagg.compile_counts()


# ---------------------------------------------------------------------------
# Deterministic edges: back-pressure on both bounds
# ---------------------------------------------------------------------------


def test_handoff_queue_full_rejects(stack, disagg):
    """``handoff_cap`` bounds staged + parked together; the overflow
    submission raises instead of queueing unboundedly."""
    cfg, _, _ = stack
    reqs = _workload(cfg, seed=99, n=ECFG.handoff_cap + 1)
    for r in reqs[:-1]:
        disagg.queue_prefill(r)
    assert disagg.handoff_free() == 0
    with pytest.raises(RuntimeError, match="handoff"):
        disagg.queue_prefill(reqs[-1])
    # prefilling moves records staged -> parked without freeing capacity
    disagg.run_prefills()
    assert disagg.handoff_free() == 0
    with pytest.raises(RuntimeError, match="handoff"):
        disagg.queue_prefill(reqs[-1])
    # attaching + draining frees it again
    disagg.attach_ready()
    while disagg.handoff_backlog() or disagg.has_active():
        disagg.run_prefills()
        disagg.attach_ready()
        if disagg.has_active():
            disagg.step()
    assert disagg.handoff_free() == ECFG.handoff_cap


def test_attach_backpressure_page_pool(stack):
    """When the paged KV pool cannot cover the head-of-queue record at
    attach time, the record WAITS at the head (num_attach_backpressure
    counts the stall) and attaches once the in-flight request retires and
    releases its pages — still token-identical to the unified run."""
    cfg, params, dec = stack
    decp = dec.replace(cache_backend="paged", page_size=8)
    # each request spans 2 pages (prompt 4 + budget 6 + lookahead 4 over
    # size-8 pages); pool = 1 trash + 3 allocatable, so ONE admitted
    # request fits but two cannot coexist
    ecfg = dataclasses.replace(ECFG, page_pool_pages=4)
    eng = ContinuousBatchingEngine(params, cfg, decp, ecfg)
    reqs = [Request(rid=i, arrival=0.0, max_new=6,
                    prompt=np.full((4,), 7 + i, np.int32))
            for i in range(2)]
    for r in reqs:
        eng.queue_prefill(r)
    eng.run_prefills()
    assert eng.attach_ready() == 1          # second record does not fit
    before = eng.num_attach_backpressure
    assert eng.attach_ready() == 0          # head-of-line wait, no skip
    assert eng.num_attach_backpressure > before
    done = []
    while eng.handoff_backlog() or eng.has_active():
        eng.attach_ready()
        if eng.has_active():
            done += eng.step()
    assert sorted(f.rid for f in done) == [0, 1]
    # unified reference under the SAME tiny pool (the scheduler requeues
    # its page-pool bounces): streams must still match bitwise
    uref = ContinuousBatchingEngine(
        params, cfg, decp,
        dataclasses.replace(ecfg, prefill_slots=0, handoff_cap=0))
    sched = Scheduler(uref)
    for r in reqs:
        sched.submit(dataclasses.replace(r))
    _check_identical(done, {f.rid: f for f in sched.run()})


# ---------------------------------------------------------------------------
# Async-stream observables: timers, overlap, sync accounting
# ---------------------------------------------------------------------------


def test_phase_timers_and_overlap(stack):
    """The per-phase host timers attribute wall time (satellite of the
    engine-vs-static regression), and with two active groups each step
    harvests group A while group B's device step is still in flight —
    ``num_overlap_harvests`` counts exactly stepped_groups - 1 per step."""
    cfg, params, dec = stack
    ecfg = dataclasses.replace(ECFG, handoff_cap=8)
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg,
                                   policies={"exact": 1, "topk": 1})
    sched = Scheduler(eng)
    rng = np.random.default_rng(5)
    for i in range(6):
        sched.submit(Request(
            rid=i, arrival=0.0, policy=("exact", "topk")[i % 2],
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(2, 7))),
            max_new=int(rng.integers(3, 11))))
    finished = sched.run()
    assert len(finished) == 6
    assert eng.time_in_prefill > 0.0
    assert eng.time_in_decode_dispatch > 0.0
    assert eng.time_in_harvest > 0.0
    # both groups were active together at least once -> overlapped harvest
    assert eng.num_overlap_harvests > 0
    # overlap never exceeds (groups - 1) per step taken
    assert eng.num_overlap_harvests <= eng.num_steps


def test_one_fused_sync_per_group_step_preserved(stack, disagg):
    """PR 5's contract survives disaggregation: worker prefill + attach
    cost ZERO device->host syncs; each group step costs exactly one fused
    status pull, plus one harvest pull per finishing group."""
    cfg, _, _ = stack
    reqs = _workload(cfg, seed=3, n=4)
    todo, done = list(reqs), []
    before = disagg.num_host_syncs
    while todo and disagg.handoff_free() > 0:
        disagg.queue_prefill(todo.pop(0))
    disagg.run_prefills()
    disagg.attach_ready()
    assert disagg.num_host_syncs == before   # admission path is sync-free
    steps = pulls = 0
    while todo or disagg.handoff_backlog() or disagg.has_active():
        while todo and disagg.handoff_free() > 0:
            disagg.queue_prefill(todo.pop(0))
        disagg.run_prefills()
        disagg.attach_ready()
        if disagg.has_active():
            got = disagg.step()
            steps += 1                       # single group -> 1 status pull
            pulls += 1 if got else 0         # + 1 harvest pull if finished
            done += got
    assert disagg.num_host_syncs - before == steps + pulls
    assert len(done) == len(reqs)


def test_windowed_decode_token_identical(stack, unified):
    """``steps_per_sync > 1`` fuses up to K decode iterations into one
    dispatch (a bounded while_loop over the same traced step body, early-
    exiting when any row finishes).  Streams must stay bitwise identical
    to per-step syncing — for the unified AND the disaggregated engine —
    including per-request ``invocations`` (the early exit surfaces
    finished rows at the same iteration per-step syncing would)."""
    cfg, params, dec = stack
    reqs = _workload(cfg, seed=11, n=8)
    uref = _drive_unified(unified, [dataclasses.replace(r) for r in reqs])
    for ecfg in (dataclasses.replace(ECFG, prefill_slots=0, handoff_cap=0,
                                     steps_per_sync=3),
                 dataclasses.replace(ECFG, steps_per_sync=3)):
        eng = ContinuousBatchingEngine(params, cfg, dec, ecfg)
        sched = Scheduler(eng)
        for r in reqs:
            sched.submit(dataclasses.replace(r))
        done = sched.run()
        _check_identical(done, uref)
        # fewer syncs than steps-without-windowing: the window actually
        # fuses (every run here has stretches with no finishing row)
        assert all(v == 1 for v in eng.compile_counts().values())


def test_queue_prefill_requires_disagg_mode(stack, unified):
    cfg, _, _ = stack
    with pytest.raises(RuntimeError, match="disaggregated"):
        unified.queue_prefill(Request(rid=0, max_new=4,
                                      prompt=np.ones(3, np.int32)))
