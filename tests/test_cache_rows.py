"""Slot-recycling cache primitives on the recurrent families.

``cache.reset_rows`` / ``cache.scatter_row`` were only exercised through
the serving engine, which is gated to attention caches — so the rwkv6 /
mamba branches (zeroed recurrent state on eviction, row-scatter on
admission) had no direct coverage.  These tests pin their semantics on
the real per-layer cache dicts built by ``blocks.block_cache_init``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_hymba, tiny_rwkv
from repro.models import cache as cache_lib
from repro.models.blocks import block_cache_init


def _randomize(cache, seed=0):
    """Fill every leaf with non-trivial values (recurrent state of a
    mid-flight request)."""
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    rng = np.random.default_rng(seed)
    out = []
    for leaf in leaves:
        vals = rng.standard_normal(leaf.shape)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            vals = rng.integers(0, 7, leaf.shape)
        out.append(jnp.asarray(vals, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _family_cache(family: str, batch: int):
    cfg = {"rwkv6": tiny_rwkv, "hymba": tiny_hymba}[family]()
    cache = block_cache_init(cfg, 0, batch, context_len=32, block_k=4,
                             dtype=jnp.float32)
    return _randomize(cache)


@pytest.mark.parametrize("family,key", [("rwkv6", "tm"), ("hymba", "mamba")])
def test_reset_rows_zeroes_recurrent_state(family, key):
    cache = _family_cache(family, batch=4)
    mask = jnp.asarray([True, False, True, False])
    out = cache_lib.reset_rows(cache, mask)

    for name, val in out[key].items():
        ref = np.asarray(cache[key][name])
        got = np.asarray(val)
        # evicted rows: recurrent state fully zeroed (a padded re-prefill
        # cannot overwrite it, unlike KV slots)
        assert np.all(got[mask] == 0), f"{key}/{name} not zeroed"
        # surviving rows: bit-identical
        np.testing.assert_array_equal(got[~np.asarray(mask)], ref[[1, 3]])


def test_reset_rows_hymba_invalidates_kv_and_zeroes_mamba():
    """The hybrid family carries BOTH cache kinds in one dict: eviction
    must invalidate the attention rows (pos = -1, values untouched) and
    zero the mamba rows in the same call."""
    cache = _family_cache("hymba", batch=3)
    mask = jnp.asarray([False, True, False])
    out = cache_lib.reset_rows(cache, mask)
    pos = np.asarray(out["attn"]["pos"])
    assert np.all(pos[1] == -1)
    np.testing.assert_array_equal(pos[[0, 2]],
                                  np.asarray(cache["attn"]["pos"])[[0, 2]])
    # K/V values are deliberately left in place (unreachable via pos = -1)
    np.testing.assert_array_equal(np.asarray(out["attn"]["k"]),
                                  np.asarray(cache["attn"]["k"]))
    assert np.all(np.asarray(out["mamba"]["h"])[1] == 0)
    assert np.all(np.asarray(out["mamba"]["conv"])[1] == 0)


@pytest.mark.parametrize("family", ["rwkv6", "hymba"])
def test_scatter_row_inserts_batch1_recurrent_cache(family):
    cache = _family_cache(family, batch=4)
    row = _randomize(jax.tree_util.tree_map(lambda x: x[:1], cache), seed=99)
    slot = jnp.asarray(2, jnp.int32)  # traced-compatible scalar
    out = jax.jit(lambda c, r: cache_lib.scatter_row(c, r, slot))(cache, row)

    def check(full_new, full_old, row_val, name):
        new, old, rv = (np.asarray(full_new), np.asarray(full_old),
                        np.asarray(row_val))
        np.testing.assert_array_equal(new[2], rv[0], err_msg=name)
        keep = [0, 1, 3]
        np.testing.assert_array_equal(new[keep], old[keep], err_msg=name)

    for key in cache:
        for name in cache[key]:
            check(out[key][name], cache[key][name], row[key][name],
                  f"{key}/{name}")


def test_scatter_row_then_reset_roundtrip_rwkv():
    """Admission-then-eviction leaves the other rows untouched and the
    recycled row zeroed — the engine lifecycle, on a recurrent cache."""
    cache = _family_cache("rwkv6", batch=3)
    row = _randomize(jax.tree_util.tree_map(lambda x: x[:1], cache), seed=7)
    admitted = cache_lib.scatter_row(cache, row, jnp.asarray(1, jnp.int32))
    evicted = cache_lib.reset_rows(admitted, jnp.asarray([False, True, False]))
    for name, val in evicted["tm"].items():
        got = np.asarray(val)
        assert np.all(got[1] == 0), name
        np.testing.assert_array_equal(
            got[[0, 2]], np.asarray(cache["tm"][name])[[0, 2]], err_msg=name)
