"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates the REDUCED variant of the same family (<=2 layers,
d_model<=512, <=4 experts), runs one forward/train step on CPU, and asserts
output shapes + finite values.  Decoder archs additionally run one BPD
serve iteration.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DecodeConfig, TrainConfig, get_config
from repro.configs import ASSIGNED
from repro.core import decode as D
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import optimizer_init

ALL_ARCHS = ASSIGNED + ["paper-mt-base"]


def _smoke_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.is_encoder_decoder:
        return {"src": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                                   jnp.int32),
                "tgt": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if cfg.modality == "audio":
        mask = np.zeros((b, s), bool)
        mask[:, 3:7] = True
        return {"frame_embeds": jnp.asarray(
                    rng.standard_normal((b, s, cfg.d_model)), jnp.float32),
                "mask": jnp.asarray(mask),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                       jnp.int32)}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if cfg.modality == "vision_text":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, 4, cfg.d_model)), jnp.float32)
    return batch


def _init(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    return S.init(key, cfg) if cfg.is_encoder_decoder else M.init(key, cfg)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.source, f"{arch} must cite its source"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    tc = TrainConfig(global_batch=2, seq_len=16, head_loss="random")
    params = _init(cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc))
    batch = _smoke_batch(cfg)
    params2, opt2, metrics = step(params, opt, batch, jax.random.PRNGKey(1))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # parameters actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))),
        jax.tree_util.tree_map(lambda a, b: a - b, params, params2), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_config(a).is_encoder_only])
def test_bpd_decode_smoke(arch):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    params = _init(cfg)
    dec = DecodeConfig(max_new_tokens=8, criterion="exact")
    batch = _smoke_batch(cfg, s=8)
    if cfg.is_encoder_decoder:
        toks, stats = D.bpd_decode_seq2seq(params, cfg, dec,
                                           {"src": batch["src"]})
    else:
        toks, stats = D.bpd_decode(params, cfg, dec, batch)
    toks = np.asarray(toks)
    assert np.isfinite(toks).all()
    assert toks.max() < cfg.vocab_size          # vocab padding never leaks
    assert float(stats["mean_accepted"]) >= 1.0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if get_config(a).is_encoder_only])
def test_encoder_only_forward_smoke(arch):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    params = _init(cfg)
    batch = _smoke_batch(cfg)
    enc = jax.jit(steps_lib.make_prefill_step(cfg, DecodeConfig()))
    logits = enc(params, {"frame_embeds": batch["frame_embeds"]})
    assert logits.shape[:2] == batch["frame_embeds"].shape[:2]
    assert logits.shape[-1] == cfg.padded_vocab_size
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_config(a).is_encoder_only
                                  and not get_config(a).is_encoder_decoder])
def test_serve_step_one_iteration(arch):
    """One BPD serve iteration against a materialized cache (what decode_32k
    lowers), at smoke scale."""
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    params = _init(cfg)
    dec = DecodeConfig(max_new_tokens=16, block_k=cfg.bpd_k)
    seq_len = 32
    step = steps_lib.make_serve_step(cfg, dec, seq_len=seq_len, max_new=16)
    state = steps_lib.materialize_serve_state(cfg, dec, batch=2,
                                              seq_len=seq_len, max_new=16)
    out = jax.jit(step)(params, state)
    assert int(out.iters) == 1
    assert np.all(np.asarray(out.text_len) >= np.asarray(state.text_len) + 1)
    assert np.isfinite(np.asarray(out.proposals)).all()
