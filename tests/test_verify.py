"""Unit tests for the verification criteria (paper §3, §5.1–§5.3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DecodeConfig
from repro.core.verify import accepted_block_size, position_accepts


def _logits_for(greedy_rows, vocab=11, second=None):
    """p1 logits whose argmax per slot is given; optional runner-up."""
    g = np.asarray(greedy_rows)
    b, k = g.shape
    logits = np.zeros((b, k, vocab), np.float32)
    for i in range(b):
        for j in range(k):
            logits[i, j, g[i, j]] = 5.0
            if second is not None:
                logits[i, j, second[i][j]] = 3.0
    return jnp.asarray(logits)


def test_exact_first_column_always_true():
    props = jnp.asarray([[3, 4, 5, 6]])
    logits = _logits_for([[9, 9, 9, 9]])  # nothing matches
    acc = position_accepts(props, logits, DecodeConfig(criterion="exact"))
    np.testing.assert_array_equal(np.asarray(acc), [[True, False, False, False]])


def test_exact_prefix_semantics():
    # slot i-1 verifies proposal i: greedy [4,5,9] vs proposals [_,4,5,6]
    props = jnp.asarray([[7, 4, 5, 6]])
    logits = _logits_for([[4, 5, 9, 0]])
    acc = position_accepts(props, logits, DecodeConfig(criterion="exact"))
    np.testing.assert_array_equal(np.asarray(acc), [[True, True, True, False]])
    khat = accepted_block_size(acc, DecodeConfig(), jnp.asarray([100]))
    assert int(khat[0]) == 3


def test_prefix_stops_at_first_reject():
    acc = jnp.asarray([[True, False, True, True]])
    khat = accepted_block_size(acc, DecodeConfig(), jnp.asarray([100]))
    assert int(khat[0]) == 1  # holes don't count (longest *prefix*)


def test_topk_accepts_runner_up():
    props = jnp.asarray([[7, 2, 2]])
    logits = _logits_for([[4, 4, 4]], second=[[2, 3, 3]])
    exact = position_accepts(props, logits, DecodeConfig(criterion="exact"))
    top2 = position_accepts(props, logits,
                            DecodeConfig(criterion="topk", top_k=2))
    assert not bool(exact[0, 1])
    assert bool(top2[0, 1])       # 2 is the runner-up at slot 0
    assert not bool(top2[0, 2])   # but not at slot 1


def test_distance_criterion_ordinal():
    props = jnp.asarray([[7, 100, 120]])
    logits = _logits_for([[98, 110, 0]], vocab=130)
    d2 = position_accepts(props, logits,
                          DecodeConfig(criterion="distance", epsilon=2.0))
    d10 = position_accepts(props, logits,
                           DecodeConfig(criterion="distance", epsilon=10.0))
    np.testing.assert_array_equal(np.asarray(d2), [[True, True, False]])
    np.testing.assert_array_equal(np.asarray(d10), [[True, True, True]])


def test_min_block_size():
    acc = jnp.asarray([[True, False, False, False]])
    k1 = accepted_block_size(acc, DecodeConfig(min_block=1), jnp.asarray([99]))
    k3 = accepted_block_size(acc, DecodeConfig(min_block=3), jnp.asarray([99]))
    assert int(k1[0]) == 1 and int(k3[0]) == 3


def test_remaining_clamps_khat():
    acc = jnp.asarray([[True, True, True, True]])
    khat = accepted_block_size(acc, DecodeConfig(), jnp.asarray([2]))
    assert int(khat[0]) == 2


@pytest.mark.parametrize("criterion", ["exact", "topk", "distance"])
def test_khat_at_least_one(criterion):
    rng = np.random.default_rng(1)
    props = jnp.asarray(rng.integers(0, 11, (8, 6)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(8, 6, 11)), jnp.float32)
    dec = DecodeConfig(criterion=criterion, top_k=2, epsilon=1.0)
    acc = position_accepts(props, logits, dec)
    khat = accepted_block_size(acc, dec, jnp.full((8,), 100))
    assert np.all(np.asarray(khat) >= 1) and np.all(np.asarray(khat) <= 6)
