"""Unit tests for the verification criteria (paper §3, §5.1–§5.3),
including hypothesis property tests over all three acceptors (skipped on
minimal installs via the tests/_hyp.py shim).

Exercises the blessed DecodePolicy path (config.get_policy -> acceptor /
schedule objects); the removed criterion-string shims in repro.core.verify
keep one pinned test asserting they fail loudly and name the migration."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.config import DecodeConfig, get_policy


def position_accepts(proposals, p1_logits, dec):
    return get_policy(dec).acceptor.accepts(proposals, p1_logits)


def accepted_block_size(accepts, dec, remaining):
    khat, _ = get_policy(dec).schedule.block_size(accepts, remaining, ())
    return khat


def _logits_for(greedy_rows, vocab=11, second=None):
    """p1 logits whose argmax per slot is given; optional runner-up."""
    g = np.asarray(greedy_rows)
    b, k = g.shape
    logits = np.zeros((b, k, vocab), np.float32)
    for i in range(b):
        for j in range(k):
            logits[i, j, g[i, j]] = 5.0
            if second is not None:
                logits[i, j, second[i][j]] = 3.0
    return jnp.asarray(logits)


def test_exact_first_column_always_true():
    props = jnp.asarray([[3, 4, 5, 6]])
    logits = _logits_for([[9, 9, 9, 9]])  # nothing matches
    acc = position_accepts(props, logits, DecodeConfig(criterion="exact"))
    np.testing.assert_array_equal(np.asarray(acc), [[True, False, False, False]])


def test_exact_prefix_semantics():
    # slot i-1 verifies proposal i: greedy [4,5,9] vs proposals [_,4,5,6]
    props = jnp.asarray([[7, 4, 5, 6]])
    logits = _logits_for([[4, 5, 9, 0]])
    acc = position_accepts(props, logits, DecodeConfig(criterion="exact"))
    np.testing.assert_array_equal(np.asarray(acc), [[True, True, True, False]])
    khat = accepted_block_size(acc, DecodeConfig(), jnp.asarray([100]))
    assert int(khat[0]) == 3


def test_prefix_stops_at_first_reject():
    acc = jnp.asarray([[True, False, True, True]])
    khat = accepted_block_size(acc, DecodeConfig(), jnp.asarray([100]))
    assert int(khat[0]) == 1  # holes don't count (longest *prefix*)


def test_topk_accepts_runner_up():
    props = jnp.asarray([[7, 2, 2]])
    logits = _logits_for([[4, 4, 4]], second=[[2, 3, 3]])
    exact = position_accepts(props, logits, DecodeConfig(criterion="exact"))
    top2 = position_accepts(props, logits,
                            DecodeConfig(criterion="topk", top_k=2))
    assert not bool(exact[0, 1])
    assert bool(top2[0, 1])       # 2 is the runner-up at slot 0
    assert not bool(top2[0, 2])   # but not at slot 1


def test_distance_criterion_ordinal():
    props = jnp.asarray([[7, 100, 120]])
    logits = _logits_for([[98, 110, 0]], vocab=130)
    d2 = position_accepts(props, logits,
                          DecodeConfig(criterion="distance", epsilon=2.0))
    d10 = position_accepts(props, logits,
                           DecodeConfig(criterion="distance", epsilon=10.0))
    np.testing.assert_array_equal(np.asarray(d2), [[True, True, False]])
    np.testing.assert_array_equal(np.asarray(d10), [[True, True, True]])


def test_min_block_size():
    acc = jnp.asarray([[True, False, False, False]])
    k1 = accepted_block_size(acc, DecodeConfig(min_block=1), jnp.asarray([99]))
    k3 = accepted_block_size(acc, DecodeConfig(min_block=3), jnp.asarray([99]))
    assert int(k1[0]) == 1 and int(k3[0]) == 3


def test_remaining_clamps_khat():
    acc = jnp.asarray([[True, True, True, True]])
    khat = accepted_block_size(acc, DecodeConfig(), jnp.asarray([2]))
    assert int(khat[0]) == 2


@pytest.mark.parametrize("criterion", ["exact", "topk", "distance"])
def test_khat_at_least_one(criterion):
    rng = np.random.default_rng(1)
    props = jnp.asarray(rng.integers(0, 11, (8, 6)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(8, 6, 11)), jnp.float32)
    dec = DecodeConfig(criterion=criterion, top_k=2, epsilon=1.0)
    acc = position_accepts(props, logits, dec)
    khat = accepted_block_size(acc, dec, jnp.full((8,), 100))
    assert np.all(np.asarray(khat) >= 1) and np.all(np.asarray(khat) <= 6)


# ---------------------------------------------------------------------------
# Property tests over the three acceptors (hypothesis; skip when absent)
# ---------------------------------------------------------------------------

CRITERIA = ("exact", "topk", "distance")


def _random_verify_case(seed, b=4, k=5, vocab=17):
    rng = np.random.default_rng(seed)
    props = jnp.asarray(rng.integers(0, vocab, (b, k)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(b, k, vocab)), jnp.float32)
    return props, logits


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), crit=st.sampled_from(CRITERIA))
def test_accepted_prefix_is_prefix_of_draft(seed, crit):
    """For every acceptor, the committed block is a PREFIX of the draft:
    k̂ counts accepted positions from the left with no holes, every
    position below k̂ was individually accepted, and 1 <= k̂ <= k."""
    props, logits = _random_verify_case(seed)
    dec = DecodeConfig(criterion=crit, top_k=2, epsilon=2.0)
    acc = np.asarray(position_accepts(props, logits, dec))
    khat = np.asarray(accepted_block_size(acc, dec, jnp.full((4,), 100)))
    k = props.shape[1]
    assert np.all(khat >= 1) and np.all(khat <= k)
    for i in range(acc.shape[0]):
        # positions inside the accepted block were all accepted...
        assert acc[i, :khat[i]].all(), (i, acc[i], khat[i])
        # ...and k̂ is the LONGEST such prefix (min_block=1): the next
        # position, if any, was rejected
        if khat[i] < k:
            assert not acc[i, khat[i]], (i, acc[i], khat[i])


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_exact_acceptance_implies_token_equality(seed):
    """ExactAcceptor semantics (§3): an accepted candidate position i >= 1
    IS the verifier's greedy token at the slot that checks it — exact
    acceptance can never commit a token greedy decoding would not."""
    props, logits = _random_verify_case(seed)
    acc = np.asarray(position_accepts(props, logits,
                                      DecodeConfig(criterion="exact")))
    greedy = np.asarray(jnp.argmax(logits, axis=-1))   # (B, k)
    p = np.asarray(props)
    b, k = p.shape
    assert acc[:, 0].all()                              # k̂ >= 1 by contract
    for i in range(b):
        for j in range(1, k):                           # slot j-1 checks j
            assert acc[i, j] == (p[i, j] == greedy[i, j - 1])


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), k_lo=st.integers(1, 6),
       k_hi=st.integers(1, 6))
def test_khat_monotone_under_tightened_topk(seed, k_lo, k_hi):
    """Tightening the §5.1 top-k threshold never grows k̂ (and the
    per-position accepts shrink as a set)."""
    props, logits = _random_verify_case(seed)
    lo, hi = min(k_lo, k_hi), max(k_lo, k_hi)
    rem = jnp.full((4,), 100)
    d_lo = DecodeConfig(criterion="topk", top_k=lo)
    d_hi = DecodeConfig(criterion="topk", top_k=hi)
    acc_lo = np.asarray(position_accepts(props, logits, d_lo))
    acc_hi = np.asarray(position_accepts(props, logits, d_hi))
    assert np.all(~acc_lo | acc_hi)                    # accepts: subset
    khat_lo = np.asarray(accepted_block_size(acc_lo, d_lo, rem))
    khat_hi = np.asarray(accepted_block_size(acc_hi, d_hi, rem))
    assert np.all(khat_lo <= khat_hi)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), e1=st.floats(0.0, 8.0),
       e2=st.floats(0.0, 8.0))
def test_khat_monotone_under_tightened_distance(seed, e1, e2):
    """Tightening the §5.2 distance tolerance never grows k̂."""
    props, logits = _random_verify_case(seed)
    lo, hi = min(e1, e2), max(e1, e2)
    rem = jnp.full((4,), 100)
    d_lo = DecodeConfig(criterion="distance", epsilon=lo)
    d_hi = DecodeConfig(criterion="distance", epsilon=hi)
    acc_lo = np.asarray(position_accepts(props, logits, d_lo))
    acc_hi = np.asarray(position_accepts(props, logits, d_hi))
    assert np.all(~acc_lo | acc_hi)
    khat_lo = np.asarray(accepted_block_size(acc_lo, d_lo, rem))
    khat_hi = np.asarray(accepted_block_size(acc_hi, d_hi, rem))
    assert np.all(khat_lo <= khat_hi)


# ---------------------------------------------------------------------------
# Removed criterion-string shims (repro.core.verify)
# ---------------------------------------------------------------------------


def test_legacy_verify_shims_removed_with_migration_path():
    """The criterion-string entry points (deprecated since the policy
    refactor) are hard errors that name ``config.get_policy`` as the
    blessed path — still importable (so stale call sites fail at the call,
    with the migration, not at import with a bare AttributeError)."""
    from repro.core import verify as legacy

    props = jnp.asarray([[7, 4, 5, 6]])
    logits = _logits_for([[4, 5, 9, 0]])
    dec = DecodeConfig(criterion="exact")
    with pytest.raises(ValueError, match="get_policy"):
        legacy.position_accepts(props, logits, dec)
    with pytest.raises(ValueError, match="get_policy"):
        legacy.accepted_block_size(jnp.ones((1, 4), bool), dec,
                                   jnp.asarray([100]))
    # the package-level re-exports fail the same way
    from repro import core as C
    with pytest.raises(ValueError, match="acceptor.accepts"):
        C.position_accepts(props, logits, dec)
    with pytest.raises(ValueError, match="schedule.block_size"):
        C.accepted_block_size(jnp.ones((1, 4), bool), dec,
                              jnp.asarray([100]))
