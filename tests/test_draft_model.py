"""ModelBundle + draft-model speculative drafting.

The ``draft_model`` policy runs an independent small causal LM (an
auxiliary ``ModelBundle``) that proposes each block autoregressively with
its own loop-carried KV cache inside ``policy_state``; the primary model
verifies.  Slot 0 of every draft is pinned to the verifier's greedy token,
so with exact acceptance the decoded tokens equal ``greedy_decode`` for
ANY draft parameters — including the random ones used here.  Draft quality
moves iteration counts only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_rwkv, tiny_seq2seq
from repro.config import DecodeConfig, ModelConfig
from repro.core import decode as D
from repro.core import policy as P
from repro.core.bundle import ModelBundle
from repro.core.draft import DraftModelDrafter
from repro.models import model as M
from repro.models import seq2seq as S
from repro.serving import ContinuousBatchingEngine, EngineConfig, Request


def draft_config(vocab: int) -> ModelConfig:
    return ModelConfig(name="tiny-draft", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=vocab, bpd_enabled=False,
                       max_seq_len=512, dtype="float32")


@pytest.fixture(scope="module")
def dense_with_draft():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dcfg = draft_config(cfg.vocab_size)
    dparams = M.init(jax.random.PRNGKey(9), dcfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                          cfg.vocab_size)}
    return cfg, params, {"draft": ModelBundle(dparams, dcfg)}, batch


# ---------------------------------------------------------------------------
# Losslessness: draft_model + exact == greedy_decode, token for token
# ---------------------------------------------------------------------------


def test_draft_model_token_identical_to_greedy(dense_with_draft):
    cfg, params, bundles, batch = dense_with_draft
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    greedy_t, greedy_s = D.greedy_decode(params, cfg, dec, batch)
    draft_t, draft_s = D.bpd_decode(params, cfg, dec, batch,
                                    policy="draft_model", bundles=bundles)
    w = batch["tokens"].shape[1] + dec.max_new_tokens  # common buffer width
    np.testing.assert_array_equal(np.asarray(greedy_t[:, :w]),
                                  np.asarray(draft_t[:, :w]))
    np.testing.assert_array_equal(np.asarray(greedy_s["generated"]),
                                  np.asarray(draft_s["generated"]))


def test_draft_model_lossless_seq2seq():
    cfg = tiny_seq2seq()
    params = S.init(jax.random.PRNGKey(2), cfg)
    dcfg = draft_config(cfg.vocab_size)
    dparams = M.init(jax.random.PRNGKey(11), dcfg)
    dec = DecodeConfig(max_new_tokens=10, block_k=4)
    batch = {"src": jax.random.randint(jax.random.PRNGKey(3), (2, 6), 1,
                                       cfg.vocab_size)}
    ref, ref_s = D.bpd_decode_seq2seq(params, cfg, dec, batch)
    out, out_s = D.bpd_decode_seq2seq(
        params, cfg, dec, batch, policy="draft_model",
        bundles={"draft": ModelBundle(dparams, dcfg)})
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(ref_s["generated"]),
                                  np.asarray(out_s["generated"]))


def test_good_draft_model_cuts_iterations(dense_with_draft):
    """A draft model that IS the verifier proposes exactly the verifier's
    greedy continuation, so every block verifies fully: iterations drop to
    ~max_new / block_k while the tokens stay identical (the speculative
    speedup the bundle seam exists for)."""
    cfg, params, _, batch = dense_with_draft
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    ref_t, ref_s = D.bpd_decode(params, cfg, dec, batch)
    t, s = D.bpd_decode(params, cfg, dec, batch, policy="draft_model",
                        bundles={"draft": ModelBundle(params, cfg)})
    np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(t))
    assert int(s["iterations"]) == -(-12 // 4)  # ceil(max_new / block_k)
    assert float(s["mean_accepted"]) >= 4.0 - 1e-6


# ---------------------------------------------------------------------------
# Serving engine: admission prefill + per-slot draft cache lifecycle
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_engine_draft_model_matches_run_to_completion(dense_with_draft):
    cfg, params, bundles, _ = dense_with_draft
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=2, max_prompt_len=6,
                                       max_new_cap=12),
        policy="draft_model", bundles=bundles)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(3)]
    done = []
    for i, p in enumerate(prompts):
        while not eng.free_slots():     # third request waits for an eviction
            done += eng.step()
        eng.admit(Request(rid=i, prompt=p, max_new=12))
        if i == 1:
            done += eng.step()          # mid-flight progress between admits
    while eng.has_active():
        done += eng.step()
    assert len(done) == 3
    for f in done:
        ref_t, ref_s = D.bpd_decode(
            params, cfg, dec, {"tokens": jnp.asarray(prompts[f.rid])[None]},
            policy="draft_model", bundles=bundles)
        n = int(ref_s["text_len"][0])
        np.testing.assert_array_equal(f.tokens, np.asarray(ref_t[0, 6:n]))
    assert all(v == 1 for v in eng.compile_counts().values())


@pytest.mark.serving
def test_engine_rejects_recurrent_aux_bundle(dense_with_draft):
    """The drafter's own bind rejects recurrent DRAFT bundles everywhere
    (see test_bind_validates_draft_config); the engine additionally rejects
    ANY recurrent auxiliary bundle, since its padded admission prefill is
    KV-only sound."""
    cfg, params, _, _ = dense_with_draft
    rcfg = tiny_rwkv(vocab_size=cfg.vocab_size)
    rparams = M.init(jax.random.PRNGKey(5), rcfg)
    with pytest.raises(NotImplementedError, match="padded admission"):
        ContinuousBatchingEngine(
            params, cfg, DecodeConfig(max_new_tokens=8, block_k=4),
            EngineConfig(num_slots=2, max_prompt_len=6, max_new_cap=8),
            bundles={"aux": ModelBundle(rparams, rcfg)})


# ---------------------------------------------------------------------------
# Bundle binding + validation
# ---------------------------------------------------------------------------


def test_draft_model_unbound_raises(dense_with_draft):
    cfg, params, _, batch = dense_with_draft
    dec = DecodeConfig(max_new_tokens=8, block_k=4)
    with pytest.raises(ValueError, match="ModelBundle"):
        D.bpd_decode(params, cfg, dec, batch, policy="draft_model")


def test_bind_validates_draft_config(dense_with_draft):
    cfg, params, _, _ = dense_with_draft
    drafter = DraftModelDrafter()
    dcfg = draft_config(cfg.vocab_size)
    dparams = M.init(jax.random.PRNGKey(4), dcfg)

    bad_vocab = ModelBundle(dparams, dcfg.replace(vocab_size=13))
    with pytest.raises(ValueError, match="vocab_size"):
        drafter.bind({"draft": bad_vocab}, cfg)

    rcfg = tiny_rwkv(vocab_size=cfg.vocab_size)
    with pytest.raises(NotImplementedError, match="recurrent"):
        drafter.bind({"draft": ModelBundle(None, rcfg)}, cfg)

    s2s = tiny_seq2seq(vocab_size=cfg.vocab_size)
    with pytest.raises(ValueError, match="decoder-only"):
        drafter.bind({"draft": ModelBundle(None, s2s)}, cfg)

    bound = drafter.bind({"draft": ModelBundle(dparams, dcfg)}, cfg)
    assert bound.cfg == dcfg


def test_session_policy_mismatch_guard(dense_with_draft):
    """A session fixes its bundles at construction; the wrappers reject
    late bundles and policy mismatches instead of silently re-binding."""
    from repro.serving import DecodeSession

    cfg, params, bundles, batch = dense_with_draft
    dec = DecodeConfig(max_new_tokens=8, block_k=4)
    sess = DecodeSession(params, cfg, dec, policy="draft_model",
                         bundles=bundles)
    with pytest.raises(ValueError, match="fixed at DecodeSession"):
        D.bpd_decode(params, cfg, dec, batch, session=sess, bundles=bundles)
    # same policy name through the session resolves to the bound policy
    t1, _ = D.bpd_decode(params, cfg, dec, batch, session=sess,
                         policy="draft_model")
    t2, _ = D.bpd_decode(params, cfg, dec, batch, policy="draft_model",
                         bundles=bundles)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_draft_cache_state_is_batch_leading(dense_with_draft):
    """The drafter's loop state honours the policy-state contract (batch-
    leading leaves), so state_specs/slot_specs can shard and the engine can
    reset/scatter single rows."""
    cfg, params, bundles, batch = dense_with_draft
    dec = DecodeConfig(max_new_tokens=8, block_k=4)
    pol = P.resolve_policy(dec, "draft_model").bind(bundles, cfg)
    b = batch["tokens"].shape[0]
    state = pol.init_state(cfg, dec, batch, b,
                           aux={"draft": bundles["draft"].params})
    for leaf in jax.tree_util.tree_leaves(state.drafter):
        assert leaf.ndim >= 1 and leaf.shape[0] == b, leaf.shape
