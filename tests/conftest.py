"""Shared tiny-model fixtures.  Tests run on 1 CPU device (the 512-device
XLA_FLAGS override is set only inside repro.launch.dryrun)."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.config import DecodeConfig, ModelConfig


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long property-based tests (CI runs -m 'not slow' "
        "per push; the full suite runs nightly)")
    config.addinivalue_line(
        "markers", "serving: continuous-batching serving engine tests")
    config.addinivalue_line(
        "markers", "sharded: host-mesh sharded decode tests (need "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8; skip on "
        "1-device hosts)")


def tiny_dense(**kw) -> ModelConfig:
    base = dict(name="tiny-dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=97, bpd_k=4,
                max_seq_len=512, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def tiny_moe(**kw) -> ModelConfig:
    base = dict(name="tiny-moe", family="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97,
                mlp_type="moe", num_experts=4, num_experts_per_tok=2,
                num_shared_experts=1, shared_expert_d_ff=64, bpd_k=4,
                max_seq_len=512, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def tiny_rwkv(**kw) -> ModelConfig:
    base = dict(name="tiny-rwkv", family="ssm", num_layers=2, d_model=64,
                block_type="rwkv6", mlp_type="rwkv_channel_mix",
                rwkv_head_dim=32, d_ff=128, vocab_size=97, bpd_k=4,
                max_seq_len=512, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def tiny_hymba(**kw) -> ModelConfig:
    base = dict(name="tiny-hymba", family="hybrid", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, block_type="hymba", d_ff=128,
                vocab_size=97, bpd_k=4, ssm_state_dim=8, num_meta_tokens=4,
                sliding_window=32, global_attn_layers=(0,),
                max_seq_len=512, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def tiny_seq2seq(**kw) -> ModelConfig:
    base = dict(name="tiny-s2s", family="seq2seq", is_encoder_decoder=True,
                num_encoder_layers=2, num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=4, d_ff=128, vocab_size=97, bpd_k=4,
                max_seq_len=512, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


FAMILY_CONFIGS = {
    "dense": tiny_dense,
    "moe": tiny_moe,
    "rwkv6": tiny_rwkv,
    "hymba": tiny_hymba,
}


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_tokens(key, cfg: ModelConfig, b: int, s: int):
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)
