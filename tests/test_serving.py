"""Continuous-batching serving engine: token-level equivalence with the
run-to-completion decoder, slot reuse, mid-flight admission, and the
compile-once guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_rwkv
from repro.config import DecodeConfig
from repro.core import decode as D
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    EngineConfig,
    Request,
    Scheduler,
    aggregate_stats,
)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def served():
    """One trafficked engine shared by the assertions below: 7 mixed-length
    requests through 3 slots (forcing eviction + re-admission)."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dec = DecodeConfig(max_new_tokens=24, block_k=4, eos_id=3)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=3, max_prompt_len=10,
                                       max_new_cap=24))
    sched = Scheduler(eng)
    rng = np.random.default_rng(0)
    reqs = {}
    for i in range(7):
        p = rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 11)))
        reqs[i] = Request(rid=i, prompt=p,
                          max_new=int(rng.integers(4, 25)))
        sched.submit(reqs[i])
    finished = sched.run()
    return params, cfg, dec, eng, reqs, finished


def _reference(params, cfg, dec, prompt, max_new):
    d1 = dec.replace(max_new_tokens=max_new)
    bt, bs = D.bpd_decode(params, cfg, d1, {"tokens": jnp.asarray(prompt)[None]})
    n = int(bs["text_len"][0])
    return np.asarray(bt[0, len(prompt):n])


def test_engine_matches_bpd_decode_per_request(served):
    """Every request's engine output equals its own run-to-completion
    bpd_decode — continuous batching is a scheduling change, not a
    decoding change."""
    params, cfg, dec, _, reqs, finished = served
    assert len(finished) == 7
    for f in finished:
        ref = _reference(params, cfg, dec, reqs[f.rid].prompt,
                         min(reqs[f.rid].max_new, 24))
        np.testing.assert_array_equal(f.tokens, ref)
        assert f.generated == len(ref)


def test_compile_once_under_traffic(served):
    """Admission/step/evict never recompile: static shapes by design."""
    *_, eng, _, _ = served
    assert all(v == 1 for v in eng.compile_counts().values()), \
        eng.compile_counts()


def test_slots_fully_recycled(served):
    """After draining, every slot is free and holds no *visible* KV entry.

    Eviction sets pos = -1; later steps may speculatively write the frozen
    block positions [text_len, text_len + k) into inactive rows — those are
    masked out by the visibility rule (pos >= length + k is stale once
    length rolls back to 0 on admission, which rewrites the row wholesale),
    so the invariant is: every entry is -1 or inside that frozen block.
    """
    *_, eng, _, _ = served
    assert eng.free_slots() == [0, 1, 2]
    text_len = eng.state.text_len[:, None]
    for layer in eng.state.caches:
        pos = layer["attn"]["pos"]
        ok = (pos == -1) | ((pos >= text_len) &
                            (pos < text_len + eng.block_k))
        assert bool(jnp.all(ok))


def test_per_request_stats(served):
    *_, finished = served
    stats = aggregate_stats(finished, wall_seconds=1.0)
    assert stats["requests"] == 7
    assert stats["total_tokens"] == sum(f.generated for f in finished)
    assert stats["mean_accepted"] >= 1.0
    assert stats["latency_p95_s"] >= stats["latency_p50_s"] >= 0.0
    for f in finished:
        assert f.invocations >= 2          # prefill + ≥1 iteration
        assert 0 < f.generated <= 24


def test_midflight_admission_is_equivalent():
    """A request admitted while another slot is mid-decode produces the
    same tokens as decoding it alone — slots are fully isolated."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(1), cfg)
    dec = DecodeConfig(max_new_tokens=16, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=2, max_prompt_len=8,
                                       max_new_cap=16))
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab_size, size=8)
    p1 = rng.integers(0, cfg.vocab_size, size=5)
    eng.admit(Request(rid=0, prompt=p0, max_new=16))
    done = []
    for _ in range(3):                      # progress request 0 first
        done += eng.step()
    eng.admit(Request(rid=1, prompt=p1, max_new=10))
    while eng.has_active():
        done += eng.step()
    by_rid = {f.rid: f for f in done}
    np.testing.assert_array_equal(by_rid[0].tokens,
                                  _reference(params, cfg, dec, p0, 16))
    np.testing.assert_array_equal(by_rid[1].tokens,
                                  _reference(params, cfg, dec, p1, 10))


def test_sjf_policy_prefers_short_jobs():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(2), cfg)
    dec = DecodeConfig(max_new_tokens=16, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=1, max_prompt_len=6,
                                       max_new_cap=16))
    sched = Scheduler(eng, policy="sjf")
    rng = np.random.default_rng(5)
    for rid, mn in [(0, 16), (1, 2), (2, 8)]:
        sched.submit(Request(rid=rid, max_new=mn,
                             prompt=rng.integers(0, cfg.vocab_size, size=4)))
    finished = sched.run()
    # single slot: admission order == finish order == ascending max_new
    assert [f.rid for f in finished] == [1, 2, 0]


def test_admission_guards():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dec = DecodeConfig(max_new_tokens=8, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=1, max_prompt_len=4,
                                       max_new_cap=8))
    with pytest.raises(ValueError):
        eng.admit(Request(rid=0, prompt=np.zeros(9, np.int32), max_new=4))
    # the scheduler rejects at submit time, before the serving loop,
    # so one bad request can never abort a mid-flight drain
    sched = Scheduler(eng)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=3, prompt=np.zeros(9, np.int32), max_new=4))
    assert not sched.queue
    eng.admit(Request(rid=1, prompt=np.zeros(3, np.int32), max_new=4))
    with pytest.raises(RuntimeError):
        eng.admit(Request(rid=2, prompt=np.zeros(3, np.int32), max_new=4))


def test_recurrent_families_are_gated():
    """Padded-prompt prefill is unsound for recurrent state — the engine
    must refuse rather than silently serve wrong tokens."""
    cfg = tiny_rwkv()
    params = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(params, cfg, DecodeConfig(), EngineConfig())


@pytest.mark.parametrize("groups", [
    {"exact": 1, "adaptive": 2},                      # 2-policy mix
    {"exact": 1, "topk": 1, "adaptive": 2},           # 3-policy mix
])
def test_host_syncs_count_group_steps_not_members(groups):
    """``num_host_syncs`` accounting under policy slot grouping: one fused
    sync per GROUP STEP — never one per slot group member, and idle groups
    cost nothing.  The bound for N engine steps with g active groups is
    exactly N * g (+ one harvest pull per finishing group at the end)."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(8), cfg)
    dec = DecodeConfig(max_new_tokens=24, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec,
        EngineConfig(num_slots=sum(groups.values()), max_prompt_len=6,
                     max_new_cap=24), policies=groups)
    rng = np.random.default_rng(13)
    mk = lambda rid, pol: Request(  # noqa: E731
        rid=rid, policy=pol, max_new=24,
        prompt=rng.integers(0, cfg.vocab_size, size=6))

    # phase 1: only the multi-slot 'adaptive' group is active — with BOTH
    # of its slots occupied, so per-member accounting would double-count
    eng.admit(mk(0, "adaptive"))
    eng.admit(mk(1, "adaptive"))
    before = eng.num_host_syncs
    for _ in range(2):
        assert not eng.step()
    assert eng.num_host_syncs - before == 2      # 2 steps x 1 active group

    # phase 2: one request per remaining group — every group active
    for i, name in enumerate(n for n in groups if n != "adaptive"):
        eng.admit(mk(2 + i, name))
    before = eng.num_host_syncs
    for _ in range(2):
        assert not eng.step()
    assert eng.num_host_syncs - before == 2 * len(groups)

    # host-cache reads never sync
    eng.free_slots(), eng.has_active()
    assert eng.num_host_syncs - before == 2 * len(groups)

    # drain: every step syncs once per active group; a harvesting step
    # adds exactly one pull per group with >= 1 finishing request (two
    # requests finishing together in one group still cost ONE pull)
    before, steps, pulls = eng.num_host_syncs, 0, 0
    finished = []
    while eng.has_active():
        active = sum(1 for g in eng.groups if np.any(g.status & 1))
        done = eng.step()
        steps += active
        pulls += len({f.policy for f in done})
        finished += done
    assert len(finished) == 2 + (len(groups) - 1)
    assert eng.num_host_syncs - before == steps + pulls


def test_bpd_iteration_active_mask_freezes_rows():
    """Direct unit check of the decode.py refactor: an inactive row accepts
    nothing and keeps its state bit-for-bit."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(4), cfg)
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (3, 6), 0,
                                          cfg.vocab_size)}
    state, prefix = D.bpd_prefill_causal_lm(params, cfg, dec, batch,
                                            max_new=12)
    be = D.causal_lm_backend(cfg)
    active = jnp.asarray([True, False, True])
    out = D.bpd_iteration(params, cfg, dec, be, state, prefix_offset=prefix,
                          max_new=jnp.full((3,), 12, jnp.int32),
                          active=active)
    assert int(out.generated[1]) == 0
    assert int(out.text_len[1]) == int(state.text_len[1])
    np.testing.assert_array_equal(np.asarray(out.tokens[1]),
                                  np.asarray(state.tokens[1]))
    np.testing.assert_array_equal(np.asarray(out.proposals[1]),
                                  np.asarray(state.proposals[1]))
    assert int(out.generated[0]) >= 1 and int(out.generated[2]) >= 1


def test_bpd_decode_per_row_budgets():
    """bpd_decode honors per-row max_new_rows (static-batch baseline)."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(6), cfg)
    dec = DecodeConfig(max_new_tokens=16, block_k=4)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (3, 5), 0,
                                          cfg.vocab_size)}
    budgets = jnp.asarray([3, 16, 9], jnp.int32)
    _, stats = D.bpd_decode(params, cfg, dec, batch, max_new_rows=budgets)
    np.testing.assert_array_equal(np.asarray(stats["generated"]),
                                  np.asarray(budgets))
