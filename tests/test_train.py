"""Training behaviour: paper §6 mechanics (random sub-loss, freezing) and
learnability of the synthetic tasks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_seq2seq
from repro.config import TrainConfig
from repro.core.train import lm_loss, seq2seq_loss
from repro.data.synthetic import CipherMT, MarkovLM
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import freeze_mask, optimizer_init
from repro.utils.tree import tree_map_with_name


def _train(cfg, tc, batches, n_steps, seed=0, mask=None):
    params = (S.init if cfg.is_encoder_decoder else M.init)(
        jax.random.PRNGKey(seed), cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc, mask=mask))
    key = jax.random.PRNGKey(seed + 1)
    losses = []
    p0 = params
    for i in range(n_steps):
        key, sub = jax.random.split(key)
        batch = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step(params, opt, batch, sub)
        losses.append(float(metrics["loss"]))
    return p0, params, losses


def test_lm_loss_decreases_on_markov_data():
    # small vocab: 32^2 contexts are learnable within ~30k training tokens
    cfg = tiny_dense(bpd_k=2, vocab_size=32)
    tc = TrainConfig(global_batch=8, seq_len=32, lr=3e-3, warmup_steps=10,
                     head_loss="random")
    task = MarkovLM(vocab=cfg.vocab_size, temperature=0.15)
    _, _, losses = _train(cfg, tc, task.batches(batch=8, seq_len=32), 120)
    assert np.mean(losses[-10:]) < 0.85 * np.mean(losses[:5])


def test_seq2seq_loss_decreases_on_cipher():
    cfg = tiny_seq2seq(bpd_k=2)
    tc = TrainConfig(global_batch=8, seq_len=12, lr=3e-3, warmup_steps=10,
                     head_loss="random")
    task = CipherMT(vocab=cfg.vocab_size)
    _, _, losses = _train(cfg, tc, task.batches(batch=8, src_len=12), 120)
    assert np.mean(losses[-10:]) < 0.9 * np.mean(losses[:5])


def test_freeze_base_moves_only_heads():
    """§6.1 frozen training: only bpd_heads parameters may change."""
    cfg = tiny_dense()
    tc = TrainConfig(global_batch=4, seq_len=16, lr=1e-2, freeze_base=True,
                     head_loss="random")
    mask = None  # make_train_step gets the mask explicitly
    task = MarkovLM(vocab=cfg.vocab_size)
    params0 = M.init(jax.random.PRNGKey(0), cfg)
    fm = freeze_mask(params0, train_only_heads=True)
    p0, p1, _ = _train(cfg, tc, task.batches(batch=4, seq_len=16), 5, mask=fm)

    def delta(name, a, b):
        return name, float(jnp.sum(jnp.abs(a - b)))

    diffs = tree_map_with_name(lambda n, x: x, jax.tree_util.tree_map(
        lambda a, b: jnp.sum(jnp.abs(a - b)), p0, p1))
    flat = {}

    def visit(path, x):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = float(x)

    jax.tree_util.tree_map_with_path(visit, diffs)
    head_moved = sum(v for k, v in flat.items() if k.startswith("bpd_heads"))
    base_moved = sum(v for k, v in flat.items() if not k.startswith("bpd_heads"))
    assert head_moved > 0
    assert base_moved == 0.0


def test_random_subloss_is_unbiased_sample_of_heads():
    """The random-head loss evaluated at each head index equals the
    corresponding term of the mean loss."""
    cfg = tiny_dense(bpd_k=3)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0,
                                          cfg.vocab_size)}
    tc_mean = TrainConfig(head_loss="mean", z_loss=0.0)
    loss_mean, _ = lm_loss(params, cfg, tc_mean, batch, jax.random.PRNGKey(2))

    # brute-force per-head losses via fixed keys that sample each index
    tc_rand = TrainConfig(head_loss="random", z_loss=0.0)
    per_head = {}
    key = jax.random.PRNGKey(0)
    tries = 0
    while len(per_head) < cfg.bpd_k and tries < 200:
        key, sub = jax.random.split(key)
        loss, m = lm_loss(params, cfg, tc_rand, batch, sub)
        per_head[int(m["head_idx"])] = float(loss)
        tries += 1
    assert len(per_head) == cfg.bpd_k
    np.testing.assert_allclose(np.mean(list(per_head.values())),
                               float(loss_mean), rtol=1e-5)


def test_gradient_flows_through_all_heads_mean_loss():
    cfg = tiny_dense(bpd_k=3)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    tc = TrainConfig(head_loss="mean")
    g = jax.grad(lambda p: lm_loss(p, cfg, tc, batch,
                                   jax.random.PRNGKey(2))[0])(params)
    # w1 grads for heads 1..k-1 must be nonzero (head 0 is identity)
    gn = np.asarray(jnp.sum(jnp.abs(g["bpd_heads"]["w1"]), axis=(0, 2)))
    assert (gn[1:] > 0).all()
