"""Training behaviour: paper §6 mechanics (random sub-loss, freezing) and
learnability of the synthetic tasks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_seq2seq
from repro.config import DecodeConfig, TrainConfig
from repro.core.train import (lm_loss, scheduled_sampling_ratio, seq2seq_loss,
                              ss_mix_lm, ss_mix_seq2seq)
from repro.data.synthetic import CipherMT, MarkovLM
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import freeze_mask, optimizer_init
from repro.utils.tree import tree_map_with_name


def _train(cfg, tc, batches, n_steps, seed=0, mask=None):
    params = (S.init if cfg.is_encoder_decoder else M.init)(
        jax.random.PRNGKey(seed), cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc, mask=mask))
    key = jax.random.PRNGKey(seed + 1)
    losses = []
    p0 = params
    for i in range(n_steps):
        key, sub = jax.random.split(key)
        batch = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step(params, opt, batch, sub)
        losses.append(float(metrics["loss"]))
    return p0, params, losses


def test_lm_loss_decreases_on_markov_data():
    # small vocab: 32^2 contexts are learnable within ~30k training tokens
    cfg = tiny_dense(bpd_k=2, vocab_size=32)
    tc = TrainConfig(global_batch=8, seq_len=32, lr=3e-3, warmup_steps=10,
                     head_loss="random")
    task = MarkovLM(vocab=cfg.vocab_size, temperature=0.15)
    _, _, losses = _train(cfg, tc, task.batches(batch=8, seq_len=32), 120)
    assert np.mean(losses[-10:]) < 0.85 * np.mean(losses[:5])


def test_seq2seq_loss_decreases_on_cipher():
    cfg = tiny_seq2seq(bpd_k=2)
    tc = TrainConfig(global_batch=8, seq_len=12, lr=3e-3, warmup_steps=10,
                     head_loss="random")
    task = CipherMT(vocab=cfg.vocab_size)
    _, _, losses = _train(cfg, tc, task.batches(batch=8, src_len=12), 120)
    assert np.mean(losses[-10:]) < 0.9 * np.mean(losses[:5])


def test_freeze_base_moves_only_heads():
    """§6.1 frozen training: only bpd_heads parameters may change."""
    cfg = tiny_dense()
    tc = TrainConfig(global_batch=4, seq_len=16, lr=1e-2, freeze_base=True,
                     head_loss="random")
    mask = None  # make_train_step gets the mask explicitly
    task = MarkovLM(vocab=cfg.vocab_size)
    params0 = M.init(jax.random.PRNGKey(0), cfg)
    fm = freeze_mask(params0, train_only_heads=True)
    p0, p1, _ = _train(cfg, tc, task.batches(batch=4, seq_len=16), 5, mask=fm)

    def delta(name, a, b):
        return name, float(jnp.sum(jnp.abs(a - b)))

    diffs = tree_map_with_name(lambda n, x: x, jax.tree_util.tree_map(
        lambda a, b: jnp.sum(jnp.abs(a - b)), p0, p1))
    flat = {}

    def visit(path, x):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = float(x)

    jax.tree_util.tree_map_with_path(visit, diffs)
    head_moved = sum(v for k, v in flat.items() if k.startswith("bpd_heads"))
    base_moved = sum(v for k, v in flat.items() if not k.startswith("bpd_heads"))
    assert head_moved > 0
    assert base_moved == 0.0


def test_random_subloss_is_unbiased_sample_of_heads():
    """The random-head loss evaluated at each head index equals the
    corresponding term of the mean loss."""
    cfg = tiny_dense(bpd_k=3)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0,
                                          cfg.vocab_size)}
    tc_mean = TrainConfig(head_loss="mean", z_loss=0.0)
    loss_mean, _ = lm_loss(params, cfg, tc_mean, batch, jax.random.PRNGKey(2))

    # brute-force per-head losses via fixed keys that sample each index
    tc_rand = TrainConfig(head_loss="random", z_loss=0.0)
    per_head = {}
    key = jax.random.PRNGKey(0)
    tries = 0
    while len(per_head) < cfg.bpd_k and tries < 200:
        key, sub = jax.random.split(key)
        loss, m = lm_loss(params, cfg, tc_rand, batch, sub)
        per_head[int(m["head_idx"])] = float(loss)
        tries += 1
    assert len(per_head) == cfg.bpd_k
    np.testing.assert_allclose(np.mean(list(per_head.values())),
                               float(loss_mean), rtol=1e-5)


# ---------------------------------------------------------------------------
# Parallel scheduled sampling (TrainConfig.scheduled_sampling)
# ---------------------------------------------------------------------------


def test_scheduled_sampling_ratio_anneal():
    """Linear gold->model ramp: 0 at step 0, peak at ss_anneal_steps, flat
    after; constant when ss_anneal_steps=0; identically 0 when disabled."""
    tc = TrainConfig(scheduled_sampling=True, ss_ratio=0.8, ss_anneal_steps=10)
    assert scheduled_sampling_ratio(tc, 0) == 0.0
    assert scheduled_sampling_ratio(tc, 5) == pytest.approx(0.4)
    assert scheduled_sampling_ratio(tc, 10) == pytest.approx(0.8)
    assert scheduled_sampling_ratio(tc, 999) == pytest.approx(0.8)
    const = TrainConfig(scheduled_sampling=True, ss_ratio=0.5)
    assert scheduled_sampling_ratio(const, 0) == 0.5
    assert scheduled_sampling_ratio(const, 100) == 0.5
    off = TrainConfig(ss_ratio=0.5, ss_anneal_steps=10)
    assert scheduled_sampling_ratio(off, 7) == 0.0


def test_ss_mix_lm_deterministic_and_gold_anchored():
    """The mixed batch is a pure function of (params, batch, key, ratio);
    position 0 always stays gold; ratio=0 is the identity; a real ratio
    actually swaps tokens and every swapped token is a model prediction."""
    cfg = tiny_dense(bpd_k=2, vocab_size=32)
    params = M.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    key = jax.random.PRNGKey(3)
    m1 = ss_mix_lm(params, cfg, batch, key, jnp.float32(0.7))
    m2 = ss_mix_lm(params, cfg, batch, key, jnp.float32(0.7))
    np.testing.assert_array_equal(m1, m2)
    np.testing.assert_array_equal(m1[:, 0], tokens[:, 0])
    assert bool((m1 != tokens).any()), "ratio=0.7 swapped nothing"
    m0 = ss_mix_lm(params, cfg, batch, key, jnp.float32(0.0))
    np.testing.assert_array_equal(m0, tokens)


def test_ss_self_targets_swaps_supervision():
    """``ss_self_targets`` supervises with the base's own chain: the
    with_pred stream anchors at the gold first token, shifts the model's
    teacher-forced predictions into positions 1.., and changes the loss
    relative to gold-target scheduled sampling (same params, same key)."""
    cfg = tiny_dense(bpd_k=2, vocab_size=32)
    params = M.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "ss_ratio": jnp.float32(0.5)}
    key = jax.random.PRNGKey(3)
    mixed, model_tok = ss_mix_lm(params, cfg, batch, key, jnp.float32(0.5),
                                 with_pred=True)
    np.testing.assert_array_equal(
        mixed, ss_mix_lm(params, cfg, batch, key, jnp.float32(0.5)))
    np.testing.assert_array_equal(model_tok[:, 0], tokens[:, 0])
    assert model_tok.shape == tokens.shape
    assert bool((model_tok != tokens).any()), (
        "untrained base reproduced the random gold stream exactly")
    tc = TrainConfig(scheduled_sampling=True, ss_ratio=0.5, head_loss="mean",
                     freeze_base=True)
    loss_gold, _ = lm_loss(params, cfg, tc, batch, key)
    tc_self = tc.replace(ss_self_targets=True)
    loss_self, _ = lm_loss(params, cfg, tc_self, batch, key)
    assert not np.isclose(float(loss_gold), float(loss_self)), (
        "self-targets did not change the training signal")


def test_ss_mix_seq2seq_bos_anchored():
    cfg = tiny_seq2seq(bpd_k=2)
    params = S.init(jax.random.PRNGKey(0), cfg)
    src = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                             cfg.vocab_size)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0,
                             cfg.vocab_size)
    batch = {"src": src, "tgt": tgt}
    key = jax.random.PRNGKey(3)
    m1 = ss_mix_seq2seq(params, cfg, batch, key, jnp.float32(0.7))
    np.testing.assert_array_equal(
        m1, ss_mix_seq2seq(params, cfg, batch, key, jnp.float32(0.7)))
    assert bool((m1[:, 0] == 0).all()), "BOS slot must stay gold"
    gold_in = jnp.concatenate([jnp.zeros((4, 1), tgt.dtype), tgt[:, :-1]], 1)
    np.testing.assert_array_equal(
        ss_mix_seq2seq(params, cfg, batch, key, jnp.float32(0.0)), gold_in)
    assert bool((m1 != gold_in).any())


def test_lm_loss_decreases_under_scheduled_sampling():
    """Training with the SS mixed prefix still learns the Markov task —
    the no-grad mixing forward must not detach the loss from the data."""
    cfg = tiny_dense(bpd_k=2, vocab_size=32)
    tc = TrainConfig(global_batch=8, seq_len=32, lr=3e-3, warmup_steps=10,
                     head_loss="mean", scheduled_sampling=True, ss_ratio=0.3)
    task = MarkovLM(vocab=cfg.vocab_size, temperature=0.15)
    _, _, losses = _train(cfg, tc, task.batches(batch=8, seq_len=32), 120)
    assert np.mean(losses[-10:]) < 0.85 * np.mean(losses[:5])


def test_train_config_validation():
    """Unknown head_loss used to fall through silently to the mean branch;
    now every invalid knob fails loudly at construction, naming the valid
    choices (satellite regression for the head_loss fall-through bug)."""
    with pytest.raises(ValueError, match="head_loss.*random.*mean"):
        TrainConfig(head_loss="banana")
    with pytest.raises(ValueError, match="ss_ratio"):
        TrainConfig(ss_ratio=1.5)
    with pytest.raises(ValueError, match="ss_anneal_steps"):
        TrainConfig(ss_anneal_steps=-3)


# ---------------------------------------------------------------------------
# Sequence-level distillation geometry (core.distill regression)
# ---------------------------------------------------------------------------


def test_distill_lm_batches_rejects_short_decode():
    """Regression: prompt_len + max_new < batch width used to slice
    zero-initialized decode-buffer padding into the distillation targets;
    the geometry is now validated up front."""
    from repro.core.distill import distill_lm_batches

    cfg = tiny_dense(bpd_k=1, vocab_size=32, bpd_enabled=False)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    with pytest.raises(ValueError, match="cannot fill the stream"):
        distill_lm_batches(params, cfg, [batch], prompt_len=4, max_new=4)
    with pytest.raises(ValueError, match="no positions to distill"):
        distill_lm_batches(params, cfg, [batch], prompt_len=12, max_new=4)
    # valid geometry: prompts preserved, continuation is the teacher's
    out = distill_lm_batches(params, cfg, [batch], prompt_len=4, max_new=8)
    assert out[0]["tokens"].shape == batch["tokens"].shape
    np.testing.assert_array_equal(np.asarray(out[0]["tokens"][:, :4]),
                                  np.asarray(batch["tokens"][:, :4]))


def test_gradient_flows_through_all_heads_mean_loss():
    cfg = tiny_dense(bpd_k=3)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    tc = TrainConfig(head_loss="mean")
    g = jax.grad(lambda p: lm_loss(p, cfg, tc, batch,
                                   jax.random.PRNGKey(2))[0])(params)
    # w1 grads for heads 1..k-1 must be nonzero (head 0 is identity)
    gn = np.asarray(jnp.sum(jnp.abs(g["bpd_heads"]["w1"]), axis=(0, 2)))
    assert (gn[1:] > 0).all()
