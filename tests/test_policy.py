"""DecodePolicy API: acceptance semantics, schedule properties, drafter
losslessness, legacy criterion-string equivalence, and the serving engine's
per-slot policy-state lifecycle + single-sync step loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import tiny_dense, tiny_seq2seq
from repro.config import DecodeConfig, get_policy, list_policies
from repro.core import decode as D
from repro.core import policy as P
from repro.models import model as M
from repro.models import seq2seq as S
from repro.serving import ContinuousBatchingEngine, EngineConfig, Request

I32 = jnp.int32


# ---------------------------------------------------------------------------
# Acceptor semantics (property tests)
# ---------------------------------------------------------------------------


def _random_case(seed, b=4, k=5, vocab=13):
    rng = np.random.default_rng(seed)
    props = jnp.asarray(rng.integers(0, vocab, (b, k)), I32)
    logits = jnp.asarray(rng.normal(size=(b, k, vocab)), jnp.float32)
    return props, logits


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), top_k=st.integers(1, 5))
def test_exact_accepts_subset_of_topk(seed, top_k):
    """Every exact-accepted position is top-k-accepted (any k >= 1), so
    exact-accepted prefixes are a subset of top-k-accepted prefixes."""
    props, logits = _random_case(seed)
    exact = P.ExactAcceptor().accepts(props, logits)
    topk = P.TopKAcceptor(top_k=top_k).accepts(props, logits)
    assert bool(jnp.all(~exact | topk))
    # prefix lengths inherit the ordering
    khat_e, _ = P.StaticSchedule().block_size(exact, jnp.full((4,), 99), ())
    khat_t, _ = P.StaticSchedule().block_size(topk, jnp.full((4,), 99), ())
    assert bool(jnp.all(khat_e <= khat_t))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), m1=st.integers(1, 6), m2=st.integers(1, 6),
       remaining=st.integers(1, 8))
def test_khat_monotone_in_min_block_and_clamped(seed, m1, m2, remaining):
    """k̂ is monotone in min_block, always in [1, k], and clamped by the
    remaining budget."""
    rng = np.random.default_rng(seed)
    k = 5
    accepts = jnp.asarray(rng.random((3, k)) < 0.5).at[:, 0].set(True)
    rem = jnp.full((3,), remaining, I32)
    lo, hi = min(m1, m2), max(m1, m2)
    khat_lo, _ = P.StaticSchedule(min_block=lo).block_size(accepts, rem, ())
    khat_hi, _ = P.StaticSchedule(min_block=hi).block_size(accepts, rem, ())
    assert bool(jnp.all(khat_lo <= khat_hi))
    for khat in (khat_lo, khat_hi):
        assert bool(jnp.all(khat >= 1))
        assert bool(jnp.all(khat <= max(remaining, 1)))
        assert bool(jnp.all(khat <= k))


def test_exact_acceptor_matches_legacy_semantics():
    """Acceptor objects reproduce the seed position_accepts semantics."""
    props = jnp.asarray([[7, 4, 5, 6]])
    logits = np.zeros((1, 4, 11), np.float32)
    for j, g in enumerate([4, 5, 9, 0]):
        logits[0, j, g] = 5.0
    acc = P.ExactAcceptor().accepts(props, jnp.asarray(logits))
    np.testing.assert_array_equal(np.asarray(acc),
                                  [[True, True, True, False]])


# ---------------------------------------------------------------------------
# Adaptive schedule
# ---------------------------------------------------------------------------


def test_adaptive_schedule_cap_tracks_acceptance():
    sched = P.AdaptiveSchedule(decay=0.5, grow=0.8, shrink=0.4)
    b, k = 2, 6
    state = sched.init_state(b)
    rem = jnp.full((b,), 99, I32)
    none = jnp.zeros((b, k), bool).at[:, 0].set(True)   # accept nothing extra
    allacc = jnp.ones((b, k), bool)
    # sustained rejection shrinks the cap (it keeps probing upward from 1,
    # so the equilibrium is small but not pinned at exactly 1)
    for _ in range(12):
        khat, state = sched.block_size(none, rem, state)
        assert bool(jnp.all(khat >= 1)) and bool(jnp.all(khat <= k))
    assert int(jnp.max(state["cap"])) <= 2
    # sustained acceptance grows it back to the full block
    for _ in range(30):
        khat, state = sched.block_size(allacc, rem, state)
    assert int(jnp.min(state["cap"])) == k
    khat, _ = sched.block_size(allacc, rem, state)
    assert bool(jnp.all(khat == k))


def test_adaptive_cap_shrinks_then_recovers_stepwise():
    """Deterministic cap dynamics, asserted step-by-step: sustained
    rejection walks the k̂-driven cap down toward min_block, sustained
    acceptance walks it back up to the full block — each step checked
    against an independent float32 replica of the documented controller
    (EMA of accepted/cap; cap +1 above ``grow``, -1 below ``shrink``)."""
    k, rem = 4, jnp.full((1,), 99, I32)
    sched = P.AdaptiveSchedule(min_block=1, decay=0.5, grow=0.8, shrink=0.45)
    state = sched.init_state(1)
    reject = jnp.zeros((1, k), bool).at[:, 0].set(True)   # prefix = 1
    accept = jnp.ones((1, k), bool)                       # prefix = k
    phases = [(reject, 8), (accept, 10)]

    rate, cap = np.float32(1.0), k          # replica state (cap pre-clip)
    caps, khats = [], []
    for accepts, steps in phases:
        prefix = 1 if accepts is reject else k
        for _ in range(steps):
            khat, state = sched.block_size(accepts, rem, state)
            cap = min(max(cap, 1), k)                      # clip into [1, k]
            accepted = min(max(prefix, 1), cap)
            want_khat = min(accepted, 99)
            rate = np.float32(rate * np.float32(0.5)
                              + np.float32(0.5) * np.float32(accepted)
                              / np.float32(cap))
            if rate >= np.float32(0.8):
                cap = min(cap + 1, k)
            elif rate <= np.float32(0.45):
                cap = max(cap - 1, 1)
            assert int(khat[0]) == want_khat, (len(khats), khat, want_khat)
            assert int(state["cap"][0]) == cap, (len(caps), state, cap)
            assert np.float32(state["rate"][0]) == pytest.approx(rate,
                                                                 abs=1e-6)
            caps.append(int(state["cap"][0]))
            khats.append(int(khat[0]))

    # milestones: the rejection phase shrank the cap to (near) min_block,
    # and the acceptance phase recovered it to the full block
    assert min(caps[:8]) <= 2, caps
    assert caps[8:].count(k) >= 1 and caps[-1] == k, caps
    assert khats[-1] == k                   # recovered cap re-enables k̂ = k
    # during sustained full acceptance, k̂ is pinned to the (growing) cap:
    # it climbs monotonically back to k instead of jumping there
    recovery = khats[8:]
    assert recovery == sorted(recovery), recovery
    assert recovery[0] < k, recovery        # the shrunk cap really bound k̂


def test_adaptive_rows_are_independent():
    sched = P.AdaptiveSchedule(decay=0.5)
    state = sched.init_state(2)
    rem = jnp.full((2,), 99, I32)
    acc = jnp.stack([jnp.ones((4,), bool),
                     jnp.zeros((4,), bool).at[0].set(True)])
    for _ in range(10):
        _, state = sched.block_size(acc, rem, state)
    assert int(state["cap"][0]) > int(state["cap"][1])


# ---------------------------------------------------------------------------
# Legacy criterion strings == policy objects (token-identical)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_model():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                          cfg.vocab_size)}
    return cfg, params, batch


ACCEPTORS = {"exact": P.ExactAcceptor(),
             "topk": P.TopKAcceptor(top_k=2),
             "distance": P.DistanceAcceptor(epsilon=2.0)}


@pytest.mark.parametrize("criterion", sorted(ACCEPTORS))
def test_criterion_strings_alias_policy_objects(criterion, dense_model):
    """dec.criterion strings, dec.policy names, and hand-built DecodePolicy
    objects all decode token-identically."""
    cfg, params, batch = dense_model
    dec = DecodeConfig(max_new_tokens=12, block_k=4, criterion=criterion,
                       top_k=2, epsilon=2.0)
    ref_t, ref_s = D.bpd_decode(params, cfg, dec, batch)

    by_name_t, by_name_s = D.bpd_decode(
        params, cfg, dec.replace(criterion="exact", policy=criterion), batch)
    obj = P.DecodePolicy(P.HeadsDrafter(), ACCEPTORS[criterion],
                         P.StaticSchedule(), name="hand-built")
    by_obj_t, by_obj_s = D.bpd_decode(params, cfg, dec, batch, policy=obj)

    for t, s in ((by_name_t, by_name_s), (by_obj_t, by_obj_s)):
        np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(t))
        np.testing.assert_array_equal(np.asarray(ref_s["generated"]),
                                      np.asarray(s["generated"]))
        assert int(ref_s["iterations"]) == int(s["iterations"])


def test_resolve_policy_precedence_and_errors():
    dec = DecodeConfig(criterion="topk", policy="exact", top_k=3)
    assert P.resolve_policy(dec).name == "exact"          # policy > criterion
    assert P.resolve_policy(dec, "distance").name == "distance"  # arg wins
    obj = P.DecodePolicy(P.HeadsDrafter(), P.ExactAcceptor(),
                         P.StaticSchedule())
    assert P.resolve_policy(dec, obj) is obj
    with pytest.raises(ValueError, match="unknown decode policy"):
        P.resolve_policy(dec.replace(policy="nope"))
    # config-level resolution used by launchers
    assert get_policy(dec).name == "exact"
    assert {"exact", "topk", "distance", "adaptive", "input_copy",
            "topk_tree"} <= set(list_policies())


# ---------------------------------------------------------------------------
# Drafters: losslessness + draft mechanics
# ---------------------------------------------------------------------------


def test_topk_tree_drafter_is_lossless_causal(dense_model):
    """Changing the drafter never changes tokens under exact acceptance —
    slot 0 stays the verified greedy token, so only iteration counts move."""
    cfg, params, batch = dense_model
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    ref_t, ref_s = D.bpd_decode(params, cfg, dec, batch)
    t, s = D.bpd_decode(params, cfg, dec, batch, policy="topk_tree")
    np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(t))
    np.testing.assert_array_equal(np.asarray(ref_s["text_len"]),
                                  np.asarray(s["text_len"]))


@pytest.mark.parametrize("policy", ["input_copy", "topk_tree", "adaptive"])
def test_new_policies_are_lossless_seq2seq(policy):
    cfg = tiny_seq2seq()
    params = S.init(jax.random.PRNGKey(2), cfg)
    dec = DecodeConfig(max_new_tokens=10, block_k=4)
    batch = {"src": jax.random.randint(jax.random.PRNGKey(3), (2, 6), 1,
                                       cfg.vocab_size)}
    ref, ref_s = D.bpd_decode_seq2seq(params, cfg, dec, batch)
    out, s = D.bpd_decode_seq2seq(params, cfg, dec, batch, policy=policy)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(ref_s["generated"]),
                                  np.asarray(s["generated"]))


def test_input_copy_drafts_source_aligned():
    """Unit check of the draft mechanics: slots >= 1 copy the source at the
    output positions the block covers; slot 0 is the verified greedy."""
    drafter = P.InputCopyDrafter()
    src = jnp.asarray([[10, 11, 12, 13, 14, 15]], I32)
    state = drafter.init_state(None, None, {"src": src}, 1)
    b, k, K, V = 1, 4, 4, 20
    logits = np.full((b, k, K, V), -10.0, np.float32)
    logits[0, 1, 0, 7] = 10.0       # p_1 argmax at accepted slot 1 -> 7
    inputs = P.DraftInputs(
        logits=jnp.asarray(logits), khat=jnp.asarray([2], I32),
        slot=jnp.asarray([1], I32), text_len=jnp.asarray([3], I32),
        old_proposals=jnp.zeros((1, 4), I32))
    props, _ = drafter.draft(inputs, state)
    # text_len=3 -> block covers output indices 2..5 -> src[2..5]; slot 0
    # replaced by the verified token 7
    np.testing.assert_array_equal(np.asarray(props), [[7, 13, 14, 15]])


def test_input_copy_rejects_promptless_paths():
    with pytest.raises(ValueError, match="seq2seq"):
        P.InputCopyDrafter().init_state(None, None, None, 2)
    with pytest.raises(ValueError, match="seq2seq"):
        P.InputCopyDrafter().init_state(None, None, {"tokens": None}, 2)


# ---------------------------------------------------------------------------
# Serving engine: policy threading, per-slot state lifecycle, sync count
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_engine_policy_matches_run_to_completion(dense_model):
    """The engine with a non-default policy serves the same tokens as the
    run-to-completion path under that policy."""
    cfg, params, _ = dense_model
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=2, max_prompt_len=6,
                                       max_new_cap=12), policy="topk_tree")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(3)]
    done = []
    for i, p in enumerate(prompts):
        while not eng.free_slots():     # third request waits for an eviction
            done += eng.step()
        eng.admit(Request(rid=i, prompt=p, max_new=12))
        if i == 1:
            done += eng.step()          # mid-flight progress between admits
    while eng.has_active():
        done += eng.step()
    for f in done:
        ref_t, ref_s = D.bpd_decode(
            params, cfg, dec, {"tokens": jnp.asarray(prompts[f.rid])[None]},
            policy="topk_tree")
        n = int(ref_s["text_len"][0])
        np.testing.assert_array_equal(f.tokens, np.asarray(ref_t[0, 6:n]))


@pytest.mark.serving
def test_engine_resets_policy_state_on_admit_and_evict(dense_model):
    """A freshly admitted request must not inherit the previous occupant's
    schedule state (and evicted slots drop theirs)."""
    cfg, params, _ = dense_model
    dec = DecodeConfig(max_new_tokens=8, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=1, max_prompt_len=6,
                                       max_new_cap=8), policy="adaptive")
    fresh_cap = int(np.asarray(eng.state.policy_state.schedule["cap"])[0])
    rng = np.random.default_rng(9)
    eng.admit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=6),
                      max_new=8))
    done = []
    while eng.has_active():
        done += eng.step()
    assert len(done) == 1
    # the untrained model accepts ~nothing, so request 0 dragged the
    # adaptive cap down; eviction must have reset it
    cap_after = int(np.asarray(eng.state.policy_state.schedule["cap"])[0])
    rate_after = float(np.asarray(eng.state.policy_state.schedule["rate"])[0])
    assert cap_after == fresh_cap
    assert rate_after == 1.0
    eng.admit(Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, size=6),
                      max_new=8))
    cap_admit = int(np.asarray(eng.state.policy_state.schedule["cap"])[0])
    assert cap_admit == fresh_cap


@pytest.mark.serving
def test_engine_step_is_single_host_sync(dense_model):
    """ROADMAP scheduler item: the host loop must round-trip exactly ONE
    device array per step (the fused active/finished status), not one each
    for active and finished — and a no-finish harvest pulls nothing."""
    cfg, params, _ = dense_model
    dec = DecodeConfig(max_new_tokens=16, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=2, max_prompt_len=6,
                                       max_new_cap=16))
    rng = np.random.default_rng(11)
    eng.admit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=6),
                      max_new=16))
    before = eng.num_host_syncs
    n_steps, finished = 0, []
    for _ in range(3):                      # request needs >= 4 iterations
        finished += eng.step()
        n_steps += 1
    assert not finished
    assert eng.num_host_syncs - before == n_steps
    # free_slots / has_active read the host cache — still no extra syncs
    eng.free_slots(), eng.has_active()
    assert eng.num_host_syncs - before == n_steps
    # draining the request costs the per-step sync + one harvest pull
    while eng.has_active():
        finished += eng.step()
        n_steps += 1
    assert len(finished) == 1
    assert eng.num_host_syncs - before == n_steps + 1
