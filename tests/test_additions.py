"""Tests for machinery added during the perf/experiment iterations:
phrase-expansion task, fractional lr masks, detached head residual,
expert padding, mesh-conditional sharding hints."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_dense, tiny_moe
from repro.config import TrainConfig
from repro.core.heads import head_apply_dynamic, heads_init
from repro.data.synthetic import PhraseMT
from repro.models import model as M
from repro.optim import lr_scale_mask, optimizer_init, optimizer_update
from repro.sharding.policy import maybe_shard


def test_phrase_mt_structure():
    task = PhraseMT(vocab=32, expand=3, seed=0)
    src, tgt = task.make_pair(np.random.default_rng(0), 4, 5)
    assert tgt.shape == (4, 15)
    np.testing.assert_array_equal(tgt, task.gold(src))
    # every source token always expands to the same phrase
    src2 = np.tile(src[:1], (2, 1))
    t2 = task.gold(src2)
    np.testing.assert_array_equal(t2[0], t2[1])
    assert (tgt > 0).all() and (tgt < 32).all()


def test_lr_scale_mask_scales_updates():
    params = {"bpd_heads": {"w": jnp.zeros(3)}, "trunk": {"w": jnp.zeros(3)}}
    tc = TrainConfig(lr=1.0, warmup_steps=1, schedule="constant",
                     weight_decay=0.0, grad_clip=0.0)
    mask = lr_scale_mask(params, trunk_scale=0.25)
    opt = optimizer_init(params, tc)
    g = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), params)
    p2, _, _ = optimizer_update(g, opt, params, tc, mask=mask)
    head_step = float(jnp.abs(p2["bpd_heads"]["w"][0]))
    trunk_step = float(jnp.abs(p2["trunk"]["w"][0]))
    np.testing.assert_allclose(trunk_step, 0.25 * head_step, rtol=1e-5)


def test_detach_residual_preserves_values():
    cfg = tiny_dense(bpd_k=3)
    p = heads_init(jax.random.PRNGKey(0), cfg)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model))
    for idx in (0, 1, 2):
        a = head_apply_dynamic(p, cfg, hidden, jnp.asarray(idx),
                               detach_residual=False)
        b = head_apply_dynamic(p, cfg, hidden, jnp.asarray(idx),
                               detach_residual=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_detach_residual_blocks_gradient_path():
    cfg = tiny_dense(bpd_k=2)
    p = heads_init(jax.random.PRNGKey(0), cfg)
    # zero the head FFN so the ONLY gradient path to hidden is the residual
    p = dict(p, w1=jnp.zeros_like(p["w1"]), w2=jnp.zeros_like(p["w2"]))
    hidden = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model))

    def loss(h, detach):
        out = head_apply_dynamic(p, cfg, h, jnp.asarray(1),
                                 detach_residual=detach)
        return jnp.sum(out ** 2)

    g_res = jax.grad(lambda h: loss(h, False))(hidden)
    g_det = jax.grad(lambda h: loss(h, True))(hidden)
    assert float(jnp.sum(jnp.abs(g_res))) > 0
    assert float(jnp.sum(jnp.abs(g_det))) == 0.0


def test_expert_padding_never_selected():
    cfg = tiny_moe(num_experts=3, num_experts_per_tok=2,
                   expert_pad_multiple=4)
    assert cfg.padded_num_experts == 4
    from repro.models.moe import moe_apply, moe_init

    p = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    assert p["w1"].shape[0] == 4
    assert p["router"]["w"].shape[1] == 3     # router sees logical experts
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, metrics = moe_apply(p, cfg, x, full_capacity=True)
    assert bool(jnp.isfinite(y).all())
    assert float(metrics["moe_dropped_frac"]) == 0.0


def test_maybe_shard_noop_without_mesh():
    from jax.sharding import PartitionSpec as P

    x = jnp.ones((4, 4))
    y = jax.jit(lambda a: maybe_shard(a, P(None, None)) * 2)(x)
    np.testing.assert_array_equal(np.asarray(y), 2 * np.asarray(x))


def test_remat_forward_unchanged():
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    h = M.embed_inputs(params, cfg, {"tokens": tokens})
    pos = jnp.arange(12, dtype=jnp.int32)
    a, _, _ = M.forward_hidden(params, cfg, h, positions=pos)
    b, _, _ = M.forward_hidden(params, cfg.replace(remat=True), h,
                               positions=pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_remat_gradients_match():
    cfg = tiny_dense(num_layers=1)
    params = M.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)

    def loss(p, c):
        from repro.core.train import lm_loss
        tc = TrainConfig(head_loss="mean")
        return lm_loss(p, c, tc, {"tokens": tokens}, jax.random.PRNGKey(2))[0]

    g1 = jax.grad(lambda p: loss(p, cfg))(params)
    g2 = jax.grad(lambda p: loss(p, cfg.replace(remat=True)))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-4, atol=1e-5), g1, g2)
