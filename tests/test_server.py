"""HTTP/SSE server contract over a real socket: health/readiness/metrics,
token-exact streaming (SSE and collected JSON), validation errors,
deterministic 429 back-pressure with Retry-After, and priority preemption
driven entirely over the wire."""
import asyncio
import http.client
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.config import DecodeConfig
from repro.core import decode as D
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    EngineConfig,
    Frontend,
    HTTPServer,
    Scheduler,
)

pytestmark = pytest.mark.serving

MAX_NEW = 16


@pytest.fixture(scope="module")
def server():
    """One live server shared by every test here: the event loop runs in a
    background thread, tests speak plain HTTP/1.1 from the test thread.
    eos -1 keeps every request at its full budget, which makes slot
    occupancy (and therefore 429s and preemption) deterministic."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dec = DecodeConfig(max_new_tokens=MAX_NEW, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=2, max_prompt_len=24,
                                       max_new_cap=MAX_NEW))
    fe = Frontend(Scheduler(eng), max_queue=2)
    srv = HTTPServer(fe, port=0)                # ephemeral port
    loop = asyncio.new_event_loop()
    thread = threading.Thread(target=loop.run_forever, daemon=True)
    thread.start()
    asyncio.run_coroutine_threadsafe(srv.start(), loop).result(timeout=300)
    yield params, cfg, dec, srv
    asyncio.run_coroutine_threadsafe(srv.stop(), loop).result(timeout=60)
    loop.call_soon_threadsafe(loop.stop)
    thread.join(timeout=10)


def _request(srv, method, path, body=None):
    conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=300)
    if body is not None and not isinstance(body, (str, bytes)):
        body = json.dumps(body)
    conn.request(method, path, body=body)
    resp = conn.getresponse()
    raw = resp.read()           # Connection: close -> EOF ends the stream
    headers = dict(resp.getheaders())
    conn.close()
    return resp.status, headers, raw


def _sse_events(raw):
    events = []
    for block in raw.decode().split("\n\n"):
        ev = data = None
        for ln in block.split("\n"):
            if ln.startswith("event: "):
                ev = ln[len("event: "):]
            elif ln.startswith("data: "):
                data = json.loads(ln[len("data: "):])
        if ev is not None:
            events.append((ev, data))
    return events


def _metrics_map(srv):
    _, _, raw = _request(srv, "GET", "/metrics")
    out = {}
    for ln in raw.decode().splitlines():
        k, v = ln.rsplit(" ", 1)
        out[k.removeprefix("repro_serving_")] = float(v)
    return out


def _reference(params, cfg, dec, prompt, max_new):
    d1 = dec.replace(max_new_tokens=max_new)
    bt, bs = D.bpd_decode(params, cfg, d1,
                          {"tokens": jnp.asarray(prompt)[None]})
    n = int(bs["text_len"][0])
    return [int(t) for t in np.asarray(bt[0, len(prompt):n])]


def test_health_ready_metrics(server):
    *_, srv = server
    status, _, raw = _request(srv, "GET", "/healthz")
    assert status == 200 and raw == b"ok\n"
    status, _, raw = _request(srv, "GET", "/readyz")
    assert status == 200 and raw == b"ready\n"
    m = _metrics_map(srv)
    assert m["num_slots"] == 2
    for key in ("requests_total", "rejected_total", "preemptions_total",
                "backpressure_requeues_total", "engine_steps_total"):
        assert key in m


def test_stream_matches_reference(server):
    """The SSE token events concatenate to exactly the run-to-completion
    bpd_decode output, and the done payload agrees with them."""
    params, cfg, dec, srv = server
    prompt = np.random.default_rng(19).integers(0, cfg.vocab_size, size=6)
    status, headers, raw = _request(
        srv, "POST", "/v1/generate",
        {"prompt": prompt.tolist(), "max_new": MAX_NEW})
    assert status == 200
    assert headers["Content-Type"] == "text/event-stream"
    events = _sse_events(raw)
    toks = [t for ev, d in events if ev == "token" for t in d["tokens"]]
    dones = [d for ev, d in events if ev == "done"]
    assert len(dones) == 1 and events[-1][0] == "done"
    done = dones[0]
    ref = _reference(params, cfg, dec, prompt, MAX_NEW)
    assert toks == done["tokens"] == ref
    assert done["generated"] == len(ref)
    assert done["preempted"] == 0
    assert done["invocations"] >= 2 and done["mean_accepted"] > 0
    assert done["latency_s"] >= done["queue_delay_s"] >= 0


def test_nonstream_json_matches_reference(server):
    params, cfg, dec, srv = server
    prompt = np.random.default_rng(20).integers(0, cfg.vocab_size, size=5)
    status, headers, raw = _request(
        srv, "POST", "/v1/generate",
        {"prompt": prompt.tolist(), "max_new": 8, "stream": False})
    assert status == 200
    assert headers["Content-Type"] == "application/json"
    done = json.loads(raw)
    assert done["tokens"] == _reference(params, cfg, dec, prompt, 8)


def test_validation_errors(server):
    *_, srv = server
    status, _, raw = _request(srv, "POST", "/v1/generate", "{not json")
    assert status == 400 and b"prompt" in raw
    status, _, _ = _request(srv, "POST", "/v1/generate", {"prompt": [1, 2]})
    assert status == 400                          # max_new missing
    status, _, raw = _request(
        srv, "POST", "/v1/generate",
        {"prompt": list(range(1, 40)), "max_new": 4})
    assert status == 400 and b"prompt length" in raw
    status, _, raw = _request(
        srv, "POST", "/v1/generate",
        {"prompt": [1, 2, 3], "max_new": 4, "policy": "no-such-policy"})
    assert status == 400
    status, _, _ = _request(srv, "GET", "/v1/generate")
    assert status == 404
    status, _, _ = _request(srv, "GET", "/nope")
    assert status == 404


def test_backpressure_429_with_retry_after(server):
    """A 12-request burst against 2 slots + 2 queue spots must reject some
    requests with 429 + Retry-After; accepted streams stay token-exact."""
    params, cfg, dec, srv = server
    rng = np.random.default_rng(29)
    prompts = [rng.integers(0, cfg.vocab_size, size=5) for _ in range(12)]

    def one(i):
        return _request(srv, "POST", "/v1/generate",
                        {"prompt": prompts[i].tolist(), "max_new": MAX_NEW})

    with ThreadPoolExecutor(max_workers=12) as ex:
        out = list(ex.map(one, range(12)))
    statuses = [s for s, _, _ in out]
    assert statuses.count(200) >= 2               # capacity was served
    assert 429 in statuses                        # overflow was refused
    _, hdrs, raw = out[statuses.index(429)]
    assert int(hdrs["Retry-After"]) >= 1
    body = json.loads(raw)
    assert body["retry_after_s"] >= 1 and "retry" in body["error"]
    assert _metrics_map(srv)["rejected_total"] >= statuses.count(429)
    for (status, _, raw), p in zip(out, prompts):
        if status == 200:
            done = [d for ev, d in _sse_events(raw) if ev == "done"][0]
            assert done["tokens"] == _reference(params, cfg, dec, p, MAX_NEW)


def test_preemption_over_the_wire(server):
    """Fill both slots with full-budget requests, then send a priority-1
    past-deadline request: one victim is evicted and re-admitted, yet every
    stream — victims included — is token-identical to an uninterrupted
    decode."""
    params, cfg, dec, srv = server
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(3)]
    results = {}

    def client(i, payload):
        status, _, raw = _request(srv, "POST", "/v1/generate", payload)
        results[i] = (status, _sse_events(raw))

    base = _metrics_map(srv)
    with ThreadPoolExecutor(max_workers=2) as ex:
        futs = [ex.submit(client, i, {"prompt": prompts[i].tolist(),
                                      "max_new": MAX_NEW})
                for i in range(2)]
        # wait until both occupy slots and the queue is empty: the urgent
        # request below then CANNOT be served without evicting someone
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            m = _metrics_map(srv)
            if m["active_slots"] >= 2 and m["queue_depth"] == 0:
                break
            time.sleep(0.002)
        else:
            pytest.fail("slots never filled")
        client(2, {"prompt": prompts[2].tolist(), "max_new": 4,
                   "priority": 1, "deadline_s": 0.0})
        for f in futs:
            f.result()

    assert all(results[i][0] == 200 for i in range(3))
    dones = {i: [d for ev, d in results[i][1] if ev == "done"][0]
             for i in range(3)}
    assert dones[2]["preempted"] == 0             # the urgent one never waits
    assert sum(dones[i]["preempted"] for i in (0, 1)) >= 1
    m = _metrics_map(srv)
    assert m["preemptions_total"] >= base["preemptions_total"] + 1
    for i, budget in ((0, MAX_NEW), (1, MAX_NEW), (2, 4)):
        toks = [t for ev, d in results[i][1] if ev == "token"
                for t in d["tokens"]]
        ref = _reference(params, cfg, dec, prompts[i], budget)
        assert toks == dones[i]["tokens"] == ref, f"rid-slot {i}"


def test_graceful_drain_over_the_wire():
    """POST /drain against a live (disaggregated) server: 202 immediately,
    readiness flips to 503 "draining", new submissions are refused with
    503, the in-flight stream finishes token-exact, and the listener then
    closes — the whole SIGTERM shutdown path, driven over the wire (the
    signal handler and this route share ``begin_drain``).  Needs its own
    server: a drained listener cannot be reused by later tests."""
    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    dec = DecodeConfig(max_new_tokens=MAX_NEW, block_k=4)
    eng = ContinuousBatchingEngine(
        params, cfg, dec,
        EngineConfig(num_slots=2, max_prompt_len=24, max_new_cap=MAX_NEW,
                     prefill_slots=2, handoff_cap=4))
    srv = HTTPServer(Frontend(Scheduler(eng), max_queue=2), port=0)
    loop = asyncio.new_event_loop()
    thread = threading.Thread(target=loop.run_forever, daemon=True)
    thread.start()
    asyncio.run_coroutine_threadsafe(srv.start(), loop).result(timeout=300)
    try:
        rng = np.random.default_rng(31)
        prompt = rng.integers(0, cfg.vocab_size, size=6)
        results = {}

        def client():
            results["r"] = _request(
                srv, "POST", "/v1/generate",
                {"prompt": prompt.tolist(), "max_new": MAX_NEW})

        t = threading.Thread(target=client)
        t.start()
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if _metrics_map(srv)["active_slots"] >= 1:
                break
            time.sleep(0.002)
        else:
            pytest.fail("in-flight request never occupied a slot")

        status, _, raw = _request(srv, "POST", "/drain")
        assert status == 202
        body = json.loads(raw)
        assert body["draining"] is True and body["in_flight"] >= 1
        status, _, _ = _request(srv, "POST", "/drain")   # idempotent
        assert status == 202
        status, _, raw = _request(srv, "GET", "/readyz")
        assert status == 503 and raw == b"draining\n"
        status, _, raw = _request(srv, "POST", "/v1/generate",
                                  {"prompt": [1, 2, 3], "max_new": 4})
        assert status == 503 and b"drain" in raw

        t.join(timeout=120)
        assert not t.is_alive(), "in-flight stream did not finish"
        status, _, raw = results["r"]
        assert status == 200
        done = [d for ev, d in _sse_events(raw) if ev == "done"][0]
        assert done["tokens"] == _reference(params, cfg, dec, prompt,
                                            MAX_NEW)
        # drained + flushed -> the listener closes; new connections refuse
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            try:
                _request(srv, "GET", "/healthz")
                time.sleep(0.01)
            except OSError:
                break
        else:
            pytest.fail("listener never closed after the drain finished")
    finally:
        asyncio.run_coroutine_threadsafe(srv.stop(), loop).result(timeout=60)
        loop.call_soon_threadsafe(loop.stop)
        thread.join(timeout=10)
