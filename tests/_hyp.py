"""Optional-``hypothesis`` shim so the suite collects on minimal installs.

When hypothesis is available this module re-exports the real ``given`` /
``settings`` / ``st``.  When it is not, ``@given(...)`` replaces the test
with a zero-argument stub marked skip (a plain skip decorator would leave
the strategy parameters looking like unknown fixtures), and ``settings`` /
``st`` become inert stand-ins.  Install the full toolchain with
``pip install -e .[test]``.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # minimal install: property-based tests skip
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -e .[test])")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
