"""The paper's central guarantee (§3): blockwise parallel decoding with
exact-match verification produces the SAME output as greedy decoding, for
any block size k, any architecture family, any prompt.

Property-tested with hypothesis over random model seeds / prompts / k, plus
deterministic cases for EOS handling and per-row divergence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import FAMILY_CONFIGS, tiny_seq2seq
from repro.config import DecodeConfig
from repro.core import decode as D
from repro.models import model as M
from repro.models import seq2seq as S


def _decode_pair(cfg, seed, b, prompt_len, max_new, k, eos=-1):
    params = M.init(jax.random.PRNGKey(seed), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                          (b, prompt_len), 0, cfg.vocab_size)}
    dec = DecodeConfig(max_new_tokens=max_new, block_k=k, criterion="exact",
                       eos_id=eos)
    bt, bs = D.bpd_decode(params, cfg, dec, batch)
    gt, gs = D.greedy_decode(params, cfg, dec, batch)
    n = prompt_len + max_new
    return (np.asarray(bt[:, :n]), np.asarray(gt[:, :n]),
            np.asarray(bs["text_len"]), np.asarray(gs["text_len"]), bs)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 6),
       family=st.sampled_from(sorted(FAMILY_CONFIGS)))
def test_bpd_equals_greedy_property(seed, k, family):
    cfg = FAMILY_CONFIGS[family](bpd_k=k)
    bt, gt, bl, gl, _ = _decode_pair(cfg, seed, b=2, prompt_len=6, max_new=12, k=k)
    np.testing.assert_array_equal(bl, gl)
    np.testing.assert_array_equal(bt, gt)


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
def test_bpd_equals_greedy_with_eos(family):
    cfg = FAMILY_CONFIGS[family]()
    # eos inside the vocab: both decoders must stop at the same position
    bt, gt, bl, gl, _ = _decode_pair(cfg, seed=7, b=4, prompt_len=5,
                                     max_new=16, k=4, eos=3)
    np.testing.assert_array_equal(bl, gl)
    for row in range(4):
        n = bl[row]
        np.testing.assert_array_equal(bt[row, :n], gt[row, :n])


def test_bpd_uses_fewer_iterations_than_greedy_on_repetitive_input():
    """A prompt of one repeated token makes the (untrained but deterministic)
    model highly predictable for its own heads is NOT guaranteed; instead we
    check the invocation count never exceeds greedy's."""
    cfg = FAMILY_CONFIGS["dense"]()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((2, 6), jnp.int32)}
    dec = DecodeConfig(max_new_tokens=20, block_k=4)
    _, bs = D.bpd_decode(params, cfg, dec, batch)
    assert int(bs["iterations"]) <= 20
    assert float(bs["mean_accepted"]) >= 1.0


def test_seq2seq_bpd_equals_greedy():
    cfg = tiny_seq2seq()
    params = S.init(jax.random.PRNGKey(3), cfg)
    batch = {"src": jax.random.randint(jax.random.PRNGKey(4), (3, 9), 1,
                                       cfg.vocab_size)}
    dec = DecodeConfig(max_new_tokens=14, criterion="exact", eos_id=1)
    bt, bs = D.bpd_decode_seq2seq(params, cfg, dec, batch)
    gt, gs = D.greedy_decode_seq2seq(params, cfg, dec, batch)
    bl, gl = np.asarray(bs["text_len"]), np.asarray(gs["text_len"])
    np.testing.assert_array_equal(bl, gl)
    for row in range(3):
        n = bl[row] - 1  # text_len includes BOS; outputs are BOS-stripped
        np.testing.assert_array_equal(np.asarray(bt)[row, :n],
                                      np.asarray(gt)[row, :n])


def test_vlm_prefix_bpd_equals_greedy():
    cfg = FAMILY_CONFIGS["dense"](modality="vision_text")
    params = M.init(jax.random.PRNGKey(0), cfg)
    patches = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0,
                                          cfg.vocab_size),
             "patch_embeds": patches}
    dec = DecodeConfig(max_new_tokens=10, block_k=4)
    bt, _ = D.bpd_decode(params, cfg, dec, batch)
    gt, _ = D.greedy_decode(params, cfg, dec, batch)
    np.testing.assert_array_equal(np.asarray(bt[:, :15]), np.asarray(gt[:, :15]))


def test_rows_advance_independently():
    """Different rows accept different k̂ per iteration; all still match
    their own greedy decode (checked above) and generated counts hit max."""
    cfg = FAMILY_CONFIGS["dense"]()
    params = M.init(jax.random.PRNGKey(11), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(12), (6, 4), 0,
                                          cfg.vocab_size)}
    dec = DecodeConfig(max_new_tokens=12, block_k=4)
    _, stats = D.bpd_decode(params, cfg, dec, batch)
    assert np.all(np.asarray(stats["generated"]) == 12)


def test_approximate_criteria_accept_at_least_exact():
    cfg = FAMILY_CONFIGS["dense"]()
    params = M.init(jax.random.PRNGKey(5), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(6), (4, 6), 0,
                                          cfg.vocab_size)}
    means = {}
    for crit, kw in [("exact", {}), ("topk", dict(top_k=3)),
                     ("distance", dict(epsilon=5.0))]:
        dec = DecodeConfig(max_new_tokens=24, block_k=4, criterion=crit, **kw)
        _, stats = D.bpd_decode(params, cfg, dec, batch)
        means[crit] = float(stats["mean_accepted"])
    assert means["topk"] >= means["exact"] - 1e-6
    assert means["distance"] >= 1.0
