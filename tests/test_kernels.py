"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.kernels import ops, ref
from repro.kernels.tree_mask import TreeTopology, default_tree

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# block_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,kq,h,kv,hd,l,window,meta,block_kv",
    [
        (1, 2, 4, 4, 16, 64, 0, 0, 32),     # MHA
        (2, 4, 8, 2, 32, 100, 0, 0, 32),    # GQA, ragged L
        (1, 8, 6, 2, 64, 256, 64, 0, 128),  # sliding window
        (2, 4, 4, 1, 32, 96, 32, 4, 32),    # MQA + meta tokens
        (1, 1, 2, 2, 128, 33, 0, 0, 512),   # single query, one short block
    ])
def test_verify_attention_sweep(b, kq, h, kv, hd, l, window, meta, block_kv,
                                dtype):
    q = _rand((b, kq, h, hd), dtype)
    k = _rand((b, l, kv, hd), dtype)
    v = _rand((b, l, kv, hd), dtype)
    base = RNG.integers(max(meta, 1), l - kq, b)
    qpos = jnp.asarray(base[:, None] + np.arange(kq)[None, :], jnp.int32)
    kvpos = np.tile(np.arange(l)[None], (b, 1))
    kvpos[:, RNG.integers(0, l, 5)] = -1          # stale speculative slots
    kvpos = jnp.asarray(kvpos, jnp.int32)
    got = ops.verify_attention(q, k, v, qpos, kvpos, window=window,
                               num_meta=meta, block_kv=block_kv)
    want = ref.verify_attention(q, k, v, qpos, kvpos, window=window,
                                num_meta=meta)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_verify_attention_masks_all_stale_rows():
    """A row whose only visible entry is its own block must not NaN."""
    b, kq, h, kv, hd, l = 1, 2, 2, 2, 16, 16
    q = _rand((b, kq, h, hd), jnp.float32)
    k = _rand((b, l, kv, hd), jnp.float32)
    v = _rand((b, l, kv, hd), jnp.float32)
    qpos = jnp.asarray([[0, 1]], jnp.int32)
    kvpos = jnp.asarray(np.r_[0:2, [-1] * (l - 2)][None], jnp.int32)
    got = ops.verify_attention(q, k, v, qpos, kvpos)
    assert not bool(jnp.any(jnp.isnan(got)))


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------


def _paged_case(b, kq, h, kv, hd, P, ps, num_pages, meta=0, share=False):
    """A paged cache with random mapped prefixes; returns kernel inputs."""
    q = _rand((b, kq, h, hd), jnp.float32)
    kp = _rand((num_pages, ps, kv, hd), jnp.float32)
    vp = _rand((num_pages, ps, kv, hd), jnp.float32)
    # each row maps a random number of leading pages; the rest hit trash 0
    tbl = np.zeros((b, P), np.int32)
    kvpos = np.full((b, P * ps), -1, np.int32)
    ctx = np.zeros(b, np.int64)
    pool = list(range(1, num_pages))
    for bi in range(b):
        n = int(RNG.integers(1, P + 1))
        for i in range(n):
            if share and bi > 0 and i == 0:
                tbl[bi, i] = tbl[0, 0]        # CoW: share row 0's first page
            else:
                tbl[bi, i] = pool.pop()
        ctx[bi] = n * ps
        kvpos[bi, :ctx[bi]] = np.arange(ctx[bi])
    # stale a few speculative tail slots (BPD rollback)
    for bi in range(b):
        kvpos[bi, RNG.integers(0, ctx[bi], 2)] = -1
    base = np.maximum(ctx - kq, meta)
    qpos = jnp.asarray(base[:, None] + np.arange(kq)[None, :], jnp.int32)
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl), qpos,
            jnp.asarray(kvpos))


@pytest.mark.parametrize(
    "b,kq,h,kv,hd,P,ps,num_pages,window,meta",
    [
        (1, 2, 4, 4, 16, 4, 8, 8, 0, 0),      # MHA, small pool
        (2, 4, 8, 2, 32, 3, 16, 12, 0, 0),    # GQA
        (1, 8, 6, 2, 64, 6, 8, 16, 32, 0),    # sliding window
        (2, 4, 4, 1, 32, 4, 8, 16, 16, 4),    # MQA + meta tokens
    ])
def test_paged_attention_sweep(b, kq, h, kv, hd, P, ps, num_pages, window,
                               meta):
    q, kp, vp, tbl, qpos, kvpos = _paged_case(b, kq, h, kv, hd, P, ps,
                                              num_pages, meta=meta)
    got = ops.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos,
                                     window=window, num_meta=meta)
    want = ref.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos,
                                      window=window, num_meta=meta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def test_paged_attention_matches_dense_gather():
    """Kernel over the paged pool == dense kernel over the gathered view —
    the token-identity invariant the paged backend rests on."""
    b, kq, h, kv, hd, P, ps = 2, 4, 4, 2, 32, 4, 8
    q, kp, vp, tbl, qpos, kvpos = _paged_case(b, kq, h, kv, hd, P, ps,
                                              num_pages=16)
    got = ops.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos)
    kd = jnp.asarray(np.asarray(kp)[np.asarray(tbl)].reshape(b, P * ps, kv, hd))
    vd = jnp.asarray(np.asarray(vp)[np.asarray(tbl)].reshape(b, P * ps, kv, hd))
    want = ops.verify_attention(q, kd, vd, qpos, kvpos, block_kv=ps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def test_paged_attention_cow_shared_page():
    """Two rows sharing one physical prefix page read identical bytes."""
    b, kq, h, kv, hd, P, ps = 2, 2, 2, 2, 16, 3, 8
    q, kp, vp, tbl, qpos, kvpos = _paged_case(b, kq, h, kv, hd, P, ps,
                                              num_pages=8, share=True)
    assert int(tbl[0, 0]) == int(tbl[1, 0])   # the share actually happened
    got = ops.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos)
    want = ref.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])
    assert not bool(jnp.any(jnp.isnan(got)))


# ---------------------------------------------------------------------------
# rwkv6_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,d,chunk", [
    (1, 16, 1, 16, 16),
    (2, 37, 3, 16, 16),      # ragged: S % chunk != 0
    (1, 128, 2, 64, 16),     # production head_dim
    (2, 64, 2, 32, 32),      # larger chunk
])
def test_rwkv6_scan_sweep(b, s, h, d, chunk, dtype):
    r, k, v = (_rand((b, s, h, d), dtype) for _ in range(3))
    logw = -jnp.exp(_rand((b, s, h, d), jnp.float32) * 0.5 - 1.0)
    u = _rand((h, d), jnp.float32) * 0.1
    y1, s1 = ops.rwkv6_scan(r, k, v, logw, u, chunk=chunk)
    y2, s2 = ref.rwkv6_scan(r, k, v, logw, u)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), **tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), **tol)


def test_rwkv6_scan_strong_decay_stable():
    """Strong decays underflow 1/a; the clamp must keep outputs finite and
    correct (annihilated contributions are ~0 in the oracle too)."""
    b, s, h, d = 1, 48, 1, 16
    r, k, v = (_rand((b, s, h, d), jnp.float32) for _ in range(3))
    logw = jnp.full((b, s, h, d), -8.0)           # w = e^-8: near-total decay
    u = _rand((h, d), jnp.float32) * 0.1
    y1, _ = ops.rwkv6_scan(r, k, v, logw, u, chunk=16)
    y2, _ = ref.rwkv6_scan(r, k, v, logw, u)
    assert np.isfinite(np.asarray(y1)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# fused_heads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,vocab,vp,top_t,block_v", [
    (8, 32, 256, 256, 1, 128),
    (17, 32, 1000, 1024, 4, 256),     # ragged rows + vocab pad
    (64, 64, 504, 512, 2, 512),       # hubert-style tiny vocab, 1 tile
    (5, 128, 2000, 2048, 4, 1024),
])
def test_fused_heads_sweep(n, d, vocab, vp, top_t, block_v, dtype):
    o = _rand((n, d), dtype)
    w = _rand((d, vp), dtype)
    v1, i1 = ops.fused_heads_topk(o, w, vocab=vocab, top_t=top_t,
                                  block_v=block_v, block_rows=8)
    v2, i2 = ref.heads_topk(o, w, vocab=vocab, top_t=top_t)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), **tol)
    # ids may differ only where values tie (random floats: no ties expected)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_fused_heads_never_selects_vocab_pad():
    o = jnp.ones((4, 16), jnp.float32)
    w = jnp.ones((16, 512), jnp.float32) * 10.0   # pad lanes equally huge
    _, ids = ops.fused_heads_topk(o, w, vocab=300, top_t=4, block_v=128,
                                  block_rows=8)
    assert int(jnp.max(ids)) < 300


# ---------------------------------------------------------------------------
# fused_verify (one-pass accept)
# ---------------------------------------------------------------------------

FV_CRITERIA = ("exact", "topk", "distance")


def _acceptor_for(crit):
    from repro.core import policy as policy_lib

    return {"exact": policy_lib.ExactAcceptor(),
            "topk": policy_lib.TopKAcceptor(top_k=3),
            "distance": policy_lib.DistanceAcceptor(epsilon=2.0)}[crit]


def _check_fused_verify(seed, crit, b, k, vocab, dtype, block_rows=8,
                        block_v=128):
    """Kernel == jnp oracle == (unfused) Acceptor semantics, bit-for-bit
    on the discrete outputs."""
    rng = np.random.default_rng(seed)
    props = jnp.asarray(rng.integers(0, vocab, (b, k)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(b, k, vocab)).astype(np.float32),
                         dtype)
    kw = dict(criterion=crit, top_k=3, epsilon=2.0)
    acc, khat, toks, nxt = ops.fused_verify(logits, props,
                                            block_rows=block_rows,
                                            block_v=block_v, **kw)
    acc2, khat2, toks2, nxt2 = ref.fused_verify(logits, props, **kw)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc2))
    np.testing.assert_array_equal(np.asarray(khat), np.asarray(khat2))
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(nxt2))
    # the per-position accepts ARE the policy Acceptor's decisions
    pol_acc = _acceptor_for(crit).accepts(props, logits)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(pol_acc))
    # contract: slot 0 accepted, khat = longest accepted prefix, tokens
    # zero-padded past khat, next_greedy in vocab
    a, kh = np.asarray(acc), np.asarray(khat)
    assert a[:, 0].all() and np.all(kh >= 1) and np.all(kh <= k)
    for i in range(b):
        assert a[i, :kh[i]].all()
        if kh[i] < k:
            assert not a[i, kh[i]]
    t = np.asarray(toks)
    assert np.all(t[np.arange(k)[None, :] >= kh[:, None]] == 0)
    assert np.all((np.asarray(nxt) >= 0) & (np.asarray(nxt) < vocab))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("crit", FV_CRITERIA)
@pytest.mark.parametrize("b,k,vocab,block_v", [
    (3, 4, 128, 128),        # single vocab tile
    (2, 8, 1000, 256),       # ragged vocab (pad lanes in the last tile)
    (5, 6, 333, 128),        # b*k not a sublane multiple
    (1, 2, 2048, 1024),
])
def test_fused_verify_sweep(b, k, vocab, block_v, crit, dtype):
    _check_fused_verify(7, crit, b, k, vocab, dtype, block_v=block_v)


@pytest.mark.parametrize("crit", FV_CRITERIA)
def test_fused_verify_all_accept_and_all_reject(crit):
    """Degenerate rows: a proposal chain equal to the greedy chain commits
    the whole block; one that never matches (and is ordinally far) commits
    exactly slot 0."""
    b, k, vocab = 2, 5, 64
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(b, k, vocab)), jnp.float32)
    greedy = np.asarray(jnp.argmax(logits, -1))
    props_acc = np.zeros((b, k), np.int32)
    props_acc[:, 1:] = greedy[:, :k - 1]                 # slot i <- greedy i-1
    acc, khat, _, _ = ops.fused_verify(
        logits, jnp.asarray(props_acc), criterion=crit, top_k=3,
        epsilon=2.0, block_rows=8, block_v=64)
    assert np.asarray(acc).all() and np.all(np.asarray(khat) == k)
    # rejection: tokens ordinally >2.0 from greedy, outside top-3, != greedy
    order = np.argsort(-np.asarray(logits), axis=-1)     # (b, k, vocab)
    props_rej = np.zeros((b, k), np.int32)
    for i in range(b):
        for j in range(1, k):
            cand = [t for t in order[i, j - 1, vocab // 2:]
                    if abs(int(t) - int(greedy[i, j - 1])) > 2]
            props_rej[i, j] = cand[0]
    acc, khat, toks, nxt = ops.fused_verify(
        logits, jnp.asarray(props_rej), criterion=crit, top_k=3,
        epsilon=2.0, block_rows=8, block_v=64)
    assert np.all(np.asarray(khat) == 1)
    np.testing.assert_array_equal(np.asarray(acc)[:, 1:], False)
    np.testing.assert_array_equal(np.asarray(toks)[:, 1:], 0)
    np.testing.assert_array_equal(np.asarray(nxt), greedy[:, 0])


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), crit=st.sampled_from(FV_CRITERIA),
       b=st.integers(1, 6), k=st.integers(2, 8),
       vocab=st.sampled_from((64, 130, 512)),
       block_v=st.sampled_from((64, 128, 256)),
       bf16=st.booleans())
def test_fused_verify_property(seed, crit, b, k, vocab, block_v, bf16):
    """Property pin: kernel == oracle == Acceptor for arbitrary shapes,
    criteria, dtypes and vocab tilings."""
    _check_fused_verify(seed, crit, b, k, vocab,
                        jnp.bfloat16 if bf16 else jnp.float32,
                        block_v=block_v)


# ---------------------------------------------------------------------------
# tree_verify_attention
# ---------------------------------------------------------------------------


def _tree_case(b, kq, h, kvh, hd, l, fanout, seed=11):
    """KV cache whose per-row slots [length, length+kq) hold this block's
    tree nodes (written at chain storage positions, RoPE'd by depth)."""
    rng = np.random.default_rng(seed)
    topo = default_tree(kq, fanout)
    q = jnp.asarray(rng.normal(size=(b, kq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, l, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, l, kvh, hd)), jnp.float32)
    length = jnp.asarray(rng.integers(kq, l - kq, size=(b,)), jnp.int32)
    depths = jnp.asarray(topo.depths)
    q_pos = length[:, None] + depths[None, :]
    slot = jnp.arange(l)[None, :]
    node = slot - length[:, None]
    is_tree = (node >= 0) & (node < kq)
    kv_node = jnp.where(is_tree, node, -1).astype(jnp.int32)
    kv_pos = jnp.where(
        slot < length[:, None], slot,
        jnp.where(is_tree,
                  length[:, None] + depths[jnp.clip(node, 0, kq - 1)],
                  -1)).astype(jnp.int32)
    anc = jnp.broadcast_to(jnp.asarray(topo.anc_bits)[None, :], (b, kq))
    return q, k, v, q_pos, kv_pos, kv_node, anc


@pytest.mark.parametrize("b,kq,h,kvh,hd,l,fanout,window,block_kv", [
    (2, 8, 4, 2, 16, 48, 4, 0, 16),     # GQA
    (1, 4, 4, 4, 24, 33, 2, 12, 16),    # MHA + sliding window, ragged hd/L
    (3, 8, 8, 2, 32, 64, 7, 0, 32),     # full-fanout star
    (1, 2, 2, 1, 64, 40, 1, 0, 512),    # MQA chain-like tree, one block
])
def test_tree_verify_attention_sweep(b, kq, h, kvh, hd, l, fanout, window,
                                     block_kv):
    q, k, v, q_pos, kv_pos, kv_node, anc = _tree_case(b, kq, h, kvh, hd, l,
                                                      fanout)
    got = ops.tree_verify_attention(q, k, v, q_pos, kv_pos, kv_node, anc,
                                    window=window, block_kv=block_kv)
    want = ref.tree_verify_attention(q, k, v, q_pos, kv_pos, kv_node, anc,
                                     window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def test_tree_verify_chain_degenerates_to_verify_attention():
    """A pure-chain topology's ancestor mask IS the causal mask — the tree
    kernel must match the standard verify kernel exactly."""
    b, kq, h, kvh, hd, l = 2, 6, 4, 2, 32, 40
    topo = TreeTopology((-1,) + tuple(range(kq - 1)))    # 0<-1<-2<-...
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(b, kq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, l, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, l, kvh, hd)), jnp.float32)
    length = jnp.asarray([10, 17], jnp.int32)
    q_pos = length[:, None] + jnp.arange(kq)[None, :]
    slot = jnp.arange(l)[None, :]
    node = slot - length[:, None]
    is_tree = (node >= 0) & (node < kq)
    kv_node = jnp.where(is_tree, node, -1).astype(jnp.int32)
    kv_pos = jnp.where(slot < length[:, None] + kq, slot, -1).astype(jnp.int32)
    anc = jnp.broadcast_to(jnp.asarray(topo.anc_bits)[None, :], (b, kq))
    got = ops.tree_verify_attention(q, k, v, q_pos, kv_pos, kv_node, anc,
                                    block_kv=16)
    want = ops.verify_attention(q, k, v, q_pos, kv_pos, block_kv=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def test_fused_heads_matches_model_argmax():
    """End-to-end: kernel top-1 == argmax of model.all_head_logits."""
    import jax

    from conftest import tiny_dense
    from repro.core.heads import heads_apply
    from repro.models import model as M

    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    hidden = _rand((6, cfg.d_model), jnp.float32)
    logits = M.all_head_logits(params, cfg, hidden)          # (6, K, Vp)
    want = np.asarray(jnp.argmax(logits, -1))                # (6, K)

    outs = heads_apply(params["bpd_heads"], cfg, hidden,
                       identity_p1=cfg.bpd_identity_p1)      # (6, K, d)
    o = outs.reshape(-1, cfg.d_model)
    w = params["lm_head"]["w"]
    _, ids = ops.fused_heads_topk(o, w, vocab=cfg.vocab_size, top_t=1,
                                  block_v=128, block_rows=8)
    got = np.asarray(ids[:, 0]).reshape(6, cfg.bpd_k)
    np.testing.assert_array_equal(got, want)
