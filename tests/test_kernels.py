"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# block_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,kq,h,kv,hd,l,window,meta,block_kv",
    [
        (1, 2, 4, 4, 16, 64, 0, 0, 32),     # MHA
        (2, 4, 8, 2, 32, 100, 0, 0, 32),    # GQA, ragged L
        (1, 8, 6, 2, 64, 256, 64, 0, 128),  # sliding window
        (2, 4, 4, 1, 32, 96, 32, 4, 32),    # MQA + meta tokens
        (1, 1, 2, 2, 128, 33, 0, 0, 512),   # single query, one short block
    ])
def test_verify_attention_sweep(b, kq, h, kv, hd, l, window, meta, block_kv,
                                dtype):
    q = _rand((b, kq, h, hd), dtype)
    k = _rand((b, l, kv, hd), dtype)
    v = _rand((b, l, kv, hd), dtype)
    base = RNG.integers(max(meta, 1), l - kq, b)
    qpos = jnp.asarray(base[:, None] + np.arange(kq)[None, :], jnp.int32)
    kvpos = np.tile(np.arange(l)[None], (b, 1))
    kvpos[:, RNG.integers(0, l, 5)] = -1          # stale speculative slots
    kvpos = jnp.asarray(kvpos, jnp.int32)
    got = ops.verify_attention(q, k, v, qpos, kvpos, window=window,
                               num_meta=meta, block_kv=block_kv)
    want = ref.verify_attention(q, k, v, qpos, kvpos, window=window,
                                num_meta=meta)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_verify_attention_masks_all_stale_rows():
    """A row whose only visible entry is its own block must not NaN."""
    b, kq, h, kv, hd, l = 1, 2, 2, 2, 16, 16
    q = _rand((b, kq, h, hd), jnp.float32)
    k = _rand((b, l, kv, hd), jnp.float32)
    v = _rand((b, l, kv, hd), jnp.float32)
    qpos = jnp.asarray([[0, 1]], jnp.int32)
    kvpos = jnp.asarray(np.r_[0:2, [-1] * (l - 2)][None], jnp.int32)
    got = ops.verify_attention(q, k, v, qpos, kvpos)
    assert not bool(jnp.any(jnp.isnan(got)))


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------


def _paged_case(b, kq, h, kv, hd, P, ps, num_pages, meta=0, share=False):
    """A paged cache with random mapped prefixes; returns kernel inputs."""
    q = _rand((b, kq, h, hd), jnp.float32)
    kp = _rand((num_pages, ps, kv, hd), jnp.float32)
    vp = _rand((num_pages, ps, kv, hd), jnp.float32)
    # each row maps a random number of leading pages; the rest hit trash 0
    tbl = np.zeros((b, P), np.int32)
    kvpos = np.full((b, P * ps), -1, np.int32)
    ctx = np.zeros(b, np.int64)
    pool = list(range(1, num_pages))
    for bi in range(b):
        n = int(RNG.integers(1, P + 1))
        for i in range(n):
            if share and bi > 0 and i == 0:
                tbl[bi, i] = tbl[0, 0]        # CoW: share row 0's first page
            else:
                tbl[bi, i] = pool.pop()
        ctx[bi] = n * ps
        kvpos[bi, :ctx[bi]] = np.arange(ctx[bi])
    # stale a few speculative tail slots (BPD rollback)
    for bi in range(b):
        kvpos[bi, RNG.integers(0, ctx[bi], 2)] = -1
    base = np.maximum(ctx - kq, meta)
    qpos = jnp.asarray(base[:, None] + np.arange(kq)[None, :], jnp.int32)
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl), qpos,
            jnp.asarray(kvpos))


@pytest.mark.parametrize(
    "b,kq,h,kv,hd,P,ps,num_pages,window,meta",
    [
        (1, 2, 4, 4, 16, 4, 8, 8, 0, 0),      # MHA, small pool
        (2, 4, 8, 2, 32, 3, 16, 12, 0, 0),    # GQA
        (1, 8, 6, 2, 64, 6, 8, 16, 32, 0),    # sliding window
        (2, 4, 4, 1, 32, 4, 8, 16, 16, 4),    # MQA + meta tokens
    ])
def test_paged_attention_sweep(b, kq, h, kv, hd, P, ps, num_pages, window,
                               meta):
    q, kp, vp, tbl, qpos, kvpos = _paged_case(b, kq, h, kv, hd, P, ps,
                                              num_pages, meta=meta)
    got = ops.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos,
                                     window=window, num_meta=meta)
    want = ref.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos,
                                      window=window, num_meta=meta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def test_paged_attention_matches_dense_gather():
    """Kernel over the paged pool == dense kernel over the gathered view —
    the token-identity invariant the paged backend rests on."""
    b, kq, h, kv, hd, P, ps = 2, 4, 4, 2, 32, 4, 8
    q, kp, vp, tbl, qpos, kvpos = _paged_case(b, kq, h, kv, hd, P, ps,
                                              num_pages=16)
    got = ops.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos)
    kd = jnp.asarray(np.asarray(kp)[np.asarray(tbl)].reshape(b, P * ps, kv, hd))
    vd = jnp.asarray(np.asarray(vp)[np.asarray(tbl)].reshape(b, P * ps, kv, hd))
    want = ops.verify_attention(q, kd, vd, qpos, kvpos, block_kv=ps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def test_paged_attention_cow_shared_page():
    """Two rows sharing one physical prefix page read identical bytes."""
    b, kq, h, kv, hd, P, ps = 2, 2, 2, 2, 16, 3, 8
    q, kp, vp, tbl, qpos, kvpos = _paged_case(b, kq, h, kv, hd, P, ps,
                                              num_pages=8, share=True)
    assert int(tbl[0, 0]) == int(tbl[1, 0])   # the share actually happened
    got = ops.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos)
    want = ref.paged_verify_attention(q, kp, vp, tbl, qpos, kvpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])
    assert not bool(jnp.any(jnp.isnan(got)))


# ---------------------------------------------------------------------------
# rwkv6_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,d,chunk", [
    (1, 16, 1, 16, 16),
    (2, 37, 3, 16, 16),      # ragged: S % chunk != 0
    (1, 128, 2, 64, 16),     # production head_dim
    (2, 64, 2, 32, 32),      # larger chunk
])
def test_rwkv6_scan_sweep(b, s, h, d, chunk, dtype):
    r, k, v = (_rand((b, s, h, d), dtype) for _ in range(3))
    logw = -jnp.exp(_rand((b, s, h, d), jnp.float32) * 0.5 - 1.0)
    u = _rand((h, d), jnp.float32) * 0.1
    y1, s1 = ops.rwkv6_scan(r, k, v, logw, u, chunk=chunk)
    y2, s2 = ref.rwkv6_scan(r, k, v, logw, u)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), **tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), **tol)


def test_rwkv6_scan_strong_decay_stable():
    """Strong decays underflow 1/a; the clamp must keep outputs finite and
    correct (annihilated contributions are ~0 in the oracle too)."""
    b, s, h, d = 1, 48, 1, 16
    r, k, v = (_rand((b, s, h, d), jnp.float32) for _ in range(3))
    logw = jnp.full((b, s, h, d), -8.0)           # w = e^-8: near-total decay
    u = _rand((h, d), jnp.float32) * 0.1
    y1, _ = ops.rwkv6_scan(r, k, v, logw, u, chunk=16)
    y2, _ = ref.rwkv6_scan(r, k, v, logw, u)
    assert np.isfinite(np.asarray(y1)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# fused_heads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,vocab,vp,top_t,block_v", [
    (8, 32, 256, 256, 1, 128),
    (17, 32, 1000, 1024, 4, 256),     # ragged rows + vocab pad
    (64, 64, 504, 512, 2, 512),       # hubert-style tiny vocab, 1 tile
    (5, 128, 2000, 2048, 4, 1024),
])
def test_fused_heads_sweep(n, d, vocab, vp, top_t, block_v, dtype):
    o = _rand((n, d), dtype)
    w = _rand((d, vp), dtype)
    v1, i1 = ops.fused_heads_topk(o, w, vocab=vocab, top_t=top_t,
                                  block_v=block_v, block_rows=8)
    v2, i2 = ref.heads_topk(o, w, vocab=vocab, top_t=top_t)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), **tol)
    # ids may differ only where values tie (random floats: no ties expected)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_fused_heads_never_selects_vocab_pad():
    o = jnp.ones((4, 16), jnp.float32)
    w = jnp.ones((16, 512), jnp.float32) * 10.0   # pad lanes equally huge
    _, ids = ops.fused_heads_topk(o, w, vocab=300, top_t=4, block_v=128,
                                  block_rows=8)
    assert int(jnp.max(ids)) < 300


def test_fused_heads_matches_model_argmax():
    """End-to-end: kernel top-1 == argmax of model.all_head_logits."""
    import jax

    from conftest import tiny_dense
    from repro.core.heads import heads_apply
    from repro.models import model as M

    cfg = tiny_dense()
    params = M.init(jax.random.PRNGKey(0), cfg)
    hidden = _rand((6, cfg.d_model), jnp.float32)
    logits = M.all_head_logits(params, cfg, hidden)          # (6, K, Vp)
    want = np.asarray(jnp.argmax(logits, -1))                # (6, K)

    outs = heads_apply(params["bpd_heads"], cfg, hidden,
                       identity_p1=cfg.bpd_identity_p1)      # (6, K, d)
    o = outs.reshape(-1, cfg.d_model)
    w = params["lm_head"]["w"]
    _, ids = ops.fused_heads_topk(o, w, vocab=cfg.vocab_size, top_t=1,
                                  block_v=128, block_rows=8)
    got = np.asarray(ids[:, 0]).reshape(6, cfg.bpd_k)
    np.testing.assert_array_equal(got, want)
