"""Quickstart: train a small combined scoring/proposal LM and watch
blockwise parallel decoding accept multi-token blocks.

    PYTHONPATH=src python examples/quickstart.py [--steps 300] [--k 4]

Trains a ~0.5M-param decoder-only LM on a predictable synthetic Markov
corpus, then decodes the same prompts with greedy and BPD and prints the
paper's headline numbers: identical outputs, fewer model invocations.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.core import decode as D
from repro.data.synthetic import MarkovLM
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import optimizer_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    cfg = ModelConfig(name="quickstart", num_layers=2, d_model=96,
                      num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=32,
                      bpd_k=args.k, max_seq_len=256, dtype="float32")
    tc = TrainConfig(global_batch=16, seq_len=48, lr=3e-3, warmup_steps=30,
                     head_loss="mean")
    task = MarkovLM(vocab=cfg.vocab_size, temperature=0.12, seed=3)

    print(f"[1/3] training {cfg.name} (k={args.k}) for {args.steps} steps ...")
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc))
    gen = task.batches(batch=tc.global_batch, seq_len=tc.seq_len, seed=1)
    key = jax.random.PRNGKey(1)
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step(params, opt, batch, sub)
        if (i + 1) % max(args.steps // 5, 1) == 0:
            print(f"    step {i + 1:4d}  loss {float(metrics['loss']):.3f}")

    print("[2/3] decoding: greedy vs blockwise-parallel ...")
    prompts = jnp.asarray(task.sample(np.random.default_rng(9), 8, 12))
    dec = DecodeConfig(max_new_tokens=args.max_new, block_k=args.k,
                       criterion="exact")
    bpd = jax.jit(lambda b: D.bpd_decode(params, cfg, dec, b))
    greedy = jax.jit(lambda b: D.greedy_decode(params, cfg, dec, b))
    bt, bs = bpd({"tokens": prompts})       # compile
    gt, gs = greedy({"tokens": prompts})

    t0 = time.perf_counter(); bt, bs = bpd({"tokens": prompts})
    jax.block_until_ready(bt); t_bpd = time.perf_counter() - t0
    t0 = time.perf_counter(); gt, gs = greedy({"tokens": prompts})
    jax.block_until_ready(gt); t_greedy = time.perf_counter() - t0

    n = prompts.shape[1] + args.max_new
    same = np.array_equal(np.asarray(bt[:, :n]), np.asarray(gt[:, :n]))
    print("[3/3] results")
    print(f"    outputs identical to greedy : {same}")
    print(f"    mean accepted block size k̂  : {float(bs['mean_accepted']):.2f}")
    print(f"    model invocations           : BPD {int(bs['invocations'])} "
          f"vs greedy {int(gs['invocations'])}")
    print(f"    wall-clock (CPU)            : BPD {t_bpd * 1e3:.0f}ms "
          f"vs greedy {t_greedy * 1e3:.0f}ms "
          f"({t_greedy / t_bpd:.2f}x)")
    print("    (wall-clock gains need hardware that scores k positions in "
          "parallel — a CPU serializes the verify substep, which is exactly "
          "the paper's premise; see the TPU roofline in EXPERIMENTS.md)")
    assert same


if __name__ == "__main__":
    main()
