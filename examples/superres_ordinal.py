"""Ordinal-sequence ("image super-resolution") driver — paper §7.2:
distance-based approximate acceptance (§5.2) on an output space with a
natural metric.

Default mode generates smooth curves quantized to integer levels (the 1-D
analog of raster-scan pixel intensities), trains a combined model, and
compares exact-match vs ε-distance acceptance: the approximate criterion
accepts much longer blocks at negligible reconstruction error — the
paper's Table 2 effect.  Decoding drives policy OBJECTS (PR 8 removed the
legacy ``criterion=`` shims): exact acceptance equals distance(ε=0) on
integer tokens, so ONE jitted decode with ε as a traced scalar covers
every criterion — the second criterion reuses the compiled trace instead
of paying a cold retrace.

``--grid`` runs the 2-D variant (arXiv:2507.01957-style locality-aware
image decoding): a model trained on smooth ordinal FIELDS serialized in
the progressive-lattice order decodes with the ``locality`` policy
(committed-neighbor interpolation drafts + class-boundary block schedule)
against the heads-drafted ``exact`` raster baseline — same tokens (both
exact-acceptance lossless), fewer iterations.

    PYTHONPATH=src python examples/superres_ordinal.py [--k 8] [--quick]
    PYTHONPATH=src python examples/superres_ordinal.py --grid [--quick]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.config.registry import get_policy
from repro.core import decode as D
from repro.core.policy import (DecodePolicy, DistanceAcceptor, HeadsDrafter,
                               StaticSchedule)
from repro.data.synthetic import OrdinalCurves, OrdinalField
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import freeze_mask, optimizer_init

LEVELS, SEQ, PROMPT = 64, 64, 16


def train_model(cfg, tc, gen, steps, *, params=None, init_seed=0,
                data_seed=1, mask=None):
    if params is None:
        params = M.init(jax.random.PRNGKey(init_seed), cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc, mask=mask))
    key = jax.random.PRNGKey(data_seed)
    for i in range(steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step(params, opt, batch, sub)
        if (i + 1) % max(steps // 4, 1) == 0:
            print(f"    step {i + 1:4d}  loss {float(metrics['loss']):.3f}")
    return params


def run_curves(args, steps):
    cfg = ModelConfig(name="superres", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=4, d_ff=192, vocab_size=LEVELS,
                      bpd_k=args.k, max_seq_len=256, dtype="float32")
    tc = TrainConfig(global_batch=16, seq_len=SEQ, lr=3e-3,
                     warmup_steps=max(steps // 10, 10), head_loss="mean")
    task = OrdinalCurves(levels=LEVELS, seed=0)

    print(f"[1/2] training (k={args.k}, {steps} steps) ...")
    params = train_model(cfg, tc, task.batches(batch=16, seq_len=SEQ, seed=1),
                         steps)

    print(f"[2/2] decoding {SEQ - PROMPT} levels from {PROMPT}-level prompts")
    rng = np.random.default_rng(42)
    full = task.sample(rng, 8, SEQ)
    prompts = jnp.asarray(full[:, :PROMPT])
    dec = DecodeConfig(max_new_tokens=SEQ - PROMPT, block_k=args.k)

    # ONE jitted decode, hoisted out of the criterion loop: ε rides through
    # the acceptor as a traced scalar (exact ≡ distance(ε=0) on integer
    # tokens), so every criterion shares the single compiled trace
    @jax.jit
    def decode(batch, eps):
        pol = DecodePolicy(HeadsDrafter(), DistanceAcceptor(epsilon=eps),
                           StaticSchedule(), name="distance")
        return D._bpd_decode_impl(params, cfg, dec, batch, policy=pol)

    rows = []
    for crit, eps in (("exact", 0.0), ("distance", args.epsilon)):
        toks, stats = decode({"tokens": prompts}, jnp.float32(eps))
        pred = np.asarray(toks)[:, PROMPT:SEQ].astype(int)
        mae = np.abs(pred - full[:, PROMPT:].astype(int)).mean()
        rows.append((crit, eps, float(stats["mean_accepted"]),
                     int(stats["iterations"]), mae))

    print(f"\n    {'criterion':12s} {'eps':>4s} {'mean k̂':>8s} "
          f"{'iters':>6s} {'MAE':>6s}")
    for crit, eps, khat, iters, mae in rows:
        print(f"    {crit:12s} {eps:4.1f} {khat:8.2f} {iters:6d} {mae:6.2f}")
    print("\n    (distance-based acceptance trades a tiny MAE increase for "
          "fewer decoding iterations — the paper's Table 2 effect)")


def run_grid(args, steps):
    # the regime the locality policy targets (and run_locality benches):
    # piecewise-bilinear fields, so every refinement position is exactly
    # the average of its committed parents — interpolation drafts only
    # pay off once the model has actually fit the fields, hence the
    # smaller grid/vocab and longer schedule than the 1-D curve mode
    H = W = 8
    stride, levels = 2, 16
    field = OrdinalField(levels=levels, height=H, width=W, stride=stride,
                         order="locality", bilinear=True, seed=0)
    cfg0 = ModelConfig(name="superres-grid", num_layers=2, d_model=96,
                       num_heads=4, num_kv_heads=4, d_ff=192,
                       vocab_size=levels, bpd_k=args.k, bpd_enabled=False,
                       max_seq_len=128, dtype="float32")
    tc = TrainConfig(global_batch=16, seq_len=H * W, lr=3e-3,
                     warmup_steps=max(steps // 10, 10), head_loss="mean")

    print(f"[1/3] pretraining the base on {H}x{W} bilinear ordinal fields, "
          f"locality order ({steps} steps) ...")
    params = train_model(cfg0, tc, field.batches(batch=16, seed=1), steps)

    # interpolation drafts only match the verifier's chain once the base
    # has fit the fields, so the heads ride on a frozen pretrained base
    # (same two-phase recipe run_locality benches)
    head_steps = max(steps // 3, 50)
    print(f"[2/3] attaching k={args.k} heads, frozen-base fine-tune "
          f"({head_steps} steps) ...")
    from repro.core.heads import heads_init
    cfg = cfg0.replace(bpd_enabled=True, bpd_k=args.k)
    params = dict(params)
    params["bpd_heads"] = heads_init(jax.random.PRNGKey(7), cfg,
                                     dtype=cfg.params_dtype)
    tc1 = tc.replace(warmup_steps=max(head_steps // 10, 10),
                     freeze_base=True)
    params = train_model(cfg, tc1, field.batches(batch=16, seed=2),
                         head_steps, params=params,
                         mask=freeze_mask(params, train_only_heads=True))

    rng = np.random.default_rng(42)
    grids = field.sample_grid(rng, 8)
    stream = field.serialize(grids)
    prompts = jnp.asarray(stream[:, :field.coarse_len])
    n = H * W
    dec = DecodeConfig(max_new_tokens=n - field.coarse_len, block_k=args.k,
                       image_height=H, image_width=W, locality_stride=stride)
    print(f"[3/3] decoding {n - field.coarse_len} pixels from the "
          f"{field.coarse_len}-pixel coarse lattice")

    # hoisted: one compiled decode per policy, built before the loop
    fns = {name: jax.jit(
        lambda b, p=get_policy(dec, name):
        D._bpd_decode_impl(params, cfg, dec, b, policy=p))
        for name in ("exact", "locality")}

    rows, toks_by = [], {}
    for name in ("exact", "locality"):
        toks, stats = fns[name]({"tokens": prompts})
        toks_by[name] = np.asarray(toks)[:, :n]
        mae = np.abs(field.to_grid(toks_by[name]).astype(int)
                     - grids.astype(int)).mean()
        rows.append((name, float(stats["mean_accepted"]),
                     int(stats["iterations"]), mae))

    assert np.array_equal(toks_by["exact"], toks_by["locality"]), \
        "locality must be token-identical to exact (lossless drafting)"
    print(f"\n    {'policy':12s} {'mean k̂':>8s} {'iters':>6s} {'MAE':>6s}")
    for name, khat, iters, mae in rows:
        print(f"    {name:12s} {khat:8.2f} {iters:6d} {mae:6.2f}")
    print("\n    (same tokens — exact acceptance is lossless — but "
          "committed-neighbor interpolation drafts verify in fewer "
          "iterations than raster extrapolation)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--epsilon", type=float, default=2.0)
    ap.add_argument("--grid", action="store_true",
                    help="2-D locality-aware image decoding instead of the "
                         "1-D curve comparison")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.grid:
        run_grid(args, 800 if args.quick else 1500)
    else:
        run_curves(args, 200 if args.quick else 800)


if __name__ == "__main__":
    main()
