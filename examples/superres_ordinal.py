"""Ordinal-sequence ("image super-resolution") driver — paper §7.2:
distance-based approximate acceptance (§5.2) on an output space with a
natural metric.

Generates smooth curves quantized to integer levels (the 1-D analog of
raster-scan pixel intensities), trains a combined model, and compares
exact-match vs ε-distance acceptance: the approximate criterion accepts
much longer blocks at negligible reconstruction error — the paper's
Table 2 effect.

    PYTHONPATH=src python examples/superres_ordinal.py [--k 8] [--quick]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.core import decode as D
from repro.data.synthetic import OrdinalCurves
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import optimizer_init

LEVELS, SEQ, PROMPT = 64, 64, 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--epsilon", type=float, default=2.0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    steps = 200 if args.quick else 800

    cfg = ModelConfig(name="superres", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=4, d_ff=192, vocab_size=LEVELS,
                      bpd_k=args.k, max_seq_len=256, dtype="float32")
    tc = TrainConfig(global_batch=16, seq_len=SEQ, lr=3e-3,
                     warmup_steps=max(steps // 10, 10), head_loss="mean")
    task = OrdinalCurves(levels=LEVELS, seed=0)

    print(f"[1/2] training (k={args.k}, {steps} steps) ...")
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc))
    gen = task.batches(batch=16, seq_len=SEQ, seed=1)
    key = jax.random.PRNGKey(1)
    for i in range(steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step(params, opt, batch, sub)
        if (i + 1) % max(steps // 4, 1) == 0:
            print(f"    step {i + 1:4d}  loss {float(metrics['loss']):.3f}")

    print(f"[2/2] decoding {SEQ - PROMPT} levels from {PROMPT}-level prompts")
    rng = np.random.default_rng(42)
    full = task.sample(rng, 8, SEQ)
    prompts = jnp.asarray(full[:, :PROMPT])
    rows = []
    for crit, eps in (("exact", 0.0), ("distance", args.epsilon)):
        dec = DecodeConfig(max_new_tokens=SEQ - PROMPT, block_k=args.k,
                           criterion=crit, epsilon=eps)
        toks, stats = jax.jit(
            lambda b, d=dec: D.bpd_decode(params, cfg, d, b))(
            {"tokens": prompts})
        pred = np.asarray(toks)[:, PROMPT:SEQ].astype(int)
        mae = np.abs(pred - full[:, PROMPT:].astype(int)).mean()
        rows.append((crit, eps, float(stats["mean_accepted"]),
                     int(stats["iterations"]), mae))

    print(f"\n    {'criterion':12s} {'eps':>4s} {'mean k̂':>8s} "
          f"{'iters':>6s} {'MAE':>6s}")
    for crit, eps, khat, iters, mae in rows:
        print(f"    {crit:12s} {eps:4.1f} {khat:8.2f} {iters:6d} {mae:6.2f}")
    print("\n    (distance-based acceptance trades a tiny MAE increase for "
          "fewer decoding iterations — the paper's Table 2 effect)")


if __name__ == "__main__":
    main()
