"""Serving-shaped driver: batched requests through the prefill + serve_step
API (the entry points the multi-pod dry-run lowers for decode_32k /
long_500k).

Simulates a request queue: each request is a prompt; the server prefills a
batch, then repeatedly applies ``serve_step`` — ONE blockwise-parallel
iteration per call, exactly the unit of work a production serving loop
schedules — until every row finishes.

    PYTHONPATH=src python examples/serve_bpd.py [--arch granite-3-8b]
                                                [--batch 4] [--steps 200]

The arch's reduced smoke config is used (full configs are dry-run-only on
CPU); any of the 10 assigned architectures with a decode path works.

``--continuous`` serves the same trained model through the slot-based
continuous-batching engine instead: twice as many requests as slots, with
finished slots evicted and queued requests admitted mid-flight (attention
families only).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, TrainConfig, get_config
from repro.core import decode as D
from repro.data.synthetic import MarkovLM
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import optimizer_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=150,
                    help="training steps to make proposals non-trivial")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the continuous-batching engine "
                         "(slots + mid-flight admission)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True).replace(dtype="float32")
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path "
                         "(see DESIGN.md §Arch-applicability)")
    print(f"[serve] arch={args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model} k={cfg.bpd_k})")

    # quick task-tune so the heads propose something acceptable
    task = MarkovLM(vocab=min(cfg.vocab_size, 64), temperature=0.15, seed=2)
    tc = TrainConfig(global_batch=8, seq_len=32, lr=3e-3, warmup_steps=20,
                     head_loss="mean")
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = optimizer_init(params, tc)
    train = jax.jit(steps_lib.make_train_step(cfg, tc))
    gen = task.batches(batch=8, seq_len=32, seed=1)
    key = jax.random.PRNGKey(1)
    for _ in range(args.steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        if cfg.modality == "vision_text":
            batch["patch_embeds"] = jnp.zeros(
                (8, 4, cfg.d_model), jnp.float32)
        params, opt, _ = train(params, opt, batch, sub)

    # ---- the serving loop --------------------------------------------------
    rng = np.random.default_rng(7)
    if args.continuous:
        serve_continuous(params, cfg, args, task, rng)
        return
    prompts = jnp.asarray(task.sample(rng, args.batch, 16))
    req = {"tokens": prompts}
    if cfg.modality == "vision_text":
        req["patch_embeds"] = jnp.zeros((args.batch, 4, cfg.d_model),
                                        jnp.float32)

    dec = DecodeConfig(max_new_tokens=args.max_new, block_k=cfg.bpd_k)
    print(f"[serve] prefilling batch of {args.batch} "
          f"(prompt len {prompts.shape[1]}) ...")
    prefill = jax.jit(lambda b: D.bpd_prefill_causal_lm(
        params, cfg, dec, b, max_new=args.max_new)[0])
    state = prefill(req)

    prefix = M.prefix_len(cfg, req)
    serve_step = jax.jit(steps_lib.make_serve_step(
        cfg, dec, seq_len=prompts.shape[1] + prefix, max_new=args.max_new))

    it = 0
    t0 = time.perf_counter()
    while not bool(jnp.all(state.finished)) and it < args.max_new:
        state = serve_step(params, state)
        it += 1
        done = int(jnp.sum(state.finished))
        print(f"    iter {it:3d}: generated/row = "
              f"{[int(x) for x in np.asarray(state.generated)]}  finished {done}/{args.batch}")
    dt = time.perf_counter() - t0

    total = int(jnp.sum(state.generated))
    print(f"[serve] {total} tokens in {it} iterations "
          f"({total / max(it, 1):.2f} tokens/iteration, "
          f"{dt * 1e3:.0f}ms wall on CPU)")
    print(f"[serve] per-row outputs:")
    for r in range(args.batch):
        n = int(state.text_len[r])
        print(f"    row {r}: {[int(x) for x in np.asarray(state.tokens[r, 16:n])]}")


def serve_continuous(params, cfg, args, task, rng):
    """Request traffic through the continuous-batching engine: 2× as many
    requests as slots, admitted as earlier requests finish."""
    from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                               Request, Scheduler, aggregate_stats)

    dec = DecodeConfig(max_new_tokens=args.max_new, block_k=cfg.bpd_k)
    engine = ContinuousBatchingEngine(
        params, cfg, dec, EngineConfig(num_slots=args.batch,
                                       max_prompt_len=16,
                                       max_new_cap=args.max_new))
    sched = Scheduler(engine)
    n = 2 * args.batch
    for rid in range(n):
        sched.submit(Request(
            rid=rid, prompt=task.sample(rng, 1, int(rng.integers(8, 17)))[0],
            max_new=int(rng.integers(4, args.max_new + 1))))
    print(f"[serve] continuous: {n} requests through {args.batch} slots ...")

    t0 = time.perf_counter()
    it = 0
    while not sched.drained():
        done = sched.step()
        it += 1
        for f in done:
            print(f"    iter {it:3d}: req {f.rid} done — k̂={f.mean_accepted:.2f} "
                  f"gen={f.generated} inv={f.invocations} "
                  f"out={[int(x) for x in f.tokens]}")
    stats = aggregate_stats(sched.finished, time.perf_counter() - t0)
    print(f"[serve] {stats['total_tokens']} tokens / "
          f"{stats['total_invocations']} invocations in {it} engine steps "
          f"({stats['tokens_per_sec']:.0f} tok/s, mean k̂ "
          f"{stats['mean_accepted']:.2f}, compile {engine.compile_counts()})")


if __name__ == "__main__":
    main()
