"""End-to-end MT driver (the paper's §7.1 pipeline at CPU scale):

  1. pre-train a baseline encoder-decoder transformer on cipher-translation,
  2. attach the combined scoring/proposal heads (paper Fig. 3),
  3. fine-tune on distilled data (§6.1 + §6.2, the paper's best setting),
  4. decode with blockwise parallel decoding and print a per-step trace in
     the style of the paper's §7.4 example ("Step 1: 4 tokens [...]").

    PYTHONPATH=src python examples/translate_bpd.py [--k 6] [--quick]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.core import decode as D
from repro.core.heads import heads_init
from repro.data.synthetic import PhraseMT
from repro.launch import steps as steps_lib
from repro.models import seq2seq as S
from repro.optim import optimizer_init

VOCAB, SRC_LEN, EXPAND, BATCH = 64, 8, 2, 16
TGT_LEN = SRC_LEN * EXPAND


def mt_config(k, enabled=True):
    return ModelConfig(
        name="translate-bpd", family="seq2seq", is_encoder_decoder=True,
        num_encoder_layers=2, num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=4, d_ff=192, vocab_size=VOCAB, bpd_k=k,
        bpd_enabled=enabled, max_seq_len=256, dtype="float32")


def train(cfg, params, gen, steps, *, lr, freeze=False, seed=0):
    from repro.optim import freeze_mask

    tc = TrainConfig(global_batch=BATCH, seq_len=TGT_LEN, lr=lr,
                     warmup_steps=max(steps // 10, 10),
                     head_loss="random" if cfg.bpd_enabled else "mean",
                     freeze_base=freeze,
                     detach_head_residual=cfg.bpd_enabled and not freeze)
    mask = freeze_mask(params, train_only_heads=freeze)
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc, mask=mask))
    key = jax.random.PRNGKey(seed)
    for i in range(steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step(params, opt, batch, sub)
        if (i + 1) % max(steps // 4, 1) == 0:
            print(f"    step {i + 1:4d}  loss {float(metrics['loss']):.3f}")
    return params


def noisy_batches(task, *, noise=0.15, seed=1):
    rng = np.random.default_rng(seed)
    while True:
        src, tgt = task.make_pair(rng, BATCH, SRC_LEN)
        flip = rng.random(tgt.shape) < noise
        tgt = np.where(flip, rng.integers(1, VOCAB, tgt.shape), tgt)
        yield {"src": src, "tgt": tgt.astype(np.int32)}


def trace_decode(params, cfg, dec, src_row):
    """Python-level BPD loop for one sentence, printing the paper-style
    per-step acceptance trace."""
    batch = {"src": jnp.asarray(src_row[None])}
    enc_kvs, enc_mask = S.encode(params, cfg, batch["src"])
    be = D.seq2seq_backend(cfg, enc_kvs, enc_mask)
    block_k = dec.block_k or cfg.bpd_k
    caches = S.init_caches(cfg, 1, 1 + dec.max_new_tokens, block_k)
    bos = jnp.zeros((1, 1), jnp.int32)
    hidden, caches = S.forward_hidden(params, cfg, bos, enc_kvs,
                                      enc_mask=enc_mask, caches=caches)
    logits = S.all_head_logits(params, cfg, hidden[:, -1, :])
    proposals = jnp.argmax(logits[:, :block_k, :], axis=-1)
    state = D.BPDState(
        tokens=jnp.zeros((1, 1 + dec.max_new_tokens + block_k), jnp.int32),
        text_len=jnp.ones((1,), jnp.int32),
        proposals=proposals, caches=caches,
        finished=jnp.zeros((1,), bool), iters=jnp.zeros((), jnp.int32),
        generated=jnp.zeros((1,), jnp.int32))
    step = 0
    while not bool(state.finished[0]) and step < dec.max_new_tokens:
        prev_len = int(state.text_len[0])
        state = D.bpd_iteration(params, cfg, dec, be, state, prefix_offset=0,
                                max_new=dec.max_new_tokens)
        khat = int(state.text_len[0]) - prev_len
        toks = np.asarray(state.tokens[0, prev_len:prev_len + khat])
        step += 1
        print(f"    Step {step}: {khat} token{'s' if khat > 1 else ''}  "
              f"{[int(x) for x in toks]}")
    return np.asarray(state.tokens[0, 1:int(state.text_len[0])])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    pre, ft = (150, 120) if args.quick else (800, 500)

    task = PhraseMT(vocab=VOCAB, expand=EXPAND, seed=0)

    print(f"[1/4] pre-training baseline seq2seq ({pre} steps) ...")
    cfg0 = mt_config(args.k, enabled=False)
    params = S.init(jax.random.PRNGKey(0), cfg0)
    params = train(cfg0, params, noisy_batches(task), pre, lr=3e-3)

    print("[2/4] distilling training data with teacher greedy decodes ...")
    dec1 = DecodeConfig(max_new_tokens=TGT_LEN, block_k=1, eos_id=-1)
    fn = jax.jit(lambda b: D.greedy_decode_seq2seq(params, cfg0, dec1, b)[0])
    rng = np.random.default_rng(11)
    distilled = []
    for _ in range(16 if args.quick else 48):
        src, _ = task.make_pair(rng, BATCH, SRC_LEN)
        toks = np.asarray(fn({"src": jnp.asarray(src)}))
        distilled.append({"src": src, "tgt": toks[:, :TGT_LEN]})

    print(f"[3/4] attaching k={args.k} heads + fine-tuning on distilled data "
          f"({ft} steps) ...")
    cfg = mt_config(args.k)
    params = dict(params)
    params["bpd_heads"] = heads_init(jax.random.PRNGKey(7), cfg,
                                     dtype=cfg.params_dtype)

    def distilled_gen():
        i = 0
        while True:
            yield distilled[i % len(distilled)]
            i += 1

    params = train(cfg, params, distilled_gen(), ft, lr=1e-3, seed=3)

    print("[4/4] blockwise parallel decoding trace (paper §7.4 style):")
    src, _ = task.make_pair(np.random.default_rng(99), 1, SRC_LEN)
    gold = task.gold(src[:1])[0]
    dec = DecodeConfig(max_new_tokens=TGT_LEN, block_k=args.k)
    print(f"    Input : {[int(x) for x in src[0]]}")
    out = trace_decode(params, cfg, dec, src[0])
    print(f"    Output: {[int(x) for x in out[:TGT_LEN]]}")
    print(f"    Gold  : {[int(x) for x in gold]}")
    acc = (out[:TGT_LEN] == gold).mean()
    print(f"    token accuracy vs gold: {acc:.2%}")

    # batch stats
    src, _ = task.make_pair(np.random.default_rng(5), BATCH, SRC_LEN)
    _, stats = jax.jit(lambda b: D.bpd_decode_seq2seq(params, cfg, dec, b))(
        {"src": jnp.asarray(src)})
    print(f"    batch mean accepted block size k̂ = "
          f"{float(stats['mean_accepted']):.2f} (max {args.k})")


if __name__ == "__main__":
    main()
