"""Roofline report: aggregates the dry-run JSON records (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), prints the
per-(arch × shape × mesh) three-term roofline table, and emits
experiments/roofline.csv for EXPERIMENTS.md §Roofline.

Terms (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):
    compute_s    = HLO_FLOPs / (chips * peak)
    memory_s     = HLO_bytes / (chips * hbm_bw)
    collective_s = collective_bytes / (chips * ici_bw)
"""
from __future__ import annotations

import argparse
import glob
import json
import os


PEAK_FLOPS_BF16 = 197e12   # v5e per chip
HBM_BW = 819e9
ICI_BW = 50e9


def fused_verify_estimate(b: int, k: int, vocab: int, *, top_t: int = 1,
                          dtype_bytes: int = 4) -> dict:
    """Analytic roofline for the one-pass accept kernel
    (``kernels.fused_verify``): bytes / FLOP estimates and the v5e memory
    term, for the BENCH_decode.json roofline row.

    The kernel streams the (b·k, V) verification logits exactly once
    (HBM-dominant), carrying an O(top_t) running top-T per row in VMEM;
    the accept scan epilogue touches only (b, k) integers.  The unfused
    path reads the same logits for argmax AND materializes/reads the
    (b, k) comparisons separately — the win is one pass instead of two
    plus kernel-launch fusion, so bytes here are the optimum floor.
    """
    logits_bytes = b * k * vocab * dtype_bytes
    io_bytes = logits_bytes + b * k * 4 * 3 + b * 4 * 2   # props + outputs
    # per element: compare-into-max (1) + top-T merge amortized (~top_t)
    flops = b * k * vocab * (1 + top_t)
    return {
        "bytes": float(io_bytes),
        "flops": float(flops),
        "flops_per_byte": round(flops / io_bytes, 4),
        "v5e_memory_us": round(io_bytes / HBM_BW * 1e6, 2),
        "v5e_compute_us": round(flops / PEAK_FLOPS_BF16 * 1e6, 4),
        "bottleneck": "memory_s",
    }


def recompute_terms(r):
    """Roofline terms from the raw per-device cost-analysis values.

    ``cost_analysis()`` reports the per-device SPMD module, so terms divide
    by single-chip peaks.  Records written by any dryrun version are
    normalized here so the report is always consistent."""
    if r.get("status") != "ok":
        return r
    if "flops_convention" not in r:
        # records written before the convention fix used 3× the standard
        # MODEL_FLOPS (6ND·3 for train, 6ND for inference) — normalize to
        # fwd = 2·N·D, train = 6·N·D.
        r["model_flops"] = r["model_flops"] / 3.0
        r["flops_convention"] = "2nd-fwd-6nd-train"
    terms = {
        "compute_s": r["hlo_flops"] / PEAK_FLOPS_BF16,
        "memory_s": r["hlo_bytes"] / HBM_BW,
        "collective_s": r["collectives"]["total_bytes"] / ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=terms.get)
    r["roofline"] = terms
    r["useful_flops_ratio"] = (
        r["model_flops"] / (r["hlo_flops"] * r["chips"])
        if r["hlo_flops"] else None)
    return r


def load_records(dryrun_dir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("kind", "roofline") != "roofline":
            # `dryrun.py --handoff` drops KV-handoff/donation records in the
            # same directory; they carry collective byte counts, not a
            # per-step cost analysis, so there is nothing to roofline.
            continue
        recs.append(recompute_terms(r))
    return recs


def format_row(r) -> str:
    if r["status"] != "ok":
        return (f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:11s} "
                f"{r['status'].upper()}: {r.get('reason', r.get('error', ''))[:60]}")
    t = r["roofline"]
    return (f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:11s} "
            f"C={t['compute_s'] * 1e3:9.3f}ms "
            f"M={t['memory_s'] * 1e3:9.3f}ms "
            f"X={t['collective_s'] * 1e3:9.3f}ms "
            f"dom={t['bottleneck'][:-2]:10s} "
            f"useful={r['useful_flops_ratio']:.3f}" if r.get("useful_flops_ratio")
            else "")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--csv", default="experiments/roofline.csv")
    args = ap.parse_args()

    est = fused_verify_estimate(64, 8, 32768)
    print(f"[roofline] fused-verify (b=64 k=8 V=32768): "
          f"{est['bytes'] / 2**20:.1f} MiB, {est['flops'] / 1e6:.1f} MFLOP, "
          f"{est['flops_per_byte']:.2f} FLOP/B -> {est['bottleneck']} "
          f"(v5e mem {est['v5e_memory_us']:.1f} us)")

    recs = load_records(args.dryrun_dir)
    if not recs:
        print("[roofline] no dry-run records found — run "
              "`python -m repro.launch.dryrun --all` first")
        return

    print(f"{'arch':18s} {'shape':12s} {'mesh':11s} roofline terms")
    for r in recs:
        print(format_row(r))

    ok = [r for r in recs if r["status"] == "ok"]
    with open(args.csv, "w") as f:
        f.write("arch,shape,mesh,chips,compute_s,memory_s,collective_s,"
                "bottleneck,hlo_flops,hlo_bytes,collective_bytes,"
                "model_flops,useful_flops_ratio\n")
        for r in ok:
            t = r["roofline"]
            f.write(f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
                    f"{t['compute_s']:.6e},{t['memory_s']:.6e},"
                    f"{t['collective_s']:.6e},{t['bottleneck']},"
                    f"{r['hlo_flops']:.4e},{r['hlo_bytes']:.4e},"
                    f"{r['collectives']['total_bytes']:.4e},"
                    f"{r['model_flops']:.4e},"
                    f"{r['useful_flops_ratio'] or 0:.4f}\n")
    print(f"\n[roofline] {len(ok)} OK records -> {args.csv}")

    doms = {}
    for r in ok:
        doms[r["roofline"]["bottleneck"]] = doms.get(
            r["roofline"]["bottleneck"], 0) + 1
    print(f"[roofline] bottleneck distribution: {doms}")


if __name__ == "__main__":
    main()
