"""Table 2 analog: the ordinal ("super-resolution") task, where the output
space has a natural distance metric and the §5.2 distance-based acceptance
criterion applies.

Paper claims validated:
  * exact-match with frozen heads barely speeds up image-style outputs
    (k̂ stays near 1),
  * the ε-distance criterion helps a little on its own,
  * fine-tuning helps more,
  * fine-tuning + approximate acceptance compounds (k̂ → near k).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, TrainConfig
from repro.core import decode as D
from repro.data.synthetic import OrdinalCurves
from repro.models import model as M
from repro.optim import freeze_mask

from benchmarks.workbench import attach_heads, ordinal_config, train_steps

SETTINGS = ("regular", "approximate", "finetune", "both")
PROMPT = 16


def _pretrain(levels, steps, seed=0):
    cfg = ordinal_config(levels=levels).replace(bpd_enabled=False)
    task = OrdinalCurves(levels=levels, seed=seed)
    tc = TrainConfig(global_batch=16, seq_len=64, lr=3e-3,
                     warmup_steps=max(steps // 10, 10), head_loss="mean")
    params = M.init(jax.random.PRNGKey(seed), cfg)
    params, _ = train_steps(cfg, tc, params,
                            task.batches(batch=16, seq_len=64, seed=seed + 1),
                            steps, seed=seed + 2)
    return cfg, params, task


def _eval(cfg, params, task, dec, *, n_batches=3, seed=77):
    rng = np.random.default_rng(seed)
    fn = jax.jit(lambda b: D.bpd_decode(params, cfg, dec, b))
    ks, maes = [], []
    for _ in range(n_batches):
        full = task.sample(rng, 8, PROMPT + dec.max_new_tokens)
        prompts = jnp.asarray(full[:, :PROMPT])
        toks, stats = fn({"tokens": prompts})
        pred = np.asarray(toks)[:, PROMPT:PROMPT + dec.max_new_tokens]
        maes.append(np.abs(pred.astype(int)
                           - full[:, PROMPT:].astype(int)).mean())
        ks.append(float(stats["mean_accepted"]))
    return {"mean_accepted": float(np.mean(ks)), "mae": float(np.mean(maes))}


def run(ks=(2, 4, 6, 8), *, levels=64, pretrain_steps=700, head_steps=500,
        epsilon=2.0, out_path="experiments/table2.json", verbose=True):
    cfg0, base_params, task = _pretrain(levels, pretrain_steps)
    results = {}
    cfg1, p1 = attach_heads(cfg0, base_params, 1)
    results["regular_k1"] = _eval(cfg1, p1, task,
                                  DecodeConfig(max_new_tokens=32, block_k=1))

    for k in ks:
        for setting in SETTINGS:
            cfg_k, params_k = attach_heads(cfg0, base_params, k)
            freeze = setting in ("regular", "approximate")
            tc = TrainConfig(global_batch=16, seq_len=64, lr=1e-3,
                             warmup_steps=max(head_steps // 10, 10),
                             head_loss="random", freeze_base=freeze,
                             detach_head_residual=not freeze)
            mask = freeze_mask(params_k, train_only_heads=freeze)
            params_k, _ = train_steps(
                cfg_k, tc, params_k,
                task.batches(batch=16, seq_len=64, seed=5), head_steps,
                mask=mask, seed=6)
            approx = setting in ("approximate", "both")
            dec = DecodeConfig(
                max_new_tokens=32, block_k=k,
                policy="distance" if approx else "exact",
                epsilon=epsilon if approx else 0.0)
            res = _eval(cfg_k, params_k, task, dec)
            results[f"{setting}_k{k}"] = res
            if verbose:
                print(f"[table2] k={k} {setting:11s} "
                      f"khat={res['mean_accepted']:.2f} mae={res['mae']:.2f}",
                      flush=True)

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/table2.json")
    args = ap.parse_args()
    if args.quick:
        run(ks=(2, 4), pretrain_steps=250, head_steps=200, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
