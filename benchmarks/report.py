"""Render EXPERIMENTS.md sections from the JSON artifacts.

Replaces the <!-- TABLE1 --> / <!-- TABLE2 --> / <!-- TABLE4 --> /
<!-- DRYRUN --> / <!-- ROOFLINE --> / <!-- CLAIMS --> markers with markdown
tables generated from experiments/*.json and experiments/dryrun/*.json.

    PYTHONPATH=src:. python -m benchmarks.report
"""
from __future__ import annotations

import json
import os
import re

from benchmarks.roofline import load_records

EXP = "EXPERIMENTS.md"


def _load(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def table1_md(res) -> str:
    if not res:
        return "_table1.json not present — run `python -m benchmarks.table1_block_size`_"
    ks = sorted({int(k.split("_k")[-1]) for k in res if "_k" in k})
    rows = ["| k | Regular | Distillation | Fine Tuning | Both | Both top-2 | Both top-3 |",
            "|---|---|---|---|---|---|---|"]
    for k in ks:
        def cell(name):
            r = res.get(f"{name}_k{k}")
            return (f"{r['accuracy']:.3f} / {r['mean_accepted']:.2f}"
                    if r else "—")
        rows.append(f"| {k} | {cell('regular')} | {cell('distill')} | "
                    f"{cell('finetune')} | {cell('both')} | "
                    f"{cell('both_top2')} | {cell('both_top3')} |")
    rows.append("")
    rows.append("Cell = token-accuracy vs clean gold (BLEU analog) / mean "
                "accepted block size k̂.  k = 1 rows are the greedy "
                "baselines (regular "
                f"{res['regular_k1']['accuracy']:.3f}, teacher "
                f"{res['distill_k1']['accuracy']:.3f}).")
    return "\n".join(rows)


def table2_md(res) -> str:
    if not res:
        return "_table2.json not present — run `python -m benchmarks.table2_distance`_"
    ks = sorted({int(k.split("_k")[-1]) for k in res if "_k" in k and not k.endswith("k1")})
    rows = ["| k | Regular | Approximate (ε=2) | Fine Tuning | Both |",
            "|---|---|---|---|---|"]
    for k in ks:
        def cell(name):
            r = res.get(f"{name}_k{k}")
            return (f"{r['mean_accepted']:.2f} (MAE {r['mae']:.1f})"
                    if r else "—")
        rows.append(f"| {k} | {cell('regular')} | {cell('approximate')} | "
                    f"{cell('finetune')} | {cell('both')} |")
    rows.append("")
    rows.append("Cell = mean accepted block size k̂ (larger = fewer decode "
                "iterations); MAE = reconstruction error vs the true curve.")
    return "\n".join(rows)


def table4_md(res) -> str:
    if not res:
        return "_table4.json not present — run `python -m benchmarks.table4_wallclock`_"
    rows = ["| k | mean k̂ (iteration speedup) | wall-clock speedup (CPU) | accuracy |",
            "|---|---|---|---|"]
    for key in sorted(res, key=lambda s: int(s[1:])):
        r = res[key]
        rows.append(f"| {key[1:]} | {r['mean_accepted']:.2f} | "
                    f"{r['wallclock_speedup']:.2f}x | {r['accuracy']:.3f} |")
    rows.append("")
    rows.append("CPU wall-clock serializes the verify substep, so the "
                "measured speedup is a LOWER bound on parallel-hardware "
                "speedup; the iteration column is hardware-independent "
                "(the paper's Fig. 4 x-axis).")
    return "\n".join(rows)


def claims_md(t1, t2, t4) -> str:
    if not (t1 and t2 and t4):
        return "_pending benchmark runs_"
    out = []

    def khat(res, name, k):
        r = res.get(f"{name}_k{k}")
        return r["mean_accepted"] if r else float("nan")

    ks = sorted({int(k.split("_k")[-1]) for k in t1 if k.startswith("regular_k")
                 and k != "regular_k1"})
    kb = 2 if 2 in ks else min(ks)   # scale-valid regime (see §Negative #2/#3)
    k_hi = max(ks)
    acc_reg = t1["regular_k1"]["accuracy"]
    out.append(f"* **Frozen heads speed decoding at zero quality cost**: "
               f"regular k̂ ≈ "
               f"{khat(t1, 'regular', k_hi):.2f} at every k with accuracy "
               f"pinned at the baseline {acc_reg:.3f} — the paper's central "
               f"frozen-setting claim (their k̂ saturates at 1.76).")
    out.append(f"* **Fine-tuning raises k̂ beyond frozen** (Table 1, k={kb}): "
               f"regular {khat(t1, 'regular', kb):.2f} < fine-tune "
               f"{khat(t1, 'finetune', kb):.2f}, accuracy "
               f"{t1[f'finetune_k{kb}']['accuracy']:.3f} vs baseline "
               f"{acc_reg:.3f} — the paper's FT effect.  At k ≥ 6 the "
               f"shared-trunk gradient conflict overwhelms the tiny repro "
               f"model (documented in §Negative #2): FT accuracy falls to "
               f"{t1[f'finetune_k{k_hi}']['accuracy']:.3f}, a steeper "
               f"version of the paper's own FT degradation (25.8 → 24.3 "
               f"BLEU at k=8).")
    out.append(f"* **Distillation recovers FT quality**: at k=6, fine-tune "
               f"accuracy {t1['finetune_k6']['accuracy']:.3f} vs both "
               f"{t1['both_k6']['accuracy']:.3f} — the paper's "
               f"distillation-lessens-the-drop effect (their 24.7 vs 26.2 "
               f"BLEU at k=6)." if "finetune_k6" in t1 else "")
    out.append(f"* **Top-k acceptance trades quality for k̂** (§5.1): at "
               f"k={kb} exact {t1[f'both_k{kb}']['accuracy']:.3f}/"
               f"{khat(t1, 'both', kb):.2f} vs top-2 "
               f"{t1[f'both_top2_k{kb}']['accuracy']:.3f}/"
               f"{khat(t1, 'both_top2', kb):.2f}."
               if f"both_top2_k{kb}" in t1 else "")
    t2k = max(int(k.split("_k")[-1]) for k in t2 if "_k" in k and not k.endswith("k1"))
    out.append(f"* **Ordinal task needs approximate acceptance + fine-tuning "
               f"compounded** (Table 2): at k={t2k} regular "
               f"{khat(t2, 'regular', t2k):.2f} / approx "
               f"{khat(t2, 'approximate', t2k):.2f} / FT "
               f"{khat(t2, 'finetune', t2k):.2f} / both "
               f"{khat(t2, 'both', t2k):.2f} — the paper's Table 2 ordering "
               f"(1.09 / 1.40 / 2.04 / 6.79 at k=10).")
    speeds = [(int(k[1:]), v["wallclock_speedup"]) for k, v in t4.items()]
    speeds.sort()
    out.append(f"* **Iteration reduction is monotone in k; wall-clock is "
               f"not** (Fig. 4): khat "
               f"{[round(t4[f'k{k}']['mean_accepted'], 2) for k, _ in speeds]}"
               f" vs CPU wall-clock {[round(s, 2) for _, s in speeds]}x for "
               f"k={[k for k, _ in speeds]}.")
    return "\n".join(out)


def kernels_md(bench) -> str:
    """Kernel timing rows from BENCH_decode.json.

    Rows with the ``_interp`` suffix are Pallas interpret-mode timings
    (kernel body run per grid step through the XLA interpreter on CPU):
    they establish correctness cost only, and are rendered in their own
    column — NEVER as a ratio against ``_ref`` or compiled rows, which
    would read interpreter overhead as kernel slowness.
    """
    if not bench:
        return "_BENCH_decode.json not present — run `python benchmarks/run.py --smoke`_"
    rows = bench.get("rows", {})
    kernels = {}
    for name, val in rows.items():
        if "/" in name:          # policies/… and roofline/… rows live elsewhere
            continue
        if name.endswith("_interp"):
            kernels.setdefault(name[:-len("_pallas_interp")], {})["interp"] = val
        elif name.endswith("_ref"):
            kernels.setdefault(name[:-len("_ref")], {})["ref"] = val
        elif name.endswith("_pallas"):
            kernels.setdefault(name[:-len("_pallas")], {})["compiled"] = val
    if not kernels:
        return "_no kernel rows in BENCH_decode.json_"
    out = ["| kernel | jnp oracle (µs) | Pallas compiled (µs) | "
           "Pallas interpret (µs) |",
           "|---|---|---|---|"]
    fmt = lambda v: f"{float(v):.0f}" if v is not None else "—"  # noqa: E731
    for name in sorted(kernels):
        r = kernels[name]
        out.append(f"| {name} | {fmt(r.get('ref'))} | "
                   f"{fmt(r.get('compiled'))} | {fmt(r.get('interp'))} |")
    out.append("")
    out.append("Interpret-mode timings are correctness-run costs on CPU, "
               "not kernel performance — compare kernels on the compiled "
               "column (TPU) or via the roofline estimates only.")
    est = {k.split("/")[-1]: v for k, v in rows.items()
           if k.startswith("roofline/fused_verify/")}
    if est:
        out.append("")
        out.append(f"Fused-verify analytic roofline (b=64, k=8, V=32768): "
                   f"{float(est['bytes']) / 2**20:.1f} MiB streamed once, "
                   f"{float(est['flops']) / 1e6:.1f} MFLOP "
                   f"({est['flops_per_byte']} FLOP/B) — memory-bound; v5e "
                   f"floor ≈ {est['v5e_memory_us']} µs.")
    return "\n".join(out)


def serve_slo_md(bench) -> str:
    """Serving SLO rows from BENCH_serve.json (the ``slo_*`` keys written
    by benchmarks/slo_harness.py through the real HTTP/SSE server)."""
    if not bench or "slo_poisson" not in bench:
        return ("_no slo_* rows in BENCH_serve.json — run "
                "`python benchmarks/slo_harness.py --smoke`_")
    traces = [(k[len("slo_"):], bench[k]) for k in
              ("slo_poisson", "slo_bursty", "slo_preempt", "slo_paged")
              if k in bench]
    out = ["| trace | req | TTFT p50/p99 (ms) | TPOT p50/p99 (ms) | "
           "tok/s | preempt | 429 (rate) | pool requeues |",
           "|---|---|---|---|---|---|---|---|"]
    ms = lambda v: f"{float(v) * 1e3:.1f}"  # noqa: E731
    for name, t in traces:
        out.append(
            f"| {name} | {t['requests']} | "
            f"{ms(t['ttft_p50_s'])} / {ms(t['ttft_p99_s'])} | "
            f"{ms(t['tpot_p50_s'])} / {ms(t['tpot_p99_s'])} | "
            f"{t['tokens_per_sec']:.0f} | {t['preemptions']} | "
            f"{t['rejected_429']} ({t['rejected_429_rate']:.2f}) | "
            f"{t['backpressure_requeues']} |")
    out.append("")
    out.append(f"Measured through the real HTTP/SSE server (TTFT from the "
               f"first send attempt, so 429 retries count against it).  "
               f"Quality gate: streamed tokens identical to an in-process "
               f"engine run over {bench.get('slo_quality_compared', '?')} "
               f"requests — including the preempted, pool-requeued, and "
               f"429-retried ones.")
    return "\n".join(out)


def dryrun_md(recs) -> str:
    if not recs:
        return "_no dry-run records yet_"
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] not in ("ok", "skipped")]
    meshes = sorted({r["mesh"] for r in recs})
    out = [f"Records: **{len(ok)} compiled OK**, {len(skipped)} skipped "
           f"(documented), {len(err)} errors, over meshes {meshes}.", ""]
    out.append("| arch | shape | mesh | per-device args | per-device temp | "
               "compile s | collectives |")
    out.append("|---|---|---|---|---|---|---|")
    for r in ok:
        ma = r["memory_analysis"]
        coll = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{(ma['argument_size_bytes'] or 0) / 2**30:.2f} GiB | "
            f"{(ma['temp_size_bytes'] or 0) / 2**30:.2f} GiB | "
            f"{r['compile_s']:.0f} | "
            f"{coll['total_bytes'] / 2**20:.1f} MiB "
            f"{dict(coll['counts'])} |")
    for r in skipped:
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"SKIPPED: {r['reason']} | | | |")
    for r in err:
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"ERROR: {r.get('error', '')[:80]} | | | |")
    return "\n".join(out)


def _lever(r) -> str:
    """One sentence: what would move the dominant term down (per brief)."""
    dom = r["roofline"]["bottleneck"]
    kind = r.get("kind", "")
    if dom == "collective_s":
        return ("overlap the expert all-to-all with the shared-expert matmul"
                if "moe" in r["arch"] else
                "reduce-scatter/all-gather sequence-parallel activations")
    if dom == "compute_s":
        return "MXU-aligned block shapes; drop remat recompute"
    if kind == "decode":
        return ("int8 KV cache halves the dominant cache read; larger k "
                "amortizes it over more accepted tokens")
    if kind == "prefill":
        return ("Pallas flash attention keeps score tiles in VMEM "
                "(kernels/block_attention pattern at Sq=block)")
    return ("microbatch + remat bounds activation traffic; "
            "sequence-parallel norms")


def roofline_md(recs) -> str:
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "pod16x16"]
    if not ok:
        return "_no single-pod records yet_"
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "useful FLOPs ratio | lever on the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    for r in ok:
        t = r["roofline"]
        dom = t["bottleneck"].replace("_s", "")
        note = _lever(r)
        if r.get("sliding_window") and r["shape"] == "long_500k":
            note = f"(SWA {r['sliding_window']}) " + note
        ratio = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s'] * 1e3:.2f} ms | "
            f"{t['memory_s'] * 1e3:.2f} ms | {t['collective_s'] * 1e3:.2f} ms "
            f"| **{dom}** | {ratio:.3f} | {note} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | {note} |")
    doms = {}
    for r in ok:
        d = r["roofline"]["bottleneck"]
        doms[d] = doms.get(d, 0) + 1
    out.append("")
    out.append(f"Dominant-term distribution (single-pod): {doms}.  The "
               "memory term uses the CPU backend's `bytes accessed` and is "
               "an upper bound (TPU fuses more aggressively); compute and "
               "collective terms are structural.")
    out.append("")
    out.append("All MoE rows (qwen2 / olmoe, both meshes) use the OPTIMIZED "
               "grouped expert dispatch; the pre-optimization baselines "
               "(99.5% more FLOPs, 66× the collective bytes at prefill_32k) "
               "are preserved in experiments/dryrun_moe_baseline/ and "
               "analysed in §Perf #3.")
    return "\n".join(out)


def main():
    t1 = _load("experiments/table1.json")
    t2 = _load("experiments/table2.json")
    t4 = _load("experiments/table4.json")
    bench = _load("BENCH_decode.json")
    serve = _load("BENCH_serve.json")
    recs = load_records("experiments/dryrun")

    if not os.path.exists(EXP):
        print(f"[report] {EXP} not present — printing the KERNELS and "
              f"SERVE sections instead of patching markers")
        print(kernels_md(bench))
        print()
        print(serve_slo_md(serve))
        return
    with open(EXP) as f:
        text = f.read()
    for marker, content in (
        ("TABLE1", table1_md(t1)),
        ("TABLE2", table2_md(t2)),
        ("TABLE4", table4_md(t4)),
        ("CLAIMS", claims_md(t1, t2, t4)),
        ("KERNELS", kernels_md(bench)),
        ("SERVE", serve_slo_md(serve)),
        ("DRYRUN", dryrun_md(recs)),
        ("ROOFLINE", roofline_md(recs)),
    ):
        pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\n### |\n---|\Z)",
                         re.S)
        if f"<!-- {marker} -->" in text:
            text = pat.sub(f"<!-- {marker} -->\n{content}\n", text)
    with open(EXP, "w") as f:
        f.write(text)
    print(f"[report] EXPERIMENTS.md updated "
          f"(t1={'y' if t1 else 'n'} t2={'y' if t2 else 'n'} "
          f"t4={'y' if t4 else 'n'} dryrun={len(recs)})")


if __name__ == "__main__":
    main()
