"""Table 4 / Figure 4 analog: iteration reduction vs real wall-clock speedup.

For the best MT setting (fine-tuned + distilled, per the paper) we measure,
for each k: mean accepted block size (iteration reduction) and the measured
wall-clock speedup of BPD over greedy decoding of the SAME model, plus the
quality metric.  The paper's claim: wall-clock speedup tracks k̂ but peaks
below it (the verify forward over k positions costs more than a 1-token
step), with the peak at intermediate k.

Wall-clock numbers here are CPU numbers — the *shape* of the curve
(monotone k̂, peaked speedup) is the claim under validation, not absolute
times, which belong to the TPU roofline analysis.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig

from benchmarks.workbench import (
    MTBench,
    attach_heads,
    distill_data,
    eval_mt,
    finetune_heads,
    pretrain_mt,
    time_decode,
)


def run(ks=(1, 2, 4, 6, 8), *, pretrain_steps=700, head_steps=500,
        out_path="experiments/table4.json", verbose=True):
    bench = MTBench()
    base_cfg, base_params = pretrain_mt(bench, steps=pretrain_steps)
    _, teacher = pretrain_mt(bench, steps=pretrain_steps, seed=100)
    distilled = distill_data(bench, base_cfg, teacher, n_batches=48)

    rng = np.random.default_rng(55)
    src, _ = bench.task.make_pair(rng, bench.batch, bench.src_len)
    batch = {"src": jnp.asarray(src)}

    results = {}
    t_greedy = None
    for k in ks:
        cfg_k, params_k = attach_heads(base_cfg, base_params, k)
        if k > 1:
            params_k = finetune_heads(bench, cfg_k, params_k,
                                      steps=head_steps, freeze=False,
                                      distilled=distilled)
        dec = DecodeConfig(max_new_tokens=bench.tgt_len, block_k=k)
        from repro.core.decode import bpd_decode_seq2seq, greedy_decode_seq2seq

        bpd_fn = jax.jit(lambda b, c=cfg_k, p=params_k, d=dec:
                         bpd_decode_seq2seq(p, c, d, b))
        t_bpd = time_decode(bpd_fn, batch)
        if t_greedy is None:  # greedy baseline: k=1 model, p_1-only loop
            greedy_fn = jax.jit(lambda b, c=cfg_k, p=params_k, d=dec:
                                greedy_decode_seq2seq(p, c, d, b))
            t_greedy = time_decode(greedy_fn, batch)
        quality = eval_mt(bench, cfg_k, params_k, dec=dec, n_batches=2)
        results[f"k{k}"] = {
            "mean_accepted": quality["mean_accepted"],
            "accuracy": quality["accuracy"],
            "t_bpd_s": t_bpd,
            "t_greedy_s": t_greedy,
            "wallclock_speedup": t_greedy / t_bpd,
            "iteration_speedup": quality["mean_accepted"],
        }
        if verbose:
            r = results[f"k{k}"]
            print(f"[table4] k={k} khat={r['mean_accepted']:.2f} "
                  f"wallclock={r['wallclock_speedup']:.2f}x "
                  f"acc={r['accuracy']:.3f}", flush=True)

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/table4.json")
    args = ap.parse_args()
    if args.quick:
        run(ks=(1, 2, 4), pretrain_steps=250, head_steps=200,
            out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
