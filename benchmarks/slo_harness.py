"""SLO harness: replayed traffic through the REAL HTTP/SSE server.

Where serve_throughput.py measures the engine in-process, this harness
measures the whole serving stack the way a user feels it: requests arrive
over a TCP socket on Poisson and bursty schedules, stream tokens back as
SSE events, get rejected with 429 + Retry-After when the wait queue
saturates (clients honor the hint and retry), and preempt lower-priority
work when a deadline demands it.  Two metric families come out, per trace:

  * TTFT — time to first token, measured from the FIRST send attempt (so
    back-pressure retries count against the server, as they do for users);
  * TPOT — time per output token after the first (streaming cadence).

both as p50/p99 over the trace, plus preemption / 429 / requeue counts.

The **quality gate** makes this a correctness harness too: every streamed
token sequence must be byte-identical to an in-process engine run of the
same request — including requests that were preempted mid-flight,
requeued by ``PagePoolExhausted``, or 429-retried.  A latency optimisation
that perturbs decode results fails here, not in production.

The bursty trace is engineered, not sampled: burst 0 overfills the slot
slab + wait queue (forcing 429s), then a late wave of priority-1,
deadline-already-passed requests lands while every slot is still busy
(forcing preemption).  The Poisson trace is the honest open-loop load.

    PYTHONPATH=src python benchmarks/slo_harness.py [--smoke]

``--smoke`` is the CI configuration: seconds-scale traces with the gates
enforced (quality identical, preemption + back-pressure actually
exercised, SLO rows present); results merge into BENCH_serve.json as the
``slo_*`` keys (serve_throughput.py owns the other keys).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import jax
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    EngineConfig,
    Frontend,
    HTTPServer,
    Request,
    Scheduler,
)
from repro.serving.types import percentile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MAX_RETRIES = 100


def bench_model(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(name="slo-smoke", num_layers=2, d_model=64,
                           num_heads=4, num_kv_heads=2, d_ff=128,
                           vocab_size=97, bpd_k=4, max_seq_len=512,
                           dtype="float32")
    return ModelConfig(name="slo-bench", num_layers=4, d_model=256,
                       num_heads=8, num_kv_heads=4, d_ff=512,
                       vocab_size=512, bpd_k=8, max_seq_len=2048,
                       dtype="float32")


# ---------------------------------------------------------------------------
# Traces: lists of request specs {offset, prompt, max_new, priority,
# deadline_s} replayed against the live server
# ---------------------------------------------------------------------------


def _spec(rng, offset, vocab, prompt_lens, max_new, priority=0,
          deadline_s=None):
    return {"offset": float(offset),
            "prompt": [int(t) for t in rng.integers(
                0, vocab, size=int(rng.integers(*prompt_lens)))],
            "max_new": int(max_new), "priority": priority,
            "deadline_s": deadline_s}


def make_poisson(rng, n, rate, vocab, prompt_lens, budgets):
    """Open-loop Poisson arrivals; a slice of traffic is latency-sensitive
    (priority 1 with a deadline) so preemption can fire under load."""
    offsets = np.cumsum(rng.exponential(1.0 / rate, size=n))
    out = []
    for i in range(n):
        urgent = rng.random() < 0.2
        out.append(_spec(rng, offsets[i], vocab, prompt_lens,
                         rng.choice(budgets),
                         priority=1 if urgent else 0,
                         deadline_s=0.0 if urgent else None))
    return out


def make_bursty(rng, slots, max_queue, vocab, prompt_lens, budgets):
    """Adversarial burst: overfill slots + wait queue at t=0.  The whole
    burst lands before the serve loop can retire anything, so at least
    two requests meet a full queue and get 429 + Retry-After (which the
    clients honor — their TTFT keeps counting)."""
    return [_spec(rng, 0.0, vocab, prompt_lens, max(budgets))
            for _ in range(slots + max_queue + 2)]


def make_preempt(rng, slots, cap, vocab, prompt_lens, budgets):
    """Deterministic preemption: exactly ``slots`` low-priority requests
    with the FULL generation budget (so no slot can finish early), then
    urgent priority-1 requests whose deadline is already in the past.
    The urgent clients gate on the server's own metrics (``after_busy``):
    they submit only once every slot is observably occupied and the wait
    queue is empty — the next scheduler tick then has no free slot and no
    natural admission, so the deadline check MUST evict a victim."""
    out = [_spec(rng, 0.0, vocab, prompt_lens, cap) for _ in range(slots)]
    out += [dict(_spec(rng, 0.0, vocab, prompt_lens, min(budgets),
                       priority=1, deadline_s=0.0), after_busy=True)
            for _ in range(2)]
    return out


def make_paged(rng, cap, vocab, prompt_lens):
    """Pool back-pressure: three simultaneous FULL-BUDGET requests against
    a paged server whose pool fits exactly one worst-case request — the
    page spans of any two overlap the pool, so admissions two and three
    hit ``PagePoolExhausted`` and requeue (``backpressure_requeues``)
    until the running request retires and releases its pages."""
    return [_spec(rng, 0.0, vocab, prompt_lens, cap) for _ in range(3)]


# ---------------------------------------------------------------------------
# SSE client: one coroutine per request, honoring Retry-After on 429
# ---------------------------------------------------------------------------


async def sse_client(host, port, spec, t0, results, frontend=None):
    loop = asyncio.get_running_loop()
    await asyncio.sleep(max(0.0, t0 + spec["offset"] - loop.time()))
    if spec.get("after_busy"):
        # submit only once every slot is occupied and the queue is empty
        # (see make_preempt) — bounded so a server bug fails, not hangs
        deadline = loop.time() + 30.0
        while True:
            m = frontend.metrics()
            if (m["active_slots"] >= m["num_slots"]
                    and m["queue_depth"] == 0):
                break
            if loop.time() > deadline:
                raise RuntimeError("after_busy: slots never filled")
            await asyncio.sleep(0.002)
    first_attempt = loop.time()
    retries = 0
    while True:
        body = json.dumps({
            "prompt": spec["prompt"], "max_new": spec["max_new"],
            "priority": spec["priority"], "deadline_s": spec["deadline_s"],
            "stream": True}).encode()
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(b"POST /v1/generate HTTP/1.1\r\n"
                     + f"Host: {host}\r\n".encode()
                     + f"Content-Length: {len(body)}\r\n\r\n".encode()
                     + body)
        await writer.drain()
        status_line = (await reader.readline()).decode()
        status = int(status_line.split(" ", 2)[1])
        if status == 429:
            rest = (await reader.read()).decode()
            writer.close()
            retry_after = json.loads(rest.rsplit("\r\n\r\n", 1)[-1]
                                     )["retry_after_s"]
            retries += 1
            if retries > MAX_RETRIES:
                raise RuntimeError(f"request gave up after {retries} 429s")
            await asyncio.sleep(retry_after)
            continue
        assert status == 200, f"unexpected response: {status_line!r}"
        tokens, first_tok_t, last_tok_t, done, cur = [], None, None, None, ""
        while True:
            line = (await reader.readline()).decode()
            if not line:
                break
            line = line.strip()
            if line.startswith("event: "):
                cur = line[7:]
            elif line.startswith("data: "):
                now = loop.time()
                d = json.loads(line[6:])
                if cur == "token":
                    first_tok_t = first_tok_t or now
                    last_tok_t = now
                    tokens.extend(d["tokens"])
                elif cur == "done":
                    done = d
        writer.close()
        assert done is not None, "stream ended without a done event"
        assert tokens == done["tokens"], \
            "SSE token events disagree with the done payload"
        results.append({
            "spec": spec, "tokens": tokens, "retries": retries,
            "preempted": done["preempted"],
            "ttft_s": first_tok_t - first_attempt,
            "tpot_s": ((last_tok_t - first_tok_t)
                       / max(len(tokens) - 1, 1)),
            "latency_s": last_tok_t - first_attempt,
        })
        return


# ---------------------------------------------------------------------------


def build_server(params, cfg, dec, ecfg, max_queue, mesh=None):
    engine = ContinuousBatchingEngine(params, cfg, dec, ecfg, mesh=mesh)
    sched = Scheduler(engine)
    return HTTPServer(Frontend(sched, max_queue=max_queue), port=0)


def build_disagg_server(params, cfg, dec, ecfg, max_queue, mesh=None):
    """Disaggregated server: dedicated prefill workers batch prompts and
    hand KV state to the decode group through the bounded handoff queue.
    Same slot geometry as the unified server — the trace comparison
    isolates the admission path (batched worker prefills + attach vs one
    inline forward per admit)."""
    import dataclasses

    ecfgd = dataclasses.replace(ecfg,
                                prefill_slots=max(ecfg.num_slots // 2, 2),
                                handoff_cap=2 * ecfg.num_slots)
    engine = ContinuousBatchingEngine(params, cfg, dec, ecfgd, mesh=mesh)
    sched = Scheduler(engine)
    return HTTPServer(Frontend(sched, max_queue=max_queue), port=0)


def build_paged_server(params, cfg, dec, ecfg, max_queue, mesh=None):
    """Paged-KV server whose page pool fits exactly ONE worst-case request
    (plus the trash page): concurrent admissions MUST hit
    ``PagePoolExhausted`` and requeue — the pool back-pressure path."""
    import dataclasses

    from repro.models import cache as cache_lib

    decp = dec.replace(cache_backend="paged", page_size=8)
    context_len = (cfg.num_meta_tokens + ecfg.max_prompt_len
                   + ecfg.max_new_cap)
    pages = 1 + cache_lib.pages_per_row(context_len, decp.block_k
                                        or cfg.bpd_k, decp.page_size)
    ecfgp = dataclasses.replace(ecfg, page_pool_pages=pages)
    engine = ContinuousBatchingEngine(params, cfg, decp, ecfgp, mesh=mesh)
    sched = Scheduler(engine)
    return HTTPServer(Frontend(sched, max_queue=max_queue), port=0)


async def replay(srv, specs):
    """Replay one trace against the live server; returns per-request
    results + the server-side counter deltas for this trace."""
    m0 = srv.frontend.metrics()
    results = []
    t0 = asyncio.get_running_loop().time() + 0.05
    wall0 = time.monotonic()
    await asyncio.gather(*(sse_client(srv.host, srv.port, s, t0, results,
                                      frontend=srv.frontend)
                           for s in specs))
    wall = time.monotonic() - wall0
    m1 = srv.frontend.metrics()
    return results, {
        "requests": len(specs),
        "ttft_p50_s": percentile([r["ttft_s"] for r in results], 50),
        "ttft_p99_s": percentile([r["ttft_s"] for r in results], 99),
        "tpot_p50_s": percentile([r["tpot_s"] for r in results], 50),
        "tpot_p99_s": percentile([r["tpot_s"] for r in results], 99),
        "latency_p50_s": percentile([r["latency_s"] for r in results], 50),
        "latency_p99_s": percentile([r["latency_s"] for r in results], 99),
        "tokens_per_sec": sum(len(r["tokens"]) for r in results) / wall,
        "rejected_429": int(m1["rejected_total"] - m0["rejected_total"]),
        "rejected_429_rate": (m1["rejected_total"] - m0["rejected_total"])
                             / max(len(specs), 1),
        "client_retries": sum(r["retries"] for r in results),
        "preemptions": int(m1["preemptions_total"]
                           - m0["preemptions_total"]),
        "preempted_requests": sum(1 for r in results if r["preempted"]),
        "backpressure_requeues": int(m1["backpressure_requeues_total"]
                                     - m0["backpressure_requeues_total"]),
        "wall_seconds": wall,
    }


def reference_tokens(params, cfg, dec, ecfg, all_specs, mesh=None):
    """In-process engine run of every unique request — the quality oracle.
    No HTTP, no priorities, no preemption: plain FCFS decode of the same
    prompts, which the served streams must match token-for-token."""
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg, mesh=mesh)
    sched = Scheduler(eng)
    keyed = {}
    for s in all_specs:
        keyed[(tuple(s["prompt"]), s["max_new"])] = None
    for rid, key in enumerate(keyed):
        sched.submit(Request(rid=rid, prompt=np.asarray(key[0], np.int32),
                             max_new=key[1]))
    for f in sched.run():
        key = list(keyed)[f.rid]
        keyed[key] = [int(t) for t in f.tokens]
    return keyed


def quality_gate(results, ref):
    """Every streamed sequence must equal its in-process reference —
    returns the number of compared requests (raises on any mismatch)."""
    for r in results:
        key = (tuple(r["spec"]["prompt"]), r["spec"]["max_new"])
        if r["tokens"] != ref[key]:
            raise SystemExit(
                f"QUALITY GATE FAILED: served stream "
                f"(preempted={r['preempted']}, retries={r['retries']}) "
                f"diverged from the in-process engine run\n"
                f"  served: {r['tokens']}\n  engine: {ref[key]}")
    return len(results)


async def run(smoke: bool, seed: int, mesh=None) -> dict:
    cfg = bench_model(smoke)
    slots = 2 if smoke else 4
    max_queue = 4 if smoke else 16
    budgets = (6, 12) if smoke else (8, 32, 64)
    n_poisson = 10 if smoke else 64
    rate = 4.0 if smoke else 20.0
    ecfg = EngineConfig(num_slots=slots,
                        max_prompt_len=24 if smoke else 96,
                        max_new_cap=max(budgets))
    dec = DecodeConfig(max_new_tokens=ecfg.max_new_cap, block_k=cfg.bpd_k)
    prompt_lens = (4, 9) if smoke else (16, 33)
    params = M.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)

    poisson = make_poisson(rng, n_poisson, rate, cfg.vocab_size,
                           prompt_lens, budgets)
    bursty = make_bursty(rng, slots, max_queue, cfg.vocab_size,
                         prompt_lens, budgets)
    preempt = make_preempt(rng, slots, ecfg.max_new_cap, cfg.vocab_size,
                           prompt_lens, budgets)
    paged = make_paged(rng, ecfg.max_new_cap, cfg.vocab_size, prompt_lens)

    srv = build_server(params, cfg, dec, ecfg, max_queue, mesh=mesh)
    await srv.start()
    # warm the compile caches outside the measured traces
    warm = [_spec(rng, 0.0, cfg.vocab_size, prompt_lens, 2)]
    await replay(srv, warm)
    try:
        p_results, p_stats = await replay(srv, poisson)
        b_results, b_stats = await replay(srv, bursty)
        pre_results, pre_stats = await replay(srv, preempt)
    finally:
        await srv.stop()

    srv2 = build_paged_server(params, cfg, dec, ecfg, max_queue, mesh=mesh)
    await srv2.start()
    warm2 = [_spec(rng, 0.0, cfg.vocab_size, prompt_lens, 2)]
    await replay(srv2, warm2)      # paged fns compile outside the trace
    try:
        pg_results, pg_stats = await replay(srv2, paged)
    finally:
        await srv2.stop()

    # disaggregated server, SAME Poisson arrivals as the unified trace:
    # the TTFT comparison below gates that moving admission off the decode
    # path never makes first tokens later than the unified engine served
    # them (the whole point of dedicated prefill workers)
    srv3 = build_disagg_server(params, cfg, dec, ecfg, max_queue, mesh=mesh)
    await srv3.start()
    warm3 = [_spec(rng, 0.0, cfg.vocab_size, prompt_lens, 2)]
    await replay(srv3, warm3)      # prefill/attach compile outside the trace
    try:
        d_results, d_stats = await replay(srv3, poisson)
    finally:
        await srv3.stop()

    ref = reference_tokens(params, cfg, dec, ecfg,
                           warm + warm2 + warm3
                           + poisson + bursty + preempt + paged, mesh=mesh)
    compared = sum(quality_gate(r, ref) for r in
                   (p_results, b_results, pre_results, pg_results,
                    d_results))

    traces = {"slo_poisson": p_stats, "slo_bursty": b_stats,
              "slo_preempt": pre_stats, "slo_paged": pg_stats,
              "slo_disagg_poisson": d_stats}
    return {
        "slo_config": {"model": cfg.name, "smoke": smoke, "slots": slots,
                       "max_queue": max_queue, "budgets": list(budgets),
                       "poisson_requests": n_poisson, "poisson_rate": rate,
                       "bursty_requests": len(bursty), "seed": seed},
        **traces,
        "slo_disagg_ttft_p99_vs_unified": (d_stats["ttft_p99_s"]
                                           / max(p_stats["ttft_p99_s"],
                                                 1e-9)),
        "slo_quality_compared": compared,
        "slo_quality_identical": True,       # quality_gate raised otherwise
        "slo_preemptions_total": sum(t["preemptions"]
                                     for t in traces.values()),
        "slo_rejected_429_total": sum(t["rejected_429"]
                                      for t in traces.values()),
        "slo_backpressure_requeues_total": sum(t["backpressure_requeues"]
                                               for t in traces.values()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run with the gates enforced")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="data-parallel shards (0 = no mesh)")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--mesh-pod", type=int, default=1,
                    help="pod-parallel shards; >1 builds the "
                         "('pod','data','model') mesh the disaggregated "
                         "trace places prefill workers on")
    args = ap.parse_args()

    mesh = None
    if args.mesh_data > 0:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(args.mesh_data, args.mesh_model,
                              pod=args.mesh_pod, require=True)
        print(f"[slo] mesh {dict(mesh.shape)} over {mesh.size} devices")
    res = asyncio.run(run(args.smoke, args.seed, mesh=mesh))

    traces = ("slo_poisson", "slo_bursty", "slo_preempt", "slo_paged",
              "slo_disagg_poisson")
    for trace in traces:
        st = res[trace]
        for key in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
                    "tokens_per_sec", "rejected_429", "client_retries",
                    "preemptions", "backpressure_requeues"):
            print(f"serve/{trace}/{key},{st[key]},", flush=True)
    print(f"serve/slo_quality,"
          f"identical_over_{res['slo_quality_compared']}_requests,ok")

    # CI gates: the serving layer must actually exercise its failure paths
    # in this harness (otherwise the quality gate proves nothing about
    # preemption/back-pressure), and streams must be correct
    if res["slo_preemptions_total"] < 1:
        raise SystemExit("SLO GATE: no preemption occurred — the preempt "
                         "trace must evict at least one low-priority slot")
    if res["slo_rejected_429_total"] < 1:
        raise SystemExit("SLO GATE: no 429 was served — the bursty trace "
                         "must saturate the wait queue")
    if res["slo_backpressure_requeues_total"] < 1:
        raise SystemExit("SLO GATE: no PagePoolExhausted requeue — the "
                         "paged trace must oversubscribe its page pool")
    for trace in traces:
        st = res[trace]
        if not (st["ttft_p99_s"] > 0 and st["tpot_p99_s"] > 0):
            raise SystemExit(f"SLO GATE: {trace} has degenerate TTFT/TPOT "
                             f"percentiles: {st}")
    # disaggregation gate: dedicated prefill workers must not make first
    # tokens later than the unified engine served the SAME Poisson trace.
    # "No worse" carries noise slack — p99 on a short trace is a single
    # order statistic, so allow 1.5x relative or 100 ms absolute, whichever
    # is larger, before calling it a regression
    uni, dis = res["slo_poisson"], res["slo_disagg_poisson"]
    if dis["ttft_p99_s"] > max(1.5 * uni["ttft_p99_s"],
                               uni["ttft_p99_s"] + 0.1):
        raise SystemExit(
            f"SLO GATE: disaggregated TTFT p99 {dis['ttft_p99_s']:.3f}s "
            f"regressed vs unified {uni['ttft_p99_s']:.3f}s on the same "
            f"Poisson trace — the KV-handoff admission path is adding "
            f"first-token latency")

    if args.smoke:
        st = res["slo_bursty"]
        if st["ttft_p99_s"] > 60.0 or st["tpot_p99_s"] > 5.0:
            raise SystemExit(
                f"SLO GATE: smoke latency out of bounds — TTFT p99 "
                f"{st['ttft_p99_s']:.2f}s (<= 60s), TPOT p99 "
                f"{st['tpot_p99_s']:.3f}s (<= 5s): a tiny model on CI "
                f"hardware should be far inside these")

    os.makedirs("experiments", exist_ok=True)
    name = "slo_harness_smoke" if args.smoke else "slo_harness"
    with open(f"experiments/{name}.json", "w") as f:
        json.dump(res, f, indent=2, default=str)

    if not args.smoke:
        return
    # merge the slo_* rows into the tracked perf-trajectory artifact;
    # serve_throughput.py owns the other keys (same merge discipline there)
    path = os.path.join(REPO_ROOT, "BENCH_serve.json")
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update(res)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, default=str)


if __name__ == "__main__":
    main()
