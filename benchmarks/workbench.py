"""Shared train/eval harness for the paper-table benchmarks.

Reproduces the paper's experimental pipeline at CPU scale:

  1. pre-train a baseline model on the task (heads disabled),
  2. optionally produce sequence-level distilled training data (§6.2) with
     greedy teacher decodes,
  3. attach combined scoring/proposal heads (§4/§6) and continue training
     under one of four settings — {regular, distillation} × {frozen,
     fine-tuned},
  4. evaluate mean accepted block size k̂ and task quality under a chosen
     acceptance criterion (§3 exact, §5.1 top-k, §5.2 distance).

The MT analog is the phrase-expansion translation task (each source token
expands into a multi-token target phrase — the subword-structure analog)
with label noise on the gold targets: like WMT bitext, the original data is
noisy/multi-modal, while teacher decodes are deterministic ("consistent
mode breaking"), which is exactly the property the paper credits for
distillation's larger k̂.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.core import decode as D
from repro.core import train as train_lib
from repro.data.synthetic import CipherMT, MarkovLM, OrdinalCurves
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import freeze_mask, optimizer_init


# ---------------------------------------------------------------------------
# Configs (CPU-scale stand-ins for transformer_base / img2img_transformer_b3)
# ---------------------------------------------------------------------------


def mt_config(k: int = 8, vocab: int = 64) -> ModelConfig:
    return ModelConfig(
        name="bench-mt", family="seq2seq", is_encoder_decoder=True,
        num_encoder_layers=2, num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=4, d_ff=192, vocab_size=vocab, bpd_k=k,
        max_seq_len=256, dtype="float32")


def ordinal_config(k: int = 8, levels: int = 256) -> ModelConfig:
    return ModelConfig(
        name="bench-ordinal", num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=4, d_ff=192, vocab_size=levels, bpd_k=k,
        max_seq_len=256, dtype="float32")


@dataclass
class MTBench:
    """Phrase-expansion MT (see data.synthetic.PhraseMT): target-side
    subword-like structure is what the paper's proposal heads exploit, and
    15% label noise on gold targets gives distillation its advantage
    (deterministic teacher decodes = 'consistent mode breaking')."""

    vocab: int = 64
    src_len: int = 10
    expand: int = 2
    noise: float = 0.15        # label-noise rate on gold targets
    batch: int = 16
    seed: int = 0

    def __post_init__(self):
        from repro.data.synthetic import PhraseMT

        self.task = PhraseMT(vocab=self.vocab, expand=self.expand,
                             seed=self.seed)
        self.tgt_len = self.src_len * self.expand

    def gold(self, src: np.ndarray) -> np.ndarray:
        return self.task.gold(src)

    def batches(self, *, noise: Optional[float] = None, seed: int = 1):
        noise = self.noise if noise is None else noise
        rng = np.random.default_rng(seed)
        while True:
            src, tgt = self.task.make_pair(rng, self.batch, self.src_len)
            if noise:
                flip = rng.random(tgt.shape) < noise
                rand = rng.integers(1, self.vocab, tgt.shape)
                tgt = np.where(flip, rand, tgt).astype(np.int32)
            yield {"src": src, "tgt": tgt}


# ---------------------------------------------------------------------------
# Training phases
# ---------------------------------------------------------------------------


def train_steps(cfg: ModelConfig, tc: TrainConfig, params, gen, n_steps: int,
                *, mask=None, seed: int = 0):
    opt = optimizer_init(params, tc)
    step = jax.jit(steps_lib.make_train_step(cfg, tc, mask=mask))
    key = jax.random.PRNGKey(seed)
    loss = float("nan")
    for i in range(n_steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        if tc.scheduled_sampling:
            # traced scalar: the linear gold->model anneal advances per
            # step without retracing the jitted train step
            batch["ss_ratio"] = jnp.float32(
                train_lib.scheduled_sampling_ratio(tc, i))
        params, opt, metrics = step(params, opt, batch, sub)
        loss = float(metrics["loss"])
    return params, loss


def pretrain_mt(bench: MTBench, *, steps: int, lr: float = 3e-3,
                seed: int = 0) -> Tuple[ModelConfig, Dict]:
    """Phase 1: baseline seq2seq model, heads disabled (paper's pre-trained
    transformer_base)."""
    cfg = mt_config().replace(bpd_enabled=False)
    tc = TrainConfig(global_batch=bench.batch, seq_len=bench.tgt_len, lr=lr,
                     warmup_steps=max(steps // 10, 10), head_loss="mean")
    params = S.init(jax.random.PRNGKey(seed), cfg)
    params, loss = train_steps(cfg, tc, params, bench.batches(seed=seed + 1),
                               steps, seed=seed + 2)
    return cfg, params


def attach_heads(cfg: ModelConfig, params: Dict, k: int, *, seed: int = 7
                 ) -> Tuple[ModelConfig, Dict]:
    """Insert the multi-output head layer (paper Fig. 3) into a pre-trained
    model, warm-starting everything else."""
    from repro.core.heads import heads_init

    cfg2 = cfg.replace(bpd_enabled=True, bpd_k=k)
    params = dict(params)
    params["bpd_heads"] = heads_init(jax.random.PRNGKey(seed), cfg2,
                                     dtype=cfg2.params_dtype)
    return cfg2, params


def distill_data(bench: MTBench, cfg: ModelConfig, teacher: Dict, *,
                 n_batches: int, seed: int = 11):
    """§6.2: replace gold targets with greedy teacher decodes."""
    dec = DecodeConfig(max_new_tokens=bench.tgt_len, block_k=1, eos_id=-1)
    fn = jax.jit(lambda b: D.greedy_decode_seq2seq(teacher, cfg, dec, b)[0])
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        src, _ = bench.task.make_pair(rng, bench.batch, bench.src_len)
        toks = np.asarray(fn({"src": jnp.asarray(src)}))
        out.append({"src": src, "tgt": toks[:, :bench.tgt_len]})
    return out


def finetune_heads(bench: MTBench, cfg: ModelConfig, params: Dict, *,
                   steps: int, freeze: bool, distilled=None, lr: float = 1e-3,
                   seed: int = 3) -> Dict:
    """Phase 2 under one of the four Table-1 settings.

    freeze=True  — §6.1 frozen base (heads only; base quality exactly kept).
    freeze=False — fine-tuned base with the head residual detached in the
    loss (see core.heads.head_apply_dynamic: at CPU-repro scale the residual
    gradient path collapses p_1 — teacher-forced accuracy 0.99 -> 0.58 in
    500 steps; detaching it reproduces the paper's FT behaviour: higher k̂
    at a small quality cost, measured 0.96 -> 0.93 / k̂ 1.6 -> 1.8)."""
    tc = TrainConfig(global_batch=bench.batch, seq_len=bench.tgt_len, lr=lr,
                     warmup_steps=max(steps // 10, 10), head_loss="random",
                     freeze_base=freeze,
                     detach_head_residual=not freeze)
    mask = freeze_mask(params, train_only_heads=True) if freeze else None
    if distilled is not None:
        def gen():
            i = 0
            while True:
                yield distilled[i % len(distilled)]
                i += 1
        data = gen()
    else:
        data = bench.batches(seed=seed + 1)
    params, _ = train_steps(cfg, tc, params, data, steps, mask=mask,
                            seed=seed)
    return params


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------


def eval_mt(bench: MTBench, cfg: ModelConfig, params: Dict, *,
            dec: DecodeConfig, n_batches: int = 4, seed: int = 123) -> Dict:
    """Mean accepted block size + token accuracy vs the clean gold target
    (the BLEU analog)."""
    rng = np.random.default_rng(seed)
    fn = jax.jit(lambda b: D.bpd_decode_seq2seq(params, cfg, dec, b))
    accs, ks, iters = [], [], []
    for _ in range(n_batches):
        src, _ = bench.task.make_pair(rng, bench.batch, bench.src_len)
        gold = bench.gold(src)
        toks, stats = fn({"src": jnp.asarray(src)})
        pred = np.asarray(toks)[:, :bench.tgt_len]
        accs.append((pred == gold).mean())
        ks.append(float(stats["mean_accepted"]))
        iters.append(int(stats["iterations"]))
    return {"accuracy": float(np.mean(accs)),
            "mean_accepted": float(np.mean(ks)),
            "iterations": float(np.mean(iters))}


def time_decode(fn, batch, *, repeats: int = 3) -> float:
    """Median wall-clock seconds for a jitted decode closure."""
    fn(batch)  # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(batch)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
