"""Benchmark aggregator: one entry per paper table/figure + the roofline
report.  Prints ``name,value,derived`` CSV lines and writes JSON records
under experiments/.

  table1  — BLEU-analog quality + mean accepted block size (paper Table 1)
  table2  — ordinal task, distance criterion (paper Table 2)
  table4  — iteration vs wall-clock speedup (paper Table 4 / Fig. 4)
  kernels — Pallas kernel microbenches vs their jnp oracles (CPU interpret)
  roofline— aggregated dry-run roofline terms (EXPERIMENTS.md §Roofline)
  policies— per-DecodePolicy mean-k̂ / acceptance-rate / iters-per-token
            sweep on a trained copy-task seq2seq (benchmarks/policy_sweep)

``--quick`` runs reduced step counts (CI-sized); default is the full
CPU-scale reproduction (~30-45 min).  ``--smoke`` runs only the
seconds-scale subset (kernels + roofline + policies) — the CI
benchmark-smoke job pairs it with ``benchmarks/serve_throughput.py
--smoke`` and FAILS if the ``exact`` policy's mean-k̂ regresses against
the committed ``BENCH_decode.json`` baseline, if no new drafter beats
HeadsDrafter+exact, if the distilled ``draft_model`` drafter stops
beating heads+exact, if the ``adaptive`` rows collapse back to
metric-identical-with-exact (cap never binding), if the scheduled-
sampling rows lose their lift (``ss_exact`` heads >= 1.3x the gold-
prefix ``ss_baseline`` acceptance on open-ended LM decode;
``ss_draft_model`` student beats the gold-prefix student), or if the
``locality`` image policy stops beating its raster-order twin on
iters/token at no-worse reconstruction MAE.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys

# script mode (`python benchmarks/run.py`) puts benchmarks/ itself on
# sys.path, not the repo root — add the root so `benchmarks.*` imports
# (here and inside the table modules) resolve in both invocation modes
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import time


def _bench_module(name: str):
    return importlib.import_module(f"benchmarks.{name}")


def bench_kernels(emit):
    """Microbench: kernel (interpret) vs oracle — correctness-oriented on
    CPU; the numbers that matter for TPU live in the roofline analysis.

    Row-naming discipline: Pallas timings taken in interpret mode carry an
    ``_interp`` suffix.  Interpret mode runs the kernel body per grid step
    through the XLA interpreter — those numbers say nothing about compiled
    TPU performance, so no gate may ever ratio an ``_interp`` row against
    a ``_ref`` (or future compiled) row.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref
    from repro.kernels.tree_mask import default_tree

    suffix = "" if ops.on_tpu() else "_interp"
    rng = np.random.default_rng(0)
    b, kq, h, kv, hd, l = 1, 8, 8, 2, 64, 2048
    q = jnp.asarray(rng.standard_normal((b, kq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, l, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, l, kv, hd)), jnp.float32)
    qpos = jnp.asarray(np.arange(l - kq, l)[None], jnp.int32)
    kvpos = jnp.asarray(np.arange(l)[None], jnp.int32)

    # tree-verification variant: same cache, last kq slots hold the tree
    topo = default_tree(kq, 4)
    depths = jnp.asarray(topo.depths)
    tstart = l - kq
    t_qpos = tstart + depths[None, :]
    slot = jnp.arange(l)[None, :]
    node = slot - tstart
    is_tree = node >= 0
    t_kvnode = jnp.where(is_tree, node, -1).astype(jnp.int32)
    t_kvpos = jnp.where(is_tree, tstart + depths[jnp.clip(node, 0, kq - 1)],
                        slot).astype(jnp.int32)
    anc = jnp.broadcast_to(jnp.asarray(topo.anc_bits)[None, :], (b, kq))

    # fused one-pass accept: serving-scale rows, real vocab
    fb, fk, fv = 64, 8, 32768
    logits = jnp.asarray(rng.standard_normal((fb, fk, fv)), jnp.float32)
    props = jnp.asarray(rng.integers(0, fv, (fb, fk)), jnp.int32)

    for name, fn in (
            ("verify_attention_ref",
             lambda: ref.verify_attention(q, k, v, qpos, kvpos)),
            (f"verify_attention_pallas{suffix}",
             lambda: ops.verify_attention(q, k, v, qpos, kvpos)),
            ("tree_verify_attention_ref",
             lambda: ref.tree_verify_attention(q, k, v, t_qpos, t_kvpos,
                                               t_kvnode, anc)),
            (f"tree_verify_attention_pallas{suffix}",
             lambda: ops.tree_verify_attention(q, k, v, t_qpos, t_kvpos,
                                               t_kvnode, anc)),
            ("fused_verify_ref",
             lambda: ref.fused_verify(logits, props, criterion="exact")[0]),
            (f"fused_verify_pallas{suffix}",
             lambda: ops.fused_verify(logits, props, criterion="exact")[0]),
    ):
        fn()
        t0 = time.perf_counter()
        fn().block_until_ready()
        emit(name, (time.perf_counter() - t0) * 1e6, "us_per_call")

    from benchmarks.roofline import fused_verify_estimate

    est = fused_verify_estimate(fb, fk, fv)
    for key, val in est.items():
        emit(f"roofline/fused_verify/{key}", val)


def main():
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset only (kernels + roofline) — "
                         "used by the CI benchmark-smoke job")
    ap.add_argument("--fresh", action="store_true",
                    help="re-run the table experiments even when a cached "
                         "experiments/tableN.json exists")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table4,kernels,roofline,"
                         "policies")
    args = ap.parse_args()
    which = set(args.only.split(",")) if args.only else {
        "table1", "table2", "table4", "kernels", "roofline", "policies"}
    if args.smoke:
        which &= {"kernels", "roofline", "policies"}
        if not which:
            raise SystemExit(f"--smoke only covers kernels/roofline/"
                             f"policies; --only {args.only} selects none "
                             f"of them")

    rows = {}

    def emit(name, value, derived=""):
        rows[name] = value
        print(f"{name},{value},{derived}", flush=True)

    def cached(path):
        if args.fresh or not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    if "table1" in which:
        res = cached("experiments/table1.json")
        if res is None:
            t1 = _bench_module("table1_block_size")
            res = (t1.run(ks=(2, 4), pretrain_steps=250, head_steps=200,
                          n_distill_batches=16)
                   if args.quick else t1.run())
        for key, r in sorted(res.items()):
            emit(f"table1/{key}/accuracy", f"{r['accuracy']:.4f}")
            emit(f"table1/{key}/mean_accepted", f"{r['mean_accepted']:.3f}")

    if "table2" in which:
        res = cached("experiments/table2.json")
        if res is None:
            t2 = _bench_module("table2_distance")
            res = (t2.run(ks=(2, 4), pretrain_steps=250, head_steps=200)
                   if args.quick else t2.run())
        for key, r in sorted(res.items()):
            emit(f"table2/{key}/mean_accepted", f"{r['mean_accepted']:.3f}")
            emit(f"table2/{key}/mae", f"{r['mae']:.3f}")

    if "table4" in which:
        res = cached("experiments/table4.json")
        if res is None:
            t4 = _bench_module("table4_wallclock")
            res = (t4.run(ks=(1, 2, 4), pretrain_steps=250, head_steps=200)
                   if args.quick else t4.run())
        for key, r in sorted(res.items()):
            emit(f"table4/{key}/wallclock_speedup",
                 f"{r['wallclock_speedup']:.3f}")
            emit(f"table4/{key}/mean_accepted", f"{r['mean_accepted']:.3f}")

    if "kernels" in which:
        bench_kernels(emit)

    if "policies" in which:
        sweep = _bench_module("policy_sweep")
        res = sweep.run()
        res.update(sweep.run_scheduled_sampling())
        res.update(sweep.run_locality())
        for name, r in res.items():
            for key, val in r.items():
                emit(f"policies/{name}/{key}", round(val, 4))

    if "roofline" in which:
        roofline = _bench_module("roofline")
        sys.argv = ["roofline"]
        roofline.main()

    # ---- policy regression gates (CI bench-smoke job) ----------------------
    # read the committed baseline BEFORE overwriting it below: a regression
    # must fail the job while leaving the baseline artifact intact
    bench_path = os.path.join(_ROOT, "BENCH_decode.json")
    if args.smoke and "policies" in which:
        base_rows = {}
        if os.path.exists(bench_path):
            with open(bench_path) as f:
                base_rows = json.load(f).get("rows", {})
        baseline = base_rows.get("policies/exact/mean_khat")
        new_exact = float(rows["policies/exact/mean_khat"])
        # NB: each passing smoke rewrites the baseline below, so the gate
        # bounds the PER-PR drop at 5% rather than enforcing an all-time
        # floor — deliberate, because the sweep workload/config may change
        # legitimately; reviewers see every baseline move in the
        # BENCH_decode.json diff.
        if baseline is not None and new_exact < 0.95 * float(baseline):
            raise SystemExit(
                f"POLICY REGRESSION: ExactAcceptor mean-k̂ {new_exact:.3f} "
                f"fell below the committed baseline {float(baseline):.3f} "
                f"(tolerance 5%) — the heads-drafted exact policy got "
                f"slower; see BENCH_decode.json")
        best_new = max(float(rows[f"policies/{p}/mean_khat"])
                       for p in ("input_copy", "topk_tree"))
        if best_new <= new_exact:
            raise SystemExit(
                f"DRAFTER REGRESSION: no new drafter beats "
                f"HeadsDrafter+exact (best {best_new:.3f} vs exact "
                f"{new_exact:.3f}) — input_copy/topk_tree lost their edge")
        draft = float(rows["policies/draft_model/mean_khat"])
        if draft <= new_exact:
            raise SystemExit(
                f"DRAFT-MODEL REGRESSION: the distilled draft-model "
                f"drafter (mean-k̂ {draft:.3f}) no longer beats "
                f"heads+exact ({new_exact:.3f}) — the speculative path "
                f"lost its edge (distillation, student size, or the "
                f"draft-cache sync may have regressed)")
        # tree verification must hold the ground the fused-verify PR won:
        # scoring the whole candidate tree in one forward pushed topk_tree
        # past the old chain-re-rank baseline (1.9288 -> 2.22 at block_k=8)
        tree_base = base_rows.get("policies/topk_tree/mean_khat")
        new_tree = float(rows["policies/topk_tree/mean_khat"])
        if tree_base is not None and new_tree < 0.95 * float(tree_base):
            raise SystemExit(
                f"TREE-VERIFICATION REGRESSION: topk_tree mean-k̂ "
                f"{new_tree:.3f} fell below the committed baseline "
                f"{float(tree_base):.3f} (tolerance 5%) — the one-forward "
                f"tree verification lost its edge; see BENCH_decode.json")
        # draft carry-over must keep saving sequential draft forwards
        steps_key = "policies/draft_model/draft_steps_saved"
        if steps_key in rows and float(rows[steps_key]) < 1.0:
            raise SystemExit(
                f"CARRY-OVER REGRESSION: the draft-model drafter issues "
                f"{rows['policies/draft_model/draft_steps_per_iter']} "
                f"sequential forwards per iteration — suffix carry-over "
                f"(DraftModelDrafter.carry_over) stopped saving the "
                f"catch-up step")
        # scheduled-sampling student: gentle prefix mixing must keep its
        # edge over the gold-prefix student on the speculative path
        ss_draft = float(rows["policies/ss_draft_model/mean_khat"])
        if ss_draft <= draft:
            raise SystemExit(
                f"SCHEDULED-SAMPLING STUDENT REGRESSION: the SS-trained "
                f"draft student (mean-k̂ {ss_draft:.3f}) no longer beats "
                f"the gold-prefix student ({draft:.3f}) — check "
                f"TrainConfig.scheduled_sampling / the ss_ratio=0.3 "
                f"anneal in policy_sweep.train_student")
        # scheduled-sampling heads: the exposure-bias lift on open-ended
        # LM decode (the ISSUE's headline gate: >= 1.3x the gold-prefix
        # baseline, token-identity asserted inside the sweep)
        ss_base = float(rows["policies/ss_baseline/acceptance_rate"])
        ss_new = float(rows["policies/ss_exact/acceptance_rate"])
        if ss_new < 1.3 * ss_base:
            raise SystemExit(
                f"SCHEDULED-SAMPLING REGRESSION: SS-trained heads' "
                f"acceptance rate {ss_new:.4f} is below 1.3x the "
                f"gold-prefix baseline {ss_base:.4f} — the scheduled-"
                f"sampling + self-target head fine-tune lost its "
                f"exposure-bias edge (see policy_sweep."
                f"run_scheduled_sampling)")
        # locality-aware image decoding: interpolation drafts must beat
        # the raster twin on iters/token WITHOUT giving up reconstruction
        loc_ipt = float(rows["policies/locality/iters_per_token"])
        ras_ipt = float(rows["policies/locality_raster/iters_per_token"])
        if loc_ipt >= ras_ipt:
            raise SystemExit(
                f"LOCALITY REGRESSION: the locality policy spends "
                f"{loc_ipt:.4f} iters/token vs the raster-order twin's "
                f"{ras_ipt:.4f} — committed-neighbor interpolation "
                f"stopped out-drafting raster extrapolation (see "
                f"policy_sweep.run_locality)")
        loc_mae = float(rows["policies/locality/mae"])
        ras_mae = float(rows["policies/locality_raster/mae"])
        if loc_mae > ras_mae:
            raise SystemExit(
                f"LOCALITY MAE REGRESSION: the locality arm reconstructs "
                f"at MAE {loc_mae:.4f}, worse than the raster twin's "
                f"{ras_mae:.4f} — the iters/token win no longer comes "
                f"for free")
        # per-PR regression bounds against the committed baselines for the
        # new rows (same 5% discipline as the exact/topk_tree gates above)
        loc_base = base_rows.get("policies/locality/iters_per_token")
        if loc_base is not None and loc_ipt > 1.05 * float(loc_base):
            raise SystemExit(
                f"LOCALITY BASELINE REGRESSION: iters/token {loc_ipt:.4f} "
                f"exceeds the committed baseline {float(loc_base):.4f} "
                f"by more than 5% — see BENCH_decode.json")
        ss_committed = base_rows.get("policies/ss_exact/acceptance_rate")
        if ss_committed is not None and ss_new < 0.95 * float(ss_committed):
            raise SystemExit(
                f"SS BASELINE REGRESSION: ss_exact acceptance "
                f"{ss_new:.4f} fell below the committed baseline "
                f"{float(ss_committed):.4f} (tolerance 5%) — see "
                f"BENCH_decode.json")
        # (the adaptive-cap-must-engage gate lives INSIDE sweep.run() on
        # the unrounded metrics — the rows here are rounded to 4 decimals,
        # so re-checking them would false-fire on legitimately tiny
        # differences.  NB: kernel timing rows with the `_interp` suffix
        # are interpret-mode Pallas numbers — gates must never ratio them
        # against `_ref` or compiled rows.)

    # repo-root perf-trajectory artifact (committed, so the smoke numbers
    # are diffable PR over PR; serve_throughput.py writes BENCH_serve.json).
    # Only the FULL smoke configuration writes it — a partial `--smoke
    # --only kernels` run would drop the policies rows and silently disarm
    # the regression gate for every later run against the committed file.
    if args.smoke and which == {"kernels", "roofline", "policies"}:
        with open(bench_path, "w") as f:
            json.dump({"smoke": True, "which": sorted(which), "rows": rows},
                      f, indent=2, default=str)
    elif args.smoke:
        print(f"[bench] partial smoke ({sorted(which)}): NOT rewriting "
              f"{bench_path}")


if __name__ == "__main__":
    main()
