"""Serving throughput: continuous batching vs run-to-completion batching.

Replays the same mixed-length Poisson workload through both serving modes
on the same model:

  * engine — the slot-based continuous-batching engine (repro.serving):
    finished requests are evicted and queued ones admitted mid-flight, so
    a slot never idles while work is waiting.
  * static — run-to-completion ``bpd_decode``: FCFS batches of
    ``num_slots`` requests, each batch held resident until its slowest row
    finishes (per-row budgets via ``max_new_rows``, so rows do stop at
    their own length — the waste is the dead slots, not extra tokens).

Reports aggregate tokens/sec and p50/p95 request latency for both, plus
the engine's jit cache sizes (the recompilation regression guard: admit /
step / evict must each compile exactly once regardless of traffic).

A third, **mixed-policy** row serves a Poisson workload whose requests
carry PER-REQUEST decode policies over per-policy slot groups
({exact, adaptive}), against the BEST of one-per-policy single-policy
baseline runs of the same workload — gated (in --smoke) to within 10% of
that baseline's tokens/sec with exactly one compile per group function;
the row lands in ``BENCH_serve.json`` as the ``mixed_*`` fields.

A fourth, **paged-KV** row reruns the engine workload with
``cache_backend="paged"`` and sweeps slots-vs-HBM via the cache backends'
``memory_bytes``: the ``paged_*`` fields record how many paged slots fit
the dense engine's cache budget (gated >= 4x, worst-case pool with no
prefix-sharing credit) and the equal-slot-count throughput (gated within
10% of dense in --smoke).

Device-work accounting is symmetric: ``model_calls`` counts jitted
forward executions over the full batch width — prefill + decode
iterations per static batch, admits + engine steps for the engine — so
``tokens_per_model_call`` compares the two modes on identical terms
(idle engine slots and dummy static rows both count against their mode).
Per-request k̂ is only reported for the engine, where per-request
iteration counts exist.

Prompts all use ``max_prompt_len`` tokens because the static baseline
conditions on its whole padded prompt buffer; the length mix that matters
for continuous batching is in ``max_new``.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]

``--smoke`` runs a seconds-scale configuration for CI (correctness and
compile-count checks, not a performance measurement).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine,
    DecodeSession,
    EngineConfig,
    Request,
    Scheduler,
    aggregate_stats,
)
from repro.serving.types import percentile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_model(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(name="serve-smoke", num_layers=2, d_model=64,
                           num_heads=4, num_kv_heads=2, d_ff=128,
                           vocab_size=97, bpd_k=4, max_seq_len=512,
                           dtype="float32")
    return ModelConfig(name="serve-bench", num_layers=4, d_model=256,
                       num_heads=8, num_kv_heads=4, d_ff=512,
                       vocab_size=512, bpd_k=8, max_seq_len=2048,
                       dtype="float32")


def make_workload(rng, n: int, rate: float, prompt_len: int, vocab: int,
                  budgets) -> list:
    """n requests, Poisson arrivals at ``rate`` req/s, max_new drawn from
    ``budgets`` (the mixed-length aspect that static batching wastes on)."""
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=prompt_len),
                    max_new=int(rng.choice(budgets)),
                    arrival=float(arrivals[i]))
            for i in range(n)]


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------


VT_DT = 1e-3
"""Virtual seconds per full-width model forward.

Both serving drivers replay arrivals in VIRTUAL time: the clock advances
``VT_DT`` per model forward (engine decode iterations + prefill batches,
static's fused-loop invocations) and jumps to the next arrival when
idle, instead of sleeping on the host clock.  Admission interleaving —
which requests share a batch, when slots refill — is then a function of
the workload alone, so the structural gate numbers (model calls, tokens
per call) are reproducible run-over-run; wall time only measures the
back-to-back device work, with no sleep jitter inside the window."""


def run_engine(params, cfg, dec, ecfg, reqs, *, policies=None, reps=1):
    """Drive ``reqs`` through the engine.  ``policies`` ({name: slots})
    switches on per-request decode policies: the engine partitions its
    slots into per-policy groups and each request is served by the group
    running its ``Request.policy``.

    Arrivals replay in virtual time (see ``VT_DT``), so the gated stats
    are deterministic; ``reps`` (same engine, fresh scheduler — no
    recompilation) survives for host-wall investigations and keeps the
    best replicate by tokens/sec."""
    eng = ContinuousBatchingEngine(params, cfg, dec, ecfg, policies=policies)
    # warm-up: compile every group's admit/step/evict outside the measured
    # window (one tiny request per policy group)
    warm = Scheduler(eng)
    for i, name in enumerate(eng.policy_names()):
        warm.submit(Request(rid=-1 - i, policy=name,
                            prompt=np.zeros(ecfg.max_prompt_len, np.int32),
                            max_new=2))
    warm.run()

    best = None
    for _ in range(reps):
        sched = Scheduler(eng)
        admits0, steps0 = eng.num_admits, eng.num_steps  # this rep only
        pre0, ov0 = eng.num_prefill_batches, eng.num_overlap_harvests
        bp0 = eng.num_attach_backpressure
        # phase timers restart with the measured window (warm-up compiled)
        eng.time_in_prefill = 0.0
        eng.time_in_decode_dispatch = 0.0
        eng.time_in_harvest = 0.0
        for r in reqs:                  # copies: reps stay isolated
            sched.submit(dataclasses.replace(r))

        def work():                     # model forwards so far this rep
            return eng.num_steps + (eng.num_prefill_batches
                                    if eng.disaggregated else eng.num_admits)

        vt, w_prev, ticks = 0.0, work(), 0
        t0 = time.monotonic()
        while not sched.drained():
            ticks += 1
            if ticks > 1_000_000:
                raise RuntimeError("virtual-time serving loop did not drain")
            if (not eng.has_active() and not sched.pending(vt)
                    and eng.handoff_backlog() == 0):
                vt = min(r.arrival for r in sched.queue)  # idle: next arrival
                continue
            sched.step(now=vt)
            w_now = work()
            vt += (w_now - w_prev) * VT_DT
            w_prev = w_now
        host_wall = time.monotonic() - t0
        finished = sched.finished
        # throughput in VIRTUAL time: tokens per unit of simulated device
        # time (forwards x VT_DT + arrival idle) — deterministic given the
        # workload, so the speedup gates measure scheduling structure, not
        # host dispatch jitter; the host wall rides along as its own row
        stats = aggregate_stats(finished, vt)
        stats["host_wall_seconds"] = host_wall
        # device-work accounting: unified admits are one forward each;
        # disaggregated admission costs one forward per PREFILL BATCH
        # (attach is a scatter, not a forward) — the batching is the
        # speedup
        prefills = ((eng.num_prefill_batches - pre0) if eng.disaggregated
                    else (eng.num_admits - admits0))
        stats["model_calls"] = prefills + (eng.num_steps - steps0)
        # per-phase host-time attribution: where the serving loop's wall
        # time actually went (the ledger behind the speedup gates)
        stats["time_in_prefill"] = eng.time_in_prefill
        stats["time_in_decode_dispatch"] = eng.time_in_decode_dispatch
        stats["time_in_harvest"] = eng.time_in_harvest
        stats["overlap_harvests"] = eng.num_overlap_harvests - ov0
        if eng.disaggregated:
            stats["prefill_batches"] = eng.num_prefill_batches - pre0
            stats["attach_backpressure"] = eng.num_attach_backpressure - bp0
        stats["tokens_per_model_call"] = (stats["total_tokens"]
                                          / max(stats["model_calls"], 1))
        if policies:
            stats["policy_groups"] = dict(policies)
            stats["per_policy_tokens"] = {
                n: sum(f.generated for f in finished if f.policy == n)
                for n in eng.policy_names()}
        if best is None or stats["tokens_per_sec"] > best["tokens_per_sec"]:
            best = stats
    # cache sizes AFTER every replicate: a recompile in any rep still trips
    best["compile_counts"] = eng.compile_counts()
    return best


# ---------------------------------------------------------------------------
# Static run-to-completion baseline
# ---------------------------------------------------------------------------


def run_static(params, cfg, dec, ecfg, reqs, *, reps=1):
    """FCFS batches of num_slots through the run-to-completion decode path
    (a jitted DecodeSession — the same driver the engine runs on); a batch's
    requests all complete when its slowest row does.  ``reps`` keeps the
    best replicate, symmetric with ``run_engine``."""
    s = ecfg.num_slots
    sess = DecodeSession(params, cfg, dec, jit=True)
    decode = lambda batch, budgets: sess.decode(batch,  # noqa: E731
                                               max_new_rows=budgets)

    dummy = {"tokens": jnp.zeros((s, ecfg.max_prompt_len), jnp.int32)}
    jax.block_until_ready(decode(dummy, jnp.ones((s,), jnp.int32)))  # compile

    best = None
    for _ in range(reps):
        queue = sorted(reqs, key=lambda r: r.arrival)
        total_tokens = 0
        model_calls = 0
        latencies = []
        vt = 0.0                        # same virtual clock as run_engine
        t0 = time.monotonic()
        while queue:
            if queue[0].arrival > vt:
                vt = queue[0].arrival   # idle until the next arrival
            take = [r for r in queue if r.arrival <= vt][:s]
            queue = [r for r in queue if r not in take]
            prompts = np.zeros((s, ecfg.max_prompt_len), np.int32)
            budgets = np.ones((s,), np.int32)      # dummy rows: 1 token
            for i, r in enumerate(take):
                prompts[i] = r.prompt
                budgets[i] = min(r.max_new, ecfg.max_new_cap)
            _, st = decode({"tokens": jnp.asarray(prompts)},
                           jnp.asarray(budgets))
            jax.block_until_ready(st["generated"])
            gen = np.asarray(st["generated"])
            inv = int(st["invocations"])            # prefill + iterations
            model_calls += inv
            vt += inv * VT_DT           # the batch ran to completion
            for i, r in enumerate(take):
                total_tokens += int(gen[i])
                latencies.append(vt - r.arrival)
        host_wall = time.monotonic() - t0
        stats = {
            "requests": len(reqs),
            "total_tokens": total_tokens,
            "model_calls": model_calls,
            "tokens_per_model_call": total_tokens / max(model_calls, 1),
            "tokens_per_sec": total_tokens / vt if vt else 0.0,
            "latency_p50_s": percentile(latencies, 50),
            "latency_p95_s": percentile(latencies, 95),
            "wall_seconds": vt,
            "host_wall_seconds": host_wall,
        }
        if best is None or stats["tokens_per_sec"] > best["tokens_per_sec"]:
            best = stats
    return best


# ---------------------------------------------------------------------------


def run(smoke: bool = False, requests: int = 48, slots: int = 8,
        rate: float = 100.0, seed: int = 0) -> dict:
    cfg = bench_model(smoke)
    if smoke:
        # arrivals overlapping service (rate ~ service rate): continuous
        # batching's edge is mid-flight admission into freed slots while
        # run-to-completion serializes at batch boundaries — a pure burst
        # would instead reward static's fully-fused decode loop, and an
        # arrival-starved trace collapses every ratio to 1.0.  Width 8 is
        # the smallest batch where static's padding waste (short rows
        # riding a full-width fused loop) outweighs the engine's per-step
        # dispatch overhead on the host backend.
        requests, slots, rate = min(requests, 32), min(slots, 8), 200.0
    dec = DecodeConfig(max_new_tokens=0, block_k=cfg.bpd_k)
    # steps_per_sync=4: every serving-engine run below uses windowed
    # decode — up to 4 fused iterations per dispatch with early exit the
    # moment a row finishes — so the engine keeps continuous batching's
    # slot-refill timing while approaching static's fused-loop dispatch
    # economy (tokens stay bitwise identical; see tests/test_disagg.py)
    ecfg = EngineConfig(num_slots=slots,
                        max_prompt_len=8 if smoke else 16,
                        max_new_cap=16 if smoke else 64,
                        steps_per_sync=4)
    dec = dec.replace(max_new_tokens=ecfg.max_new_cap)
    budgets = (2, 16) if smoke else (4, 16, 64)
    rng = np.random.default_rng(seed)
    reqs = make_workload(rng, requests, rate, ecfg.max_prompt_len,
                         cfg.vocab_size, budgets)
    params = M.init(jax.random.PRNGKey(seed), cfg)
    # virtual-time replay makes every gated ratio deterministic given the
    # workload, so one replicate per mode suffices (reps survives as a
    # knob for host-wall investigations)
    reps = 1

    engine_stats = run_engine(params, cfg, dec, ecfg, reqs, reps=reps)
    static_stats = run_static(params, cfg, dec, ecfg, reqs, reps=reps)

    # mixed-policy row: a Poisson workload with a PER-REQUEST decode policy
    # served by per-policy slot groups, against its own single-policy
    # baseline run of the SAME workload.  Two deliberate choices keep this
    # a measurement of the serving stack rather than of workload shape:
    #
    #   * each group is sized at the baseline's slot width, so every group
    #     step has the IDENTICAL geometry as the baseline step — the
    #     comparison isolates the grouping machinery (per-group compiled
    #     steps, round-robin dispatch, one fused sync per group step) from
    #     small-batch matmul efficiency, a hardware property;
    #   * the workload is long enough that the steady packed phase
    #     dominates each group's drain tail (the last long request
    #     decoding alone), which with a handful of requests would measure
    #     workload fragmentation instead.
    #
    # Policy heterogeneity is a scheduling change, not a decoding change,
    # so tokens/sec must stay within 10% of the best single-policy run
    # (gated in main) with zero per-step recompilation after warmup.
    mixed_n = max(requests, 64) if smoke else requests
    mreqs = make_workload(rng, mixed_n, rate, ecfg.max_prompt_len,
                          cfg.vocab_size, budgets)

    groups = {"exact": slots, "adaptive": slots}
    names = list(groups)
    # "best single-policy run": one baseline per constituent policy on the
    # same workload (every request forced to that one policy), best taken
    # by tokens/sec — the mixed run is gated against the winner
    base_runs = {}
    for name in names:
        base_reqs = [dataclasses.replace(r, policy=name) for r in mreqs]
        base_runs[name] = run_engine(params, cfg, dec, ecfg, base_reqs,
                                     policies={name: slots}, reps=reps)
    best_name = max(base_runs, key=lambda n: base_runs[n]["tokens_per_sec"])
    single_base_stats = base_runs[best_name]
    mixed_ecfg = dataclasses.replace(ecfg, num_slots=sum(groups.values()))
    # round-robin within each budget class so both groups carry the same
    # length mix (an index round-robin can hand one group most of the long
    # requests, and its drain tail would be charged to the serving stack)
    order = sorted(range(len(mreqs)), key=lambda i: (mreqs[i].max_new, i))
    pol_of = {i: names[j % len(names)] for j, i in enumerate(order)}
    mixed_reqs = [dataclasses.replace(r, policy=pol_of[i])
                  for i, r in enumerate(mreqs)]
    mixed_stats = run_engine(params, cfg, dec, mixed_ecfg, mixed_reqs,
                             policies=groups, reps=reps)

    # paged KV cache rows: the memory claim (how many concurrent slots fit
    # in the dense engine's HBM budget) and the throughput claim (paged is
    # not slower at the same slot count — it is a layout change, not a
    # compute change).  Memory is measured with the backends' own
    # ``memory_bytes`` (eval_shape over the real init, so block tables,
    # position maps and the trash page are all accounted), with the paged
    # pool at its worst case (no prefix sharing: every slot holds its full
    # page span).
    from repro.models import cache as cache_lib

    decp = dec.replace(cache_backend="paged", page_size=8)
    prefix = cfg.num_meta_tokens
    context_len = prefix + ecfg.max_prompt_len + ecfg.max_new_cap
    P = cache_lib.pages_per_row(context_len, dec.block_k, decp.page_size)

    def _dense_bytes(s):
        return cache_lib.DenseBackend().memory_bytes(
            cfg, s, context_len, dec.block_k)

    def _paged_bytes(s):
        be = cache_lib.PagedBackend(decp.page_size, num_pages=1 + s * P,
                                    managed=True)
        return be.memory_bytes(cfg, s, context_len, dec.block_k)

    hbm_budget = _dense_bytes(slots)
    paged_slots = slots
    while (paged_slots < 64 * slots
           and _paged_bytes(paged_slots + 1) <= hbm_budget):
        paged_slots += 1
    paged_stats = run_engine(params, cfg, decp, ecfg, reqs, reps=reps)

    # disaggregated prefill/decode rows: the same decode-slot geometry
    # with dedicated prefill workers feeding decode through the KV-handoff
    # queue, sized at a 1:2 prefill:decode ratio — prompts are short
    # relative to decode budgets, so half-width workers stay saturated
    # while halving the padding waste of partial prefill batches (the
    # padded worker forward always computes prefill_slots rows).  Two
    # comparisons:
    #   * the base trace, engine/disagg vs static — the serving stack must
    #     BEAT run-to-completion batching on CALL ECONOMY (tokens per
    #     full-width model forward, >= 1.05x/1.15x gates in main): batched
    #     worker prefills amortize the per-admission forward that made
    #     the unified engine lose its 0.95x smoke round, and the windowed
    #     step counts every iteration it ran so the accounting stays
    #     symmetric with static's fused loop;
    #   * an admission-heavy Poisson trace (short budgets, so prefill
    #     dominates decode), disagg vs the UNIFIED engine at equal device
    #     count — the >= 1.15x gate on the disaggregation win itself.
    disagg_ecfg = dataclasses.replace(ecfg,
                                      prefill_slots=max(slots // 2, 2))
    disagg_stats = run_engine(params, cfg, dec, disagg_ecfg, reqs,
                              reps=reps)
    disagg_n = max(requests, 32) if smoke else requests
    disagg_budgets = (2, 4) if smoke else (4, 8)
    # near-simultaneous arrivals: the disaggregation claim is about the
    # ADMISSION path (batched worker prefills vs one forward per admit),
    # so the trace must keep admission busy rather than arrival-starved
    disagg_rate = 2000.0 if smoke else rate
    dreqs = make_workload(rng, disagg_n, disagg_rate, ecfg.max_prompt_len,
                          cfg.vocab_size, disagg_budgets)
    disagg_trace_unified = run_engine(params, cfg, dec, ecfg, dreqs,
                                      reps=reps)
    disagg_trace_stats = run_engine(params, cfg, dec, disagg_ecfg, dreqs,
                                    reps=reps)

    return {
        "config": {"requests": requests, "slots": slots, "rate": rate,
                   "budgets": list(budgets), "model": cfg.name,
                   "smoke": smoke, "mixed_groups": groups,
                   "mixed_requests": mixed_n,
                   "page_size": decp.page_size, "pages_per_row": P},
        "engine": engine_stats,
        "paged": paged_stats,
        "paged_slots_at_equal_hbm": paged_slots,
        "paged_slots_ratio": paged_slots / slots,
        "dense_cache_bytes": hbm_budget,
        "dense_cache_bytes_per_slot": hbm_budget / slots,
        "paged_cache_bytes_at_equal_slots": _paged_bytes(slots),
        "paged_vs_dense_tokens_per_sec": (
            paged_stats["tokens_per_sec"]
            / max(engine_stats["tokens_per_sec"], 1e-9)),
        "static": static_stats,
        "single_base": single_base_stats,
        "single_base_policy": best_name,
        "single_base_all": {n: s["tokens_per_sec"]
                            for n, s in base_runs.items()},
        "mixed": mixed_stats,
        "disagg": disagg_stats,
        "disagg_trace": disagg_trace_stats,
        "disagg_trace_unified": disagg_trace_unified,
        "disagg_tokens_per_sec": disagg_stats["tokens_per_sec"],
        "disagg_vs_engine_tokens_per_sec": (
            disagg_trace_stats["tokens_per_sec"]
            / max(disagg_trace_unified["tokens_per_sec"], 1e-9)),
        "disagg_speedup_tokens_per_sec": (
            disagg_stats["tokens_per_sec"]
            / max(static_stats["tokens_per_sec"], 1e-9)),
        "speedup_tokens_per_sec": (engine_stats["tokens_per_sec"]
                                   / max(static_stats["tokens_per_sec"],
                                         1e-9)),
        "mixed_vs_best_single": (mixed_stats["tokens_per_sec"]
                                 / max(single_base_stats["tokens_per_sec"],
                                       1e-9)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (correctness + compile "
                         "counts, not a perf measurement)")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate, requests/sec")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    res = run(smoke=args.smoke, requests=args.requests, slots=args.slots,
              rate=args.rate, seed=args.seed)

    for mode in ("engine", "static", "mixed", "disagg"):
        st = res[mode]
        for key in ("tokens_per_sec", "latency_p50_s", "latency_p95_s",
                    "model_calls", "tokens_per_model_call", "wall_seconds"):
            print(f"serve/{mode}/{key},{st[key]},", flush=True)
    print(f"serve/engine/mean_accepted,{res['engine']['mean_accepted']},"
          f"per_request_khat")
    print(f"serve/speedup_tokens_per_sec,{res['speedup_tokens_per_sec']:.3f},"
          f"engine_vs_static")
    # per-phase host-time attribution of the engine-vs-static gap: the
    # unified engine pays one prefill FORWARD per admission; disaggregation
    # batches those into prefill-worker forwards
    for mode in ("engine", "disagg"):
        st = res[mode]
        print(f"serve/{mode}/time_in_prefill,{st['time_in_prefill']:.4f},s")
        print(f"serve/{mode}/time_in_decode_dispatch,"
              f"{st['time_in_decode_dispatch']:.4f},s")
        print(f"serve/{mode}/time_in_harvest,{st['time_in_harvest']:.4f},s")
    print(f"serve/disagg/prefill_batches,{res['disagg']['prefill_batches']},"
          f"requests={res['config']['requests']}")
    print(f"serve/disagg/overlap_harvests,"
          f"{res['disagg']['overlap_harvests']},")
    print(f"serve/disagg_speedup_tokens_per_sec,"
          f"{res['disagg_speedup_tokens_per_sec']:.3f},disagg_vs_static")
    print(f"serve/disagg_vs_engine_tokens_per_sec,"
          f"{res['disagg_vs_engine_tokens_per_sec']:.3f},"
          f"admission_heavy_trace_equal_devices")
    print(f"serve/mixed_vs_best_single,{res['mixed_vs_best_single']:.3f},"
          f"mixed_policy_groups={res['config']['mixed_groups']}_vs_"
          f"{res['single_base_policy']}")

    cc = res["engine"]["compile_counts"]
    if any(v != 1 for v in cc.values()):
        raise SystemExit(f"RECOMPILATION REGRESSION: engine jit cache sizes "
                         f"{cc} (expected 1 each)")
    print(f"serve/engine/compile_counts,{cc},ok")

    # per-request-policy gates: every group's admit/step/evict compiled
    # exactly once across the whole trafficked run (no per-step
    # recompilation after warmup), and policy slot grouping costs at most
    # 10% tokens/sec against the best single-policy run
    mcc = res["mixed"]["compile_counts"]
    if any(v != 1 for v in mcc.values()):
        raise SystemExit(f"RECOMPILATION REGRESSION (mixed-policy): engine "
                         f"jit cache sizes {mcc} (expected 1 each)")
    print(f"serve/mixed/compile_counts,{mcc},ok")

    # paged-KV gates: the layout must buy >= 4x the concurrent slots inside
    # the dense engine's HBM budget (worst-case pool, no prefix sharing
    # credited), serve the same workload within 10% of dense tokens/sec at
    # equal slot count, and never recompile under traffic
    print(f"serve/paged/tokens_per_sec,{res['paged']['tokens_per_sec']},")
    print(f"serve/paged_slots_at_equal_hbm,{res['paged_slots_at_equal_hbm']},"
          f"dense_slots={res['config']['slots']}")
    print(f"serve/paged_vs_dense_tokens_per_sec,"
          f"{res['paged_vs_dense_tokens_per_sec']:.3f},equal_slot_count")
    pcc = res["paged"]["compile_counts"]
    if any(v != 1 for v in pcc.values()):
        raise SystemExit(f"RECOMPILATION REGRESSION (paged): engine jit "
                         f"cache sizes {pcc} (expected 1 each)")
    print(f"serve/paged/compile_counts,{pcc},ok")
    if res["paged_slots_ratio"] < 4.0:
        raise SystemExit(
            f"PAGED MEMORY REGRESSION: only {res['paged_slots_at_equal_hbm']}"
            f" paged slots fit the dense {res['config']['slots']}-slot HBM "
            f"budget ({res['paged_slots_ratio']:.2f}x, need >= 4x): "
            f"{res['dense_cache_bytes_per_slot']:.0f} B/slot dense vs "
            f"{res['paged_cache_bytes_at_equal_slots'] / res['config']['slots']:.0f}"
            f" B/slot paged")
    if args.smoke and res["paged_vs_dense_tokens_per_sec"] < 0.9:
        raise SystemExit(
            f"PAGED THROUGHPUT REGRESSION: "
            f"{res['paged']['tokens_per_sec']:.1f} tok/s is "
            f"{res['paged_vs_dense_tokens_per_sec']:.2f}x dense "
            f"({res['engine']['tokens_per_sec']:.1f} tok/s) on the same "
            f"workload at equal slot count; the paged layout must cost "
            f"< 10%")

    if args.smoke and res["mixed_vs_best_single"] < 0.9:
        raise SystemExit(
            f"MIXED-POLICY THROUGHPUT REGRESSION: "
            f"{res['mixed']['tokens_per_sec']:.1f} tok/s is "
            f"{res['mixed_vs_best_single']:.2f}x the best single-policy "
            f"run ({res['single_base_policy']}: "
            f"{res['single_base']['tokens_per_sec']:.1f} tok/s on the "
            f"same workload); per-request policies must cost < 10%")

    # disaggregation gates: engine jit caches stay compile-once, the
    # disaggregated engine beats the unified one >= 1.15x on the
    # admission-heavy Poisson trace at equal device count, and the serving
    # stack's best mode now beats run-to-completion static batching
    # (the historical engine<static smoke regression, attributed above by
    # the per-phase timers to per-admission prefill dispatch)
    dcc = res["disagg"]["compile_counts"]
    if any(v != 1 for v in dcc.values()):
        raise SystemExit(f"RECOMPILATION REGRESSION (disagg): engine jit "
                         f"cache sizes {dcc} (expected 1 each)")
    print(f"serve/disagg/compile_counts,{dcc},ok")
    if args.smoke and res["disagg_vs_engine_tokens_per_sec"] < 1.15:
        raise SystemExit(
            f"DISAGGREGATION REGRESSION: "
            f"{res['disagg_trace']['tokens_per_sec']:.1f} tok/s is only "
            f"{res['disagg_vs_engine_tokens_per_sec']:.2f}x the unified "
            f"engine ({res['disagg_trace_unified']['tokens_per_sec']:.1f} "
            f"tok/s) on the admission-heavy Poisson trace at equal device "
            f"count; prefill/decode disaggregation must buy >= 1.15x")
    # engine-vs-static on the base trace.  Virtual-time replay makes both
    # the throughput ratios (tokens per unit of simulated device time)
    # and the call-economy ratios (tokens per full-width model forward,
    # with the windowed step counting every iteration it ran) fully
    # deterministic given the workload — identical runs print identical
    # numbers — so these gate exactly, with no wall-clock noise margin.
    e_tpmc = (res["engine"]["tokens_per_model_call"]
              / max(res["static"]["tokens_per_model_call"], 1e-9))
    d_tpmc = (res["disagg"]["tokens_per_model_call"]
              / max(res["static"]["tokens_per_model_call"], 1e-9))
    print(f"serve/engine_vs_static_tokens_per_model_call,{e_tpmc:.3f},"
          f"call_economy")
    print(f"serve/disagg_vs_static_tokens_per_model_call,{d_tpmc:.3f},"
          f"call_economy")
    if args.smoke and e_tpmc < 1.05:
        raise SystemExit(
            f"SERVING CALL-ECONOMY REGRESSION: engine commits "
            f"{res['engine']['tokens_per_model_call']:.2f} tokens per "
            f"model forward vs static {res['static']['tokens_per_model_call']:.2f} "
            f"({e_tpmc:.2f}x): continuous batching must waste fewer "
            f"full-width forwards than run-to-completion padding (>= 1.05x)")
    if args.smoke and d_tpmc < 1.10:
        raise SystemExit(
            f"SERVING CALL-ECONOMY REGRESSION (disagg): "
            f"{res['disagg']['tokens_per_model_call']:.2f} tokens per model "
            f"forward vs static {res['static']['tokens_per_model_call']:.2f} "
            f"({d_tpmc:.2f}x): batched worker prefills must keep the "
            f"engine's call economy past 1.10x static")
    if args.smoke and res["disagg_speedup_tokens_per_sec"] < 1.0:
        raise SystemExit(
            f"SERVING SPEEDUP REGRESSION: disaggregated engine "
            f"{res['disagg']['tokens_per_sec']:.1f} tok/s vs static "
            f"{res['static']['tokens_per_sec']:.1f} tok/s "
            f"({res['disagg_speedup_tokens_per_sec']:.2f}x): continuous "
            f"batching with disaggregated prefill must beat "
            f"run-to-completion batching (>= 1.0x)")
    if args.smoke and res["speedup_tokens_per_sec"] < 1.0:
        raise SystemExit(
            f"SERVING SPEEDUP REGRESSION: unified engine "
            f"{res['engine']['tokens_per_sec']:.1f} tok/s vs static "
            f"{res['static']['tokens_per_sec']:.1f} tok/s "
            f"({res['speedup_tokens_per_sec']:.2f}x): continuous batching "
            f"must beat run-to-completion batching (>= 1.0x)")

    os.makedirs("experiments", exist_ok=True)
    # smoke runs get their own artifact so a CI-sized run never clobbers
    # saved full-benchmark numbers
    name = "serve_throughput_smoke" if args.smoke else "serve_throughput"
    with open(f"experiments/{name}.json", "w") as f:
        json.dump(res, f, indent=2, default=str)

    # repo-root perf-trajectory artifact (tracked in git so every PR's smoke
    # run appends to the history via the diff); full runs keep their own
    # experiments/ record and never clobber the committed smoke baseline
    if not args.smoke:
        return
    bench = {
        "smoke": args.smoke,
        "engine_tokens_per_sec": res["engine"]["tokens_per_sec"],
        "static_tokens_per_sec": res["static"]["tokens_per_sec"],
        "speedup_tokens_per_sec": res["speedup_tokens_per_sec"],
        "engine_tokens_per_model_call": res["engine"]["tokens_per_model_call"],
        "static_tokens_per_model_call": res["static"]["tokens_per_model_call"],
        "engine_mean_accepted": res["engine"]["mean_accepted"],
        "compile_counts": cc,
        "mixed_tokens_per_sec": res["mixed"]["tokens_per_sec"],
        "mixed_vs_best_single": res["mixed_vs_best_single"],
        "best_single_policy": res["single_base_policy"],
        "single_policy_tokens_per_sec": res["single_base_all"],
        "mixed_policy_groups": res["config"]["mixed_groups"],
        "mixed_per_policy_tokens": res["mixed"]["per_policy_tokens"],
        "mixed_compile_counts": mcc,
        "paged_tokens_per_sec": res["paged"]["tokens_per_sec"],
        "paged_vs_dense_tokens_per_sec": res["paged_vs_dense_tokens_per_sec"],
        "paged_slots_at_equal_hbm": res["paged_slots_at_equal_hbm"],
        "paged_slots_ratio": res["paged_slots_ratio"],
        "paged_dense_cache_bytes_per_slot": res["dense_cache_bytes_per_slot"],
        "paged_cache_bytes_at_equal_slots":
            res["paged_cache_bytes_at_equal_slots"],
        "paged_compile_counts": pcc,
        "disagg_tokens_per_sec": res["disagg_tokens_per_sec"],
        "disagg_tokens_per_model_call":
            res["disagg"]["tokens_per_model_call"],
        "engine_vs_static_tokens_per_model_call": e_tpmc,
        "disagg_vs_static_tokens_per_model_call": d_tpmc,
        "disagg_speedup_tokens_per_sec": res["disagg_speedup_tokens_per_sec"],
        "disagg_vs_engine_tokens_per_sec":
            res["disagg_vs_engine_tokens_per_sec"],
        "disagg_prefill_batches": res["disagg"]["prefill_batches"],
        "disagg_overlap_harvests": res["disagg"]["overlap_harvests"],
        "disagg_compile_counts": dcc,
        "engine_time_in_prefill": res["engine"]["time_in_prefill"],
        "engine_time_in_decode_dispatch":
            res["engine"]["time_in_decode_dispatch"],
        "engine_time_in_harvest": res["engine"]["time_in_harvest"],
        "disagg_time_in_prefill": res["disagg"]["time_in_prefill"],
        "disagg_time_in_decode_dispatch":
            res["disagg"]["time_in_decode_dispatch"],
        "disagg_time_in_harvest": res["disagg"]["time_in_harvest"],
        "config": res["config"],
    }
    # merge-write: BENCH_serve.json is shared with slo_harness.py (the
    # slo_* keys) — each benchmark owns its keys and must not clobber the
    # other's rows
    path = os.path.join(REPO_ROOT, "BENCH_serve.json")
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update(bench)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, default=str)


if __name__ == "__main__":
    main()
