"""Per-policy decode sweep: mean-k̂ / acceptance-rate / iters-per-token for
every registered DecodePolicy on a seconds-scale trained copy-task seq2seq.

The task is deliberately the Aggressive-Decoding regime (target == source):
a briefly pre-trained base model decodes it near-perfectly with p_1 alone,
while the prediction heads get only a short fine-tune — so the sweep
separates the policy axes the API exposes:

  * ``exact`` / ``topk`` / ``distance`` — the legacy acceptor criteria over
    ``HeadsDrafter`` (paper §3, §5.1, §5.2);
  * ``adaptive`` — the k̂-driven dynamic block schedule.  The sweep config
    (block_k = 8, 24-token outputs) is sized so the cap actually ENGAGES:
    mid-quality heads accept ≈1.5/8 per iteration, the running-rate EMA
    falls through the shrink threshold within a few iterations, and the
    shrunken cap clamps the occasional long accepted prefix — so the
    adaptive rows must differ from ``exact`` (asserted here and gated in
    CI; at the old block_k = 4 smoke config the cap never bound and the
    rows were metric-identical to ``exact``);
  * ``input_copy`` — source-sentence drafts (arXiv:2205.10350): on this
    workload it must beat ``HeadsDrafter``+exact on mean-k̂, which the CI
    bench-smoke asserts;
  * ``topk_tree`` — per-slot candidate re-ranking against p_1's chain
    logits (arXiv:2404.09221-style draft improvement);
  * ``draft_model`` — the speculative draft-model drafter: a 2-layer
    causal student DISTILLED from the trained workbench teacher
    (``core.distill.distill_seq2seq_to_causal_batches``, paper §6.2 reuse)
    proposes the block autoregressively through its own ``ModelBundle``;
    CI gates that it beats heads+exact on mean-k̂.

Everything is seeded and CPU-deterministic; ``benchmarks/run.py --smoke``
folds the rows into ``BENCH_decode.json`` and gates the committed
``exact`` mean-k̂ baseline against regressions.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.workbench import attach_heads, train_steps
from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.core.bundle import ModelBundle
from repro.core.distill import distill_seq2seq_to_causal_batches
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import freeze_mask

VOCAB, SRC_LEN, BATCH = 48, 24, 32

# the sweep order is the report order; exact is the gated baseline
POLICIES = ("exact", "topk", "distance", "adaptive", "input_copy",
            "topk_tree", "draft_model")

# exact-acceptance policies: token-identical to exact by construction
LOSSLESS = ("adaptive", "input_copy", "topk_tree", "draft_model")


def _config(k: int, enabled: bool = True) -> ModelConfig:
    return ModelConfig(
        name="policy-sweep", family="seq2seq", is_encoder_decoder=True,
        num_encoder_layers=1, num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=VOCAB, bpd_k=k,
        bpd_enabled=enabled, max_seq_len=256, dtype="float32")


def _draft_config() -> ModelConfig:
    """The distilled student: a 2-layer causal LM (no encoder, no heads —
    p_1 only), decode-cheap relative to the verify forward."""
    return ModelConfig(
        name="policy-sweep-draft", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=VOCAB, bpd_enabled=False,
        max_seq_len=256, dtype="float32")


def _copy_task(seed: int = 0):
    """Low-entropy Markov source with target == source.

    Source drafts are exact (the Aggressive-Decoding regime), AND the
    target inherits the chain's redundancy — so frozen-base prediction
    heads have something learnable (unlike a uniform copy task, cf. the
    ``PhraseMT`` docstring), the ``exact`` baseline sits measurably above
    its k̂ = 1 floor (giving the CI regression gate slack to fire), and a
    small causal student can learn the teacher's output distribution
    without ever seeing the source.  Token 0 is reserved (BOS/PAD), hence
    the +1 shift.
    """
    from repro.data.synthetic import MarkovLM

    return MarkovLM(vocab=VOCAB - 1, temperature=0.12, seed=seed)


def _copy_batches(seed: int, task=None):
    task = task or _copy_task()
    rng = np.random.default_rng(seed)
    while True:
        src = (task.sample(rng, BATCH, SRC_LEN) + 1).astype(np.int32)
        yield {"src": src, "tgt": src.copy()}


def build_model(k: int = 8, *, pretrain_steps: int = 600,
                head_steps: int = 300, seed: int = 0):
    """Pre-train the base model on the copy task, then attach heads (the
    shared ``benchmarks.workbench`` harness) with a frozen-base fine-tune
    sized so the heads are mid-quality: good enough that ``exact`` sits
    measurably above its k̂ = 1 floor (the CI regression gate needs slack
    below the baseline), short enough that p_1's source-copy knowledge
    stays far ahead of them — the regime where the draft source is the
    high-leverage knob."""
    cfg0 = _config(k, enabled=False)
    tc0 = TrainConfig(global_batch=BATCH, seq_len=SRC_LEN, lr=3e-3,
                      warmup_steps=max(pretrain_steps // 10, 5),
                      head_loss="mean")
    params = S.init(jax.random.PRNGKey(seed), cfg0)
    params, _ = train_steps(cfg0, tc0, params, _copy_batches(seed + 1),
                            pretrain_steps, seed=seed)
    cfg, params = attach_heads(cfg0, params, k, seed=seed + 7)
    tc1 = TrainConfig(global_batch=BATCH, seq_len=SRC_LEN, lr=3e-3,
                      warmup_steps=max(head_steps // 10, 5),
                      head_loss="mean", freeze_base=True)
    params, _ = train_steps(cfg, tc1, params, _copy_batches(seed + 2),
                            head_steps, seed=seed + 3,
                            mask=freeze_mask(params, train_only_heads=True))
    return cfg, params


def build_draft_student(cfg, params, *, n_distill_batches: int = 64,
                        student_steps: int = 900, seed: int = 0):
    """§6.2 reuse: greedy teacher decodes -> BOS-prefixed causal streams ->
    a 2-layer student LM trained on them (the ``draft`` ModelBundle)."""
    rng = np.random.default_rng(seed + 31)
    task = _copy_task()
    srcs = [(task.sample(rng, BATCH, SRC_LEN) + 1).astype(np.int32)
            for _ in range(n_distill_batches)]
    distilled = distill_seq2seq_to_causal_batches(params, cfg, srcs,
                                                  max_new=SRC_LEN)
    dcfg = _draft_config()
    dparams = M.init(jax.random.PRNGKey(seed + 13), dcfg)
    tc = TrainConfig(global_batch=BATCH, seq_len=SRC_LEN + 1, lr=3e-3,
                     warmup_steps=max(student_steps // 10, 5),
                     head_loss="mean")

    def gen():
        i = 0
        while True:
            yield distilled[i % len(distilled)]
            i += 1

    dparams, _ = train_steps(dcfg, tc, dparams, gen(), student_steps,
                             seed=seed + 17)
    return dcfg, dparams


def run(*, k: int = 8, seed: int = 0, pretrain_steps: int = 900,
        head_steps: int = 300, student_steps: int = 900,
        eval_rows: int = 16) -> dict:
    cfg, params = build_model(k, pretrain_steps=pretrain_steps,
                              head_steps=head_steps, seed=seed)
    dcfg, dparams = build_draft_student(cfg, params,
                                        student_steps=student_steps,
                                        seed=seed)
    rng = np.random.default_rng(seed + 11)
    src = (_copy_task().sample(rng, eval_rows, SRC_LEN) + 1).astype(np.int32)

    from repro.serving import DecodeSession

    results = {}
    ref_tokens = None
    for name in POLICIES:
        dec = DecodeConfig(max_new_tokens=SRC_LEN, block_k=k, policy=name,
                           top_k=2, epsilon=2.0)
        bundles = ({"draft": ModelBundle(dparams, dcfg)}
                   if name == "draft_model" else None)
        # decode row-by-row (one jit per policy, geometry (1, SRC_LEN)):
        # the batched loop's global iteration count is gated by its slowest
        # row, which would floor mean-k̂ at 1.0 whenever ANY row rejects
        # everything — per-row decodes measure the honest k̂ distribution
        sess = DecodeSession(params, cfg, dec, jit=True, bundles=bundles)
        toks, iters, gen = [], [], []
        for r in range(eval_rows):
            t, stats = sess.decode_seq2seq({"src": jnp.asarray(src[r:r + 1])})
            toks.append(np.asarray(t[0, :SRC_LEN]))
            iters.append(int(stats["iterations"]))
            gen.append(int(stats["generated"][0]))
        toks = np.stack(toks)
        khat = float(np.mean([g / max(i, 1) for g, i in zip(gen, iters)]))
        results[name] = {
            "mean_khat": khat,
            "acceptance_rate": (khat - 1.0) / max(k - 1, 1),
            "iters_per_token": sum(iters) / max(sum(gen), 1),
            "accuracy": float((toks == src).mean()),
        }
        if name == "draft_model":
            # suffix carry-over: sequential draft-model forwards per BPD
            # iteration (k-1 with carry-over vs the k-step legacy loop);
            # CI gates that the saving stays engaged
            steps = sess.policy.drafter.draft_steps_per_iter(k)
            results[name]["draft_steps_per_iter"] = float(steps)
            results[name]["draft_steps_saved"] = float(k - steps)
        # lossless policies (exact acceptance) must agree token-for-token
        if name == "exact":
            ref_tokens = toks
        elif name in LOSSLESS:
            if not np.array_equal(toks, ref_tokens):
                raise SystemExit(
                    f"LOSSLESSNESS VIOLATION: policy {name!r} changed the "
                    f"decoded tokens vs exact")
    # the satellite gate's precondition: this config must exercise the
    # adaptive cap (metric-identical rows mean the sweep lost its teeth)
    if abs(results["adaptive"]["mean_khat"]
           - results["exact"]["mean_khat"]) < 1e-9:
        raise SystemExit(
            "ADAPTIVE CAP NEVER ENGAGED: the adaptive rows are "
            "metric-identical to exact — pick a sweep config where the "
            "running-rate cap binds (see module docstring)")
    return results


def main():
    res = run()
    for name, r in res.items():
        for key, val in r.items():
            print(f"policies/{name}/{key},{val:.4f},", flush=True)


if __name__ == "__main__":
    main()
