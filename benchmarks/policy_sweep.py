"""Per-policy decode sweep: mean-k̂ / acceptance-rate / iters-per-token for
every registered DecodePolicy on a seconds-scale trained copy-task seq2seq.

The task is deliberately the Aggressive-Decoding regime (target == source):
a briefly pre-trained base model decodes it near-perfectly with p_1 alone,
while the prediction heads get only a short fine-tune — so the sweep
separates the policy axes the API exposes:

  * ``exact`` / ``topk`` / ``distance`` — the legacy acceptor criteria over
    ``HeadsDrafter`` (paper §3, §5.1, §5.2);
  * ``adaptive`` — the k̂-driven dynamic block schedule.  The sweep config
    (block_k = 8, 24-token outputs) is sized so the cap actually ENGAGES:
    mid-quality heads accept ≈1.5/8 per iteration, the running-rate EMA
    falls through the shrink threshold within a few iterations, and the
    shrunken cap clamps the occasional long accepted prefix — so the
    adaptive rows must differ from ``exact`` (asserted here and gated in
    CI; at the old block_k = 4 smoke config the cap never bound and the
    rows were metric-identical to ``exact``);
  * ``input_copy`` — source-sentence drafts (arXiv:2205.10350): on this
    workload it must beat ``HeadsDrafter``+exact on mean-k̂, which the CI
    bench-smoke asserts;
  * ``topk_tree`` — per-slot candidate re-ranking against p_1's chain
    logits (arXiv:2404.09221-style draft improvement);
  * ``draft_model`` — the speculative draft-model drafter: a 2-layer
    causal student DISTILLED from the trained workbench teacher
    (``core.distill.distill_seq2seq_to_causal_batches``, paper §6.2 reuse)
    proposes the block autoregressively through its own ``ModelBundle``;
    CI gates that it beats heads+exact on mean-k̂;
  * ``ss_draft_model`` — ``draft_model`` with the student re-trained
    under parallel scheduled sampling (arXiv:1906.04331,
    ``TrainConfig.scheduled_sampling``): one extra no-grad forward per
    step predicts every position, and a Bernoulli mask (linearly
    annealed toward ``ss_ratio``) swaps those predictions into the
    conditioning prefix — the student replays its own output
    autoregressively at decode time, so gentle mixing closes that
    train/decode prefix gap and lifts speculative acceptance.  The
    verifier is untouched, so the row stays lossless.

``run_scheduled_sampling()`` adds the exposure-bias rows (``ss_baseline``
/ ``ss_exact``): heads fine-tuned classically vs under scheduled
sampling + self-distilled targets on an open-ended LM workload — the
regime where the greedy chain actually leaves the gold distribution (the
seq2seq copy task cannot show the effect: the source pins the chain to
gold; see the function docstring for the probe data).  CI gates the
``ss_exact`` acceptance rate ≥ 1.3× ``ss_baseline``.

``run_locality()`` adds the 2-D image-decoding rows (``locality`` /
``locality_exact`` / ``locality_raster``): a causal model trained on
piecewise-bilinear ordinal FIELDS serialized in the progressive-lattice
order decodes with the ``locality`` policy (committed-neighbor
interpolation drafts re-ranked in a ±1 head-logit window, class-boundary
block schedule) against a raster-order twin decoding with heads+``exact``
— CI gates that locality wins iters/token at no-worse reconstruction MAE.

Everything is seeded and CPU-deterministic; ``benchmarks/run.py --smoke``
folds the rows into ``BENCH_decode.json`` and gates the committed
``exact`` mean-k̂ baseline against regressions.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.workbench import attach_heads, train_steps
from repro.config import DecodeConfig, ModelConfig, TrainConfig
from repro.core.bundle import ModelBundle
from repro.core.distill import distill_seq2seq_to_causal_batches
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import freeze_mask

VOCAB, SRC_LEN, BATCH = 48, 24, 32

# the sweep order is the report order; exact is the gated baseline
POLICIES = ("exact", "topk", "distance", "adaptive", "input_copy",
            "topk_tree", "draft_model")

# exact-acceptance policies: token-identical to exact by construction.
# ss_draft_model belongs here too — scheduled sampling retrains the student
# only; the verifier (p_1) is untouched, so the exact-acceptance stream is
# bit-identical and only iteration counts move.  (The ss_exact /
# ss_baseline head rows live in ``run_scheduled_sampling`` with their own
# internal token-identity assert — they decode a different workload.)
LOSSLESS = ("adaptive", "input_copy", "topk_tree", "draft_model",
            "ss_draft_model")


def _config(k: int, enabled: bool = True) -> ModelConfig:
    return ModelConfig(
        name="policy-sweep", family="seq2seq", is_encoder_decoder=True,
        num_encoder_layers=1, num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=VOCAB, bpd_k=k,
        bpd_enabled=enabled, max_seq_len=256, dtype="float32")


def _draft_config() -> ModelConfig:
    """The distilled student: a 2-layer causal LM (no encoder, no heads —
    p_1 only), decode-cheap relative to the verify forward."""
    return ModelConfig(
        name="policy-sweep-draft", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=VOCAB, bpd_enabled=False,
        max_seq_len=256, dtype="float32")


def _copy_task(seed: int = 0):
    """Low-entropy Markov source with target == source.

    Source drafts are exact (the Aggressive-Decoding regime), AND the
    target inherits the chain's redundancy — so frozen-base prediction
    heads have something learnable (unlike a uniform copy task, cf. the
    ``PhraseMT`` docstring), the ``exact`` baseline sits measurably above
    its k̂ = 1 floor (giving the CI regression gate slack to fire), and a
    small causal student can learn the teacher's output distribution
    without ever seeing the source.  Token 0 is reserved (BOS/PAD), hence
    the +1 shift.
    """
    from repro.data.synthetic import MarkovLM

    return MarkovLM(vocab=VOCAB - 1, temperature=0.12, seed=seed)


def _copy_batches(seed: int, task=None):
    task = task or _copy_task()
    rng = np.random.default_rng(seed)
    while True:
        src = (task.sample(rng, BATCH, SRC_LEN) + 1).astype(np.int32)
        yield {"src": src, "tgt": src.copy()}


def pretrain_base(k: int = 8, *, pretrain_steps: int = 600, seed: int = 0):
    """Phase 1 — the shared pre-trained base (heads disabled): both the
    gold-prefix and the scheduled-sampling head fine-tunes start from THIS
    model, so their acceptance-rate difference is attributable to the head
    training distribution alone."""
    cfg0 = _config(k, enabled=False)
    tc0 = TrainConfig(global_batch=BATCH, seq_len=SRC_LEN, lr=3e-3,
                      warmup_steps=max(pretrain_steps // 10, 5),
                      head_loss="mean")
    params = S.init(jax.random.PRNGKey(seed), cfg0)
    params, _ = train_steps(cfg0, tc0, params, _copy_batches(seed + 1),
                            pretrain_steps, seed=seed)
    return cfg0, params


def finetune_heads(cfg0, base_params, k: int, *, head_steps: int = 300,
                   seed: int = 0, scheduled_sampling: bool = False):
    """Phase 2 — attach heads and fine-tune them on a frozen base.  The
    fine-tune is sized so the heads are mid-quality: good enough that
    ``exact`` sits measurably above its k̂ = 1 floor (the CI regression
    gate needs slack below the baseline), short enough that p_1's
    source-copy knowledge stays far ahead of them.

    ``scheduled_sampling=True`` trains the SAME heads (same seeds, same
    data stream) with the decoder prefix Bernoulli-mixed toward the
    model's own teacher-forced predictions (parallel scheduled sampling,
    arXiv:1906.04331) on a linear anneal — the train-time prefix then
    matches the decode-time prefix (the model's committed output, errors
    included), which is exactly the mismatch that caps gold-prefix heads'
    acceptance rate.  The base stays frozen either way, so p_1 — and
    therefore every exact-acceptance token stream — is bit-identical
    between the two head sets; only iteration counts may differ."""
    cfg, params = attach_heads(cfg0, base_params, k, seed=seed + 7)
    tc1 = TrainConfig(global_batch=BATCH, seq_len=SRC_LEN, lr=3e-3,
                      warmup_steps=max(head_steps // 10, 5),
                      head_loss="mean", freeze_base=True,
                      scheduled_sampling=scheduled_sampling,
                      ss_ratio=0.9, ss_anneal_steps=head_steps // 2)
    params, _ = train_steps(cfg, tc1, params, _copy_batches(seed + 2),
                            head_steps, seed=seed + 3,
                            mask=freeze_mask(params, train_only_heads=True))
    return cfg, params


def build_model(k: int = 8, *, pretrain_steps: int = 600,
                head_steps: int = 300, seed: int = 0,
                scheduled_sampling: bool = False):
    """Pre-train + head fine-tune in one call (the legacy entry point)."""
    cfg0, base = pretrain_base(k, pretrain_steps=pretrain_steps, seed=seed)
    return finetune_heads(cfg0, base, k, head_steps=head_steps, seed=seed,
                          scheduled_sampling=scheduled_sampling)


def distill_student_data(cfg, params, *, n_distill_batches: int = 64,
                         seed: int = 0):
    """§6.2: greedy teacher decodes -> BOS-prefixed causal streams.  The
    teacher decode is p_1-greedy, so the SAME data serves the gold-prefix
    and scheduled-sampling students."""
    rng = np.random.default_rng(seed + 31)
    task = _copy_task()
    srcs = [(task.sample(rng, BATCH, SRC_LEN) + 1).astype(np.int32)
            for _ in range(n_distill_batches)]
    return distill_seq2seq_to_causal_batches(params, cfg, srcs,
                                             max_new=SRC_LEN)


def train_student(distilled, *, student_steps: int = 900, seed: int = 0,
                  scheduled_sampling: bool = False):
    """Train the 2-layer causal student on the distilled streams.  With
    ``scheduled_sampling=True`` the student's conditioning prefix is mixed
    toward its OWN predictions — the drafter replays its output
    autoregressively at decode time, so this closes the same train/decode
    prefix gap for the speculative path.  The mixing is deliberately
    GENTLE (peak ratio 0.3, annealed over the whole run): the student's
    value comes from tracking the teacher's chain, and heavy mixing
    (ratio 0.9) swaps so much of the prefix for early-training student
    noise that distillation collapses (measured: acceptance 0.098 vs the
    gold-prefix student's 0.248 — worse than no student training change;
    ratio 0.3 lifts it to 0.268)."""
    dcfg = _draft_config()
    dparams = M.init(jax.random.PRNGKey(seed + 13), dcfg)
    tc = TrainConfig(global_batch=BATCH, seq_len=SRC_LEN + 1, lr=3e-3,
                     warmup_steps=max(student_steps // 10, 5),
                     head_loss="mean",
                     scheduled_sampling=scheduled_sampling,
                     ss_ratio=0.3, ss_anneal_steps=student_steps)

    def gen():
        i = 0
        while True:
            yield distilled[i % len(distilled)]
            i += 1

    dparams, _ = train_steps(dcfg, tc, dparams, gen(), student_steps,
                             seed=seed + 17)
    return dcfg, dparams


def build_draft_student(cfg, params, *, n_distill_batches: int = 64,
                        student_steps: int = 900, seed: int = 0,
                        scheduled_sampling: bool = False):
    """§6.2 reuse: greedy teacher decodes -> BOS-prefixed causal streams ->
    a 2-layer student LM trained on them (the ``draft`` ModelBundle)."""
    distilled = distill_student_data(cfg, params,
                                     n_distill_batches=n_distill_batches,
                                     seed=seed)
    return train_student(distilled, student_steps=student_steps, seed=seed,
                         scheduled_sampling=scheduled_sampling)


def run(*, k: int = 8, seed: int = 0, pretrain_steps: int = 900,
        head_steps: int = 300, student_steps: int = 900,
        eval_rows: int = 16) -> dict:
    cfg0, base = pretrain_base(k, pretrain_steps=pretrain_steps, seed=seed)
    cfg, params = finetune_heads(cfg0, base, k, head_steps=head_steps,
                                 seed=seed)
    # gold-prefix vs scheduled-sampling students: SAME distilled data,
    # SAME seeds — the ss_draft_model row isolates the training-prefix knob
    distilled = distill_student_data(cfg, params, seed=seed)
    dcfg, dparams = train_student(distilled, student_steps=student_steps,
                                  seed=seed)
    _, dparams_ss = train_student(distilled, student_steps=student_steps,
                                  seed=seed, scheduled_sampling=True)
    rng = np.random.default_rng(seed + 11)
    src = (_copy_task().sample(rng, eval_rows, SRC_LEN) + 1).astype(np.int32)

    from repro.serving import DecodeSession

    # (row name, registered policy, verifier params, draft bundle) — the
    # ss_draft_model row swaps in the scheduled-sampling-trained student
    # while the verifier stays bit-identical, so it sits in LOSSLESS
    variants = [(name, name, params,
                 (dparams, dcfg) if name == "draft_model" else None)
                for name in POLICIES]
    variants += [("ss_draft_model", "draft_model", params,
                  (dparams_ss, dcfg))]

    results = {}
    ref_tokens = None
    for row, name, vparams, draft in variants:
        dec = DecodeConfig(max_new_tokens=SRC_LEN, block_k=k, policy=name,
                           top_k=2, epsilon=2.0)
        bundles = ({"draft": ModelBundle(*draft)} if draft else None)
        # decode row-by-row (one jit per policy, geometry (1, SRC_LEN)):
        # the batched loop's global iteration count is gated by its slowest
        # row, which would floor mean-k̂ at 1.0 whenever ANY row rejects
        # everything — per-row decodes measure the honest k̂ distribution
        sess = DecodeSession(vparams, cfg, dec, jit=True, bundles=bundles)
        toks, iters, gen = [], [], []
        for r in range(eval_rows):
            t, stats = sess.decode_seq2seq({"src": jnp.asarray(src[r:r + 1])})
            toks.append(np.asarray(t[0, :SRC_LEN]))
            iters.append(int(stats["iterations"]))
            gen.append(int(stats["generated"][0]))
        toks = np.stack(toks)
        khat = float(np.mean([g / max(i, 1) for g, i in zip(gen, iters)]))
        results[row] = {
            "mean_khat": khat,
            "acceptance_rate": (khat - 1.0) / max(k - 1, 1),
            "iters_per_token": sum(iters) / max(sum(gen), 1),
            "accuracy": float((toks == src).mean()),
        }
        if name == "draft_model":
            # suffix carry-over: sequential draft-model forwards per BPD
            # iteration (k-1 with carry-over vs the k-step legacy loop);
            # CI gates that the saving stays engaged
            steps = sess.policy.drafter.draft_steps_per_iter(k)
            results[row]["draft_steps_per_iter"] = float(steps)
            results[row]["draft_steps_saved"] = float(k - steps)
        # lossless policies (exact acceptance) must agree token-for-token
        if row == "exact":
            ref_tokens = toks
        elif row in LOSSLESS:
            if not np.array_equal(toks, ref_tokens):
                raise SystemExit(
                    f"LOSSLESSNESS VIOLATION: policy {row!r} changed the "
                    f"decoded tokens vs exact")
    # the satellite gate's precondition: this config must exercise the
    # adaptive cap (metric-identical rows mean the sweep lost its teeth)
    if abs(results["adaptive"]["mean_khat"]
           - results["exact"]["mean_khat"]) < 1e-9:
        raise SystemExit(
            "ADAPTIVE CAP NEVER ENGAGED: the adaptive rows are "
            "metric-identical to exact — pick a sweep config where the "
            "running-rate cap binds (see module docstring)")
    return results


# ---------------------------------------------------------------------------
# Scheduled-sampling head training (arXiv:1906.04331)
# ---------------------------------------------------------------------------

SS_SEQ, SS_PROMPT, SS_NEW = 32, 8, 24


def _lm_task(temperature: float = 0.3, seed: int = 0):
    from repro.data.synthetic import MarkovLM

    return MarkovLM(vocab=VOCAB, temperature=temperature, seed=seed)


def _lm_batches(task, seed: int):
    rng = np.random.default_rng(seed)
    while True:
        yield {"tokens": task.sample(rng, BATCH, SS_SEQ).astype(np.int32)}


def run_scheduled_sampling(*, k: int = 8, pretrain_steps: int = 900,
                           head_steps: int = 300, eval_rows: int = 16,
                           seed: int = 0) -> dict:
    """The exposure-bias rows: OPEN-ENDED LM decoding, where scheduled
    sampling actually has a gap to close.

    On the seq2seq copy task SS cannot move the needle — the source pins
    the model's greedy chain to the gold stream (measured: the chain
    agrees with gold on 91%+ of positions, and the heads' chain-prefix
    agreement equals their gold-prefix agreement slot-for-slot, so
    acceptance is purely far-slot head capacity).  Free-running LM decode
    is the regime the SS paper targets: the greedy chain wanders off the
    gold data distribution immediately, so heads fine-tuned on gold
    prefixes toward gold targets face out-of-distribution prefixes AND
    systematically-different continuations at decode time.

      ss_baseline — heads fine-tuned classically (gold prefix, gold
                    targets) on the frozen LM base, ``exact`` policy
      ss_exact    — same base/seeds/data, heads fine-tuned with
                    ``scheduled_sampling`` + ``ss_self_targets``: the
                    conditioning prefix is Bernoulli-mixed toward the
                    model's own predictions (annealed ratio) and the
                    targets are the frozen base's chain — the actual
                    exact-acceptance condition

    Both head sets sit on the SAME frozen base, so the decoded streams
    are bit-identical (asserted) — only iteration counts move.  CI gates
    ss_exact acceptance ≥ 1.3× ss_baseline.  (Prefix mixing toward gold
    targets alone is measurably HARMFUL here — x0.61 — the lift needs
    the self-distilled targets.)
    """
    from repro.serving import DecodeSession

    task = _lm_task()
    cfg0 = ModelConfig(
        name="ss-lm", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=VOCAB, bpd_k=k, bpd_enabled=False,
        max_seq_len=128, dtype="float32")
    tc0 = TrainConfig(global_batch=BATCH, seq_len=SS_SEQ, lr=3e-3,
                      warmup_steps=max(pretrain_steps // 10, 5),
                      head_loss="mean")
    base = M.init(jax.random.PRNGKey(seed), cfg0)
    base, _ = train_steps(cfg0, tc0, base, _lm_batches(task, seed + 1),
                          pretrain_steps, seed=seed)

    def tune(scheduled_sampling: bool):
        cfg, params = attach_heads(cfg0, base, k, seed=seed + 7)
        tc = TrainConfig(global_batch=BATCH, seq_len=SS_SEQ, lr=3e-3,
                         warmup_steps=max(head_steps // 10, 5),
                         head_loss="mean", freeze_base=True,
                         scheduled_sampling=scheduled_sampling,
                         ss_ratio=0.9, ss_anneal_steps=head_steps // 2,
                         ss_self_targets=scheduled_sampling)
        params, _ = train_steps(cfg, tc, params, _lm_batches(task, seed + 2),
                                head_steps, seed=seed + 3,
                                mask=freeze_mask(params,
                                                 train_only_heads=True))
        return cfg, params

    rng = np.random.default_rng(seed + 11)
    prompts = task.sample(rng, eval_rows, SS_PROMPT).astype(np.int32)
    results, streams = {}, {}
    for row, ss in (("ss_baseline", False), ("ss_exact", True)):
        cfg, params = tune(ss)
        dec = DecodeConfig(max_new_tokens=SS_NEW, block_k=k, policy="exact")
        sess = DecodeSession(params, cfg, dec, jit=True)
        toks, iters, gen = [], [], []
        for r in range(eval_rows):
            t, stats = sess.decode({"tokens": jnp.asarray(prompts[r:r + 1])})
            toks.append(np.asarray(t)[0, :SS_PROMPT + SS_NEW])
            iters.append(int(stats["iterations"]))
            gen.append(int(np.asarray(stats["generated"]).sum()))
        khat = float(np.mean([g / max(i, 1) for g, i in zip(gen, iters)]))
        results[row] = {"mean_khat": khat,
                        "acceptance_rate": (khat - 1.0) / max(k - 1, 1)}
        streams[row] = np.stack(toks)
    if not np.array_equal(streams["ss_exact"], streams["ss_baseline"]):
        raise SystemExit(
            "LOSSLESSNESS VIOLATION: scheduled-sampling heads changed the "
            "decoded tokens vs the gold-prefix heads on the same frozen "
            "base — p_1 must be untouched by head fine-tuning")
    return results


# ---------------------------------------------------------------------------
# Locality-aware image decoding (arXiv:2507.01957)
# ---------------------------------------------------------------------------

LOC_H = LOC_W = 8
LOC_STRIDE = 2
LOC_LEVELS = 16
LOC_K = 4
LOC_BATCH = 16


def _loc_config(name: str) -> ModelConfig:
    return ModelConfig(name=name, num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=128, vocab_size=LOC_LEVELS,
                       bpd_k=LOC_K, bpd_enabled=False, max_seq_len=128,
                       dtype="float32")


def _train_field_model(order: str, *, pretrain_steps: int, head_steps: int,
                       seed: int = 0):
    """One arm of the image comparison: a 2-layer causal LM trained on
    piecewise-bilinear ordinal fields serialized in ``order`` (raster scan
    vs progressive-lattice), plus a frozen-base head fine-tune."""
    from repro.data.synthetic import OrdinalField

    field = OrdinalField(levels=LOC_LEVELS, height=LOC_H, width=LOC_W,
                         n_waves=2, stride=LOC_STRIDE, order=order,
                         bilinear=True)
    n = LOC_H * LOC_W
    cfg0 = _loc_config(f"loc-{order}")
    tc0 = TrainConfig(global_batch=LOC_BATCH, seq_len=n, lr=3e-3,
                      warmup_steps=max(pretrain_steps // 10, 5),
                      head_loss="mean")
    params = M.init(jax.random.PRNGKey(seed), cfg0)
    params, _ = train_steps(cfg0, tc0, params,
                            field.batches(batch=LOC_BATCH, seed=seed + 1),
                            pretrain_steps, seed=seed)
    cfg, params = attach_heads(cfg0, params, LOC_K, seed=seed + 7)
    tc1 = tc0.replace(warmup_steps=max(head_steps // 10, 5), freeze_base=True)
    params, _ = train_steps(cfg, tc1, params,
                            field.batches(batch=LOC_BATCH, seed=seed + 2),
                            head_steps, seed=seed + 3,
                            mask=freeze_mask(params, train_only_heads=True))
    return field, cfg, params


def _decode_field(field, cfg, params, policy: str, *, rows: int, seed: int):
    """Decode ``rows`` held-out fields from the coarse prompt; returns
    (metrics, decoded streams)."""
    from repro.serving import DecodeSession

    n = LOC_H * LOC_W
    rng = np.random.default_rng(seed)
    grids = field.sample_grid(rng, rows)
    stream = field.serialize(grids)
    start = field.coarse_len
    dec = DecodeConfig(max_new_tokens=n - start, block_k=LOC_K,
                       policy=policy, image_height=LOC_H, image_width=LOC_W,
                       locality_stride=LOC_STRIDE)
    sess = DecodeSession(params, cfg, dec, jit=True)
    toks, iters, gen = [], 0, 0
    for r in range(rows):
        t, stats = sess.decode({"tokens": jnp.asarray(stream[r:r + 1, :start])})
        toks.append(np.asarray(t)[:, :n])
        iters += int(stats["iterations"])
        gen += int(np.asarray(stats["generated"]).sum())
    toks = np.concatenate(toks)
    mae = float(np.abs(field.to_grid(toks).astype(int)
                       - grids.astype(int)).mean())
    return {
        "iters_per_token": iters / max(gen, 1),
        "mean_khat": gen / max(iters, 1),
        "mae": mae,
    }, toks


def run_locality(*, pretrain_steps: int = 1200, head_steps: int = 400,
                 eval_rows: int = 8, seed: int = 0) -> dict:
    """The 2-D image rows: same data distribution, same training budget,
    same block size — only the serialization order and the drafter differ.

      locality        — progressive-lattice model, ``locality`` policy
                        (committed-neighbor interpolation drafts)
      locality_exact  — SAME model + prompts, heads-drafted ``exact``
                        (the token-identity reference: the locality
                        drafter must move iteration counts, not tokens)
      locality_raster — raster-order twin decoding with heads + ``exact``

    CI gates locality < locality_raster on iters/token with MAE no worse:
    on locally-smooth fields the raster model must extrapolate its scan k
    positions ahead (error grows with distance), while every locality
    refinement is bracketed by committed spatial parents — interpolation
    drafts then agree with the verifier far more often than raster heads.
    """
    f_loc, cfg_l, p_l = _train_field_model(
        "locality", pretrain_steps=pretrain_steps, head_steps=head_steps,
        seed=seed)
    f_ras, cfg_r, p_r = _train_field_model(
        "raster", pretrain_steps=pretrain_steps, head_steps=head_steps,
        seed=seed)
    res_loc, toks_loc = _decode_field(f_loc, cfg_l, p_l, "locality",
                                      rows=eval_rows, seed=seed + 42)
    res_ex, toks_ex = _decode_field(f_loc, cfg_l, p_l, "exact",
                                    rows=eval_rows, seed=seed + 42)
    res_ras, _ = _decode_field(f_ras, cfg_r, p_r, "exact",
                               rows=eval_rows, seed=seed + 42)
    if not np.array_equal(toks_loc, toks_ex):
        raise SystemExit(
            "LOSSLESSNESS VIOLATION: the locality policy changed the "
            "decoded tokens vs heads-drafted exact on the same model")
    return {"locality": res_loc, "locality_exact": res_ex,
            "locality_raster": res_ras}


def main():
    res = run()
    res.update(run_scheduled_sampling())
    res.update(run_locality())
    for name, r in res.items():
        for key, val in r.items():
            print(f"policies/{name}/{key},{val:.4f},", flush=True)


if __name__ == "__main__":
    main()
