"""Table 1 analog: BLEU-analog quality + mean accepted block size k̂ on the
cipher-MT task for k ∈ KS under the four settings

    Regular (frozen, gold) | Distillation (frozen, distilled)
    Fine Tuning (gold)     | Both (fine-tuned, distilled)

plus the paper's §7.1 follow-up: top-k approximate selection for the "Both"
models.  Paper claims being validated (EXPERIMENTS.md §Paper-claims):
  * frozen + gold preserves quality with k̂ > 1 that saturates (~1.7 in the
    paper) as k grows,
  * fine-tuning raises k̂ substantially at some quality cost,
  * distillation raises k̂ AND recovers most of that quality,
  * top-k selection trades further quality for larger k̂.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.config import DecodeConfig

from benchmarks.workbench import (
    MTBench,
    attach_heads,
    distill_data,
    eval_mt,
    finetune_heads,
    pretrain_mt,
)

SETTINGS = ("regular", "distill", "finetune", "both")


def run(ks=(2, 4, 6, 8), *, pretrain_steps=700, head_steps=500,
        n_distill_batches=48, out_path="experiments/table1.json",
        verbose=True):
    bench = MTBench()
    base_cfg, base_params = pretrain_mt(bench, steps=pretrain_steps)
    # separate-seed teacher, as in the paper (distilled data comes from a
    # different baseline run)
    _, teacher_params = pretrain_mt(bench, steps=pretrain_steps, seed=100)
    distilled = distill_data(bench, base_cfg, teacher_params,
                             n_batches=n_distill_batches)

    results = {}
    # k = 1 rows: the baselines themselves (greedy decoding)
    for name, par in (("regular", base_params), ("distill", teacher_params)):
        cfg1, p1 = attach_heads(base_cfg, par, 1)
        dec = DecodeConfig(max_new_tokens=bench.tgt_len, block_k=1)
        results[f"{name}_k1"] = eval_mt(bench, cfg1, p1, dec=dec)

    for k in ks:
        for setting in SETTINGS:
            cfg_k, params_k = attach_heads(base_cfg, base_params, k)
            freeze = setting in ("regular", "distill")
            data = distilled if setting in ("distill", "both") else None
            params_k = finetune_heads(bench, cfg_k, params_k,
                                      steps=head_steps, freeze=freeze,
                                      distilled=data)
            dec = DecodeConfig(max_new_tokens=bench.tgt_len, block_k=k,
                               policy="exact")
            res = eval_mt(bench, cfg_k, params_k, dec=dec)
            results[f"{setting}_k{k}"] = res
            if setting == "both":
                for topk in (2, 3):
                    deck = dec.replace(policy="topk", top_k=topk)
                    results[f"both_top{topk}_k{k}"] = eval_mt(
                        bench, cfg_k, params_k, dec=deck)
            if verbose:
                print(f"[table1] k={k} {setting:9s} "
                      f"acc={res['accuracy']:.3f} khat={res['mean_accepted']:.2f}",
                      flush=True)

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/table1.json")
    args = ap.parse_args()
    if args.quick:
        run(ks=(2, 4), pretrain_steps=250, head_steps=200,
            n_distill_batches=16, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
