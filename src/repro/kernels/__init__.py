"""Pallas TPU kernels for the BPD serving hot spots (+ pure-jnp oracles).

  * ``block_attention``  — k-query verify attention vs a long KV cache,
                           plus the tree-verification masking variant
  * ``paged_attention``  — same verify substep over a paged KV pool
                           (block-table gather via scalar prefetch)
  * ``rwkv6_scan``       — chunked RWKV-6 wkv linear-attention scan
  * ``fused_heads``      — streaming head-logits top-T (no k×V materialization)
  * ``fused_verify``     — one-pass accept: streaming top-T + criterion
                           compare + prefix-accept scan
  * ``tree_mask``        — candidate-tree topologies (ancestor masks,
                           packed bitmasks) for tree verification

``ops`` holds the jit'd wrappers (interpret mode on CPU); ``ref`` the
oracles used by the per-kernel shape/dtype sweep tests.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    fused_heads_topk,
    fused_verify,
    paged_verify_attention,
    rwkv6_scan,
    tree_verify_attention,
    verify_attention,
)
from repro.kernels.tree_mask import TreeTopology, default_tree  # noqa: F401
