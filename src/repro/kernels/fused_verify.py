"""Pallas TPU kernel: fused one-pass block verification (paper §3, §5.1–5.2).

The BPD accept step is a chain of vocab-dimension ops on the verify
forward's p_1 logits — argmax / top-k per block slot, a compare against the
drafted tokens, and the longest-accepted-prefix scan.  Run separately,
each op round-trips the (B, k, V) logit tensor through HBM (V reaches 256k
padded for the assigned archs).  This kernel streams the logits once in
``block_v`` vocab tiles, keeps a running top-T (values, ids) carry per
(row, slot) in VMEM — the ``fused_heads.py`` merge idiom — and on the last
tile performs the criterion compare plus the prefix-accept scan in
registers, emitting per row:

    accepts (B, k) — per-slot acceptance (column 0 always True, k̂ ≥ 1)
    k̂      (B,)   — longest accepted prefix (before schedule clamping)
    tokens  (B, k) — the accepted prefix of the draft, zero beyond k̂
    next    (B,)   — the verifier's greedy token at slot k̂-1 (the one
                     guaranteed-correct token every iteration commits)

Criterion variants are compile-time (``functools.partial``): ``exact``
(§3 greedy match), ``topk`` (§5.1, T = top_k carry), ``distance`` (§5.2
ordinal tolerance).  Tie-breaking matches ``jnp.argmax`` exactly:
``lax.top_k`` is stable (lowest index wins) and the carry∪tile merge
concatenates the carry — earlier vocab tiles — first, so equal logits
resolve to the lowest token id in both the fused and unfused paths.

Grid: (num_row_tiles, num_vocab_tiles); vocab axis sequential, carry in
VMEM.  Row tiles hold whole batch rows (rn = rb·k, a multiple of 8) so the
cross-slot prefix scan never spans tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
CRITERIA = ("exact", "topk", "distance")


def _accept_scan(ids, props, *, criterion: str, k: int, epsilon: float):
    """Shared final-tile epilogue: criterion compare + prefix scan.

    ids: (rb, k, T) top-T token ids per slot; props: (rb, k) draft tokens.
    Returns (accepts bool, k̂ (rb,1), accepted tokens, next greedy (rb,1)).
    """
    rb = props.shape[0]
    greedy = ids[..., 0]                                   # (rb, k)
    cand = props[:, 1:]                                    # slot i-1 checks i
    if criterion == "exact":
        ok = cand == greedy[:, :k - 1]
    elif criterion == "topk":
        ok = jnp.any(ids[:, :k - 1, :] == cand[..., None], axis=-1)
    elif criterion == "distance":
        ok = jnp.abs(cand - greedy[:, :k - 1]).astype(jnp.float32) <= epsilon
    else:  # pragma: no cover - guarded by the wrapper
        raise ValueError(f"unknown criterion {criterion!r}")
    acc = jnp.concatenate([jnp.ones((rb, 1), jnp.bool_), ok], axis=1)
    rej = jnp.logical_not(acc)
    first = jnp.argmax(rej.astype(jnp.int32), axis=1, keepdims=True)
    any_rej = jnp.any(rej, axis=1, keepdims=True)
    khat = jnp.where(any_rej, first, k).astype(jnp.int32)  # (rb, 1)
    slot = jax.lax.broadcasted_iota(jnp.int32, (rb, k), 1)
    toks = jnp.where(slot < khat, props, 0)
    nxt = jnp.sum(jnp.where(slot == khat - 1, greedy, 0), axis=1,
                  keepdims=True)
    return acc, khat, toks, nxt


def _fused_verify_kernel(logits_ref, prop_ref,             # inputs
                         acc_ref, khat_ref, tok_ref, nxt_ref,   # outputs
                         bval_ref, bidx_ref,               # scratch
                         *, criterion: str, k: int, top_t: int,
                         block_v: int, vocab: int, epsilon: float):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        bval_ref[...] = jnp.full_like(bval_ref, NEG_INF)
        bidx_ref[...] = jnp.zeros_like(bidx_ref)

    logits = logits_ref[...].astype(jnp.float32)           # (rb·k, block_v)
    base = vb * block_v
    lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + base
    logits = jnp.where(lane < vocab, logits, NEG_INF)      # mask vocab pad

    tvals, tids = jax.lax.top_k(logits, top_t)             # tile-local top-T
    cand_v = jnp.concatenate([bval_ref[...], tvals], axis=1)
    cand_i = jnp.concatenate([bidx_ref[...], tids + base], axis=1)
    mvals, sel = jax.lax.top_k(cand_v, top_t)              # merge carry ∪ tile
    bval_ref[...] = mvals
    bidx_ref[...] = jnp.take_along_axis(cand_i, sel, axis=1)

    @pl.when(vb == pl.num_programs(1) - 1)
    def _finish():
        rb = prop_ref.shape[0]
        ids = bidx_ref[...].reshape(rb, k, top_t)
        acc, khat, toks, nxt = _accept_scan(
            ids, prop_ref[...], criterion=criterion, k=k, epsilon=epsilon)
        acc_ref[...] = acc.astype(jnp.int32)
        khat_ref[...] = khat
        tok_ref[...] = toks
        nxt_ref[...] = nxt


def fused_verify_pallas(p1_logits, proposals, *, criterion: str,
                        top_k: int = 1, epsilon: float = 0.0,
                        block_rows: int = 64, block_v: int = 1024,
                        interpret: bool = False):
    """p1_logits: (B, k, V) verify-forward p_1 logits at block slots 0..k-1;
    proposals: (B, k) int32 draft tokens (slot 0 = the verified token).

    Returns (accepts (B, k) bool, k̂ (B,) int32, accepted_tokens (B, k)
    int32, next_greedy (B,) int32).  Bit-identical to ``ref.fused_verify``
    and to the unfused ``Acceptor`` path for the same criterion.
    """
    if criterion not in CRITERIA:
        raise ValueError(f"unknown criterion {criterion!r}; one of {CRITERIA}")
    b, k, v = p1_logits.shape
    top_t = max(1, int(top_k)) if criterion == "topk" else 1
    block_v = min(block_v, max(128, v))
    vp = ((v + block_v - 1) // block_v) * block_v

    # whole batch rows per tile, rn = rb·k aligned to the 8-sublane tile
    rn_unit = (k * 8) // math.gcd(k, 8)
    rb = (rn_unit // k) * max(1, block_rows // rn_unit)
    b_pad = ((b + rb - 1) // rb) * rb
    rn = rb * k

    lg = jnp.pad(p1_logits.astype(jnp.float32),
                 ((0, b_pad - b), (0, 0), (0, vp - v)),
                 constant_values=NEG_INF).reshape(b_pad * k, vp)
    props = jnp.pad(proposals.astype(jnp.int32), ((0, b_pad - b), (0, 0)))

    grid = (b_pad // rb, vp // block_v)
    acc, khat, toks, nxt = pl.pallas_call(
        functools.partial(_fused_verify_kernel, criterion=criterion, k=k,
                          top_t=top_t, block_v=block_v, vocab=v,
                          epsilon=float(epsilon)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rn, block_v), lambda ri, vi: (ri, vi)),
            pl.BlockSpec((rb, k), lambda ri, vi: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rb, k), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((rb, 1), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((rb, k), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((rb, 1), lambda ri, vi: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rn, top_t), jnp.float32),
            pltpu.VMEM((rn, top_t), jnp.int32),
        ],
        interpret=interpret,
    )(lg, props)
    return (acc[:b].astype(jnp.bool_), khat[:b, 0], toks[:b], nxt[:b, 0])
