"""Pallas TPU kernel: BPD verify attention over a *paged* KV cache.

Same regime as ``block_attention`` — a tiny block of k fresh query tokens
scored against a long KV context — but the context lives in a shared pool
of fixed-size pages (``models.cache.paged_attn_cache_init``) instead of a
dense per-row slab.  Each slot addresses its context through a block table
``tbl (B, P)`` of physical page ids, so the kernel must gather pages rather
than stream a contiguous row.

TPU adaptation:
  * The block table is a *scalar-prefetch* argument
    (``pltpu.PrefetchScalarGridSpec``): it lands in SMEM before the body
    runs, and the K/V BlockSpec index maps read ``tbl[b, p]`` to aim each
    grid step's DMA at the right physical page.  The gather happens in the
    pipeline — no (B, P*ps) dense copy of the pool is ever materialized.
  * Grid is (batch, kv_head, page); the page axis is sequential on TPU so
    the flash-decoding online-softmax carry (m/l/acc) lives in VMEM scratch
    across pages, exactly as ``block_attention`` carries it across KV tiles.
    One KV tile == one page (``page_size`` is a multiple of 8 by
    EngineConfig validation, so tiles stay sublane-aligned).
  * GQA folds into query rows ((kq × G, hd) resident block), masking is the
    same positional predicate as the dense kernel: ``kv_pos`` is the slot's
    *logical* position array (B, P*ps), so CoW-shared pages and BPD
    rollback (pos = -1 staling) need no data movement — unmapped table
    entries point at trash page 0 and their positions are -1, masking the
    whole page.

Oracle: ``ref.paged_verify_attention`` (gather ``kp[tbl]`` + dense oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(tbl_ref,                                # scalar prefetch
                       qpos_ref, kvpos_ref, q_ref, k_ref, v_ref,  # inputs
                       o_ref,                                  # outputs
                       m_ref, l_ref, acc_ref,                  # scratch
                       *, window: int, num_meta: int, scale: float):
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (RQ = kq*G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (page_size, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (page_size, hd)
    qpos = qpos_ref[0]                             # (RQ,) int32 (row -> q pos)
    kvpos = kvpos_ref[0]                           # (page_size,) int32

    scores = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (RQ, page_size)

    qp = qpos[:, None]
    kp = kvpos[None, :]
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp < window) | (kp < num_meta)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                            # (RQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                    # (RQ, page_size)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pi == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_verify_attention_pallas(q, kp, vp, tbl, q_pos, kv_pos, *,
                                  window: int = 0, num_meta: int = 0,
                                  interpret: bool = False) -> jnp.ndarray:
    """q: (B, kq, H, hd); kp/vp: (num_pages, ps, KV, hd); tbl: (B, P) i32;
    q_pos: (B, kq); kv_pos: (B, P*ps) logical positions (-1 = masked).

    Returns (B, kq, H, hd).  ``ps`` must be a multiple of 8 (sublane)."""
    b, kq, h, hd = q.shape
    num_pages, ps, kvh, _ = kp.shape
    P = tbl.shape[1]
    if ps % 8:
        raise ValueError(f"page_size {ps} must be a multiple of 8")
    if kv_pos.shape != (b, P * ps):
        raise ValueError(f"kv_pos shape {kv_pos.shape} != {(b, P * ps)}")
    g = h // kvh
    scale = float(hd) ** -0.5

    # ---- fold GQA groups into query rows; pad for TPU tile alignment -------
    rq = kq * g
    rq_pad = max(8, ((rq + 7) // 8) * 8)
    hd_pad = max(128, ((hd + 127) // 128) * 128)

    # head index h = kvh_idx * g + g_idx  (matches models.attention._gqa_attend)
    qr = q.reshape(b, kq, kvh, g, hd).transpose(0, 2, 1, 3, 4).reshape(b, kvh, rq, hd)
    qr = jnp.pad(qr, ((0, 0), (0, 0), (0, rq_pad - rq), (0, hd_pad - hd)))
    # pool laid out (page, kv_head, ps, hd) so each grid step's block is one
    # page of one kv head — (ps, hd_pad) MXU-aligned
    kr = jnp.pad(kp.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, 0), (0, hd_pad - hd)))
    vr = jnp.pad(vp.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, 0), (0, hd_pad - hd)))

    qpos_rows = jnp.repeat(q_pos, g, axis=1)                     # (B, rq)
    qpos_rows = jnp.pad(qpos_rows, ((0, 0), (0, rq_pad - rq)),
                        constant_values=-(2 ** 30))

    grid = (b, kvh, P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # tbl: SMEM, feeds index maps
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rq_pad), lambda bi, hi, pi, tbl: (bi, 0)),
            pl.BlockSpec((1, ps), lambda bi, hi, pi, tbl: (bi, pi)),
            pl.BlockSpec((1, 1, rq_pad, hd_pad),
                         lambda bi, hi, pi, tbl: (bi, hi, 0, 0)),
            # the paged gather: DMA the physical page this slot maps here
            pl.BlockSpec((1, 1, ps, hd_pad),
                         lambda bi, hi, pi, tbl: (tbl[bi, pi], hi, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd_pad),
                         lambda bi, hi, pi, tbl: (tbl[bi, pi], hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rq_pad, hd_pad),
                               lambda bi, hi, pi, tbl: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rq_pad, 1), jnp.float32),
            pltpu.VMEM((rq_pad, 1), jnp.float32),
            pltpu.VMEM((rq_pad, hd_pad), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, window=window,
                          num_meta=num_meta, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rq_pad, hd_pad), q.dtype),
        interpret=interpret,
    )(tbl.astype(jnp.int32), qpos_rows, kv_pos.astype(jnp.int32), qr, kr, vr)

    out = out[:, :, :rq, :hd].reshape(b, kvh, kq, g, hd)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, kq, h, hd)
