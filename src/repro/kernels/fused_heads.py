"""Pallas TPU kernel: fused BPD head FFN + vocab projection + running top-T.

Verification (paper §3) needs only argmax / top-k token ids of p_1..p_k, not
the logits themselves.  For the assigned archs the padded vocab reaches 256k
(nemotron-4), so materializing the (B, k, V) logit tensor per BPD iteration
would round-trip ~256k × k × 4B per row through HBM.  This kernel streams
the vocabulary projection in ``block_v`` tiles through VMEM, keeping a
running top-T (values, ids) carry per (row, head), and never writes logits
to HBM — a beyond-paper TPU optimization recorded in EXPERIMENTS.md §Perf.

Inputs are the *per-head decoder outputs* o = heads_apply(hidden) flattened
to (N·K, d) (the head FFN is tiny — K × d × d_hidden — and runs as a plain
XLA matmul; fusing it in would force the d_hidden working set into every
vocab tile for no bandwidth win).

Grid: (num_row_tiles, num_vocab_tiles); vocab axis sequential, carry in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fused_heads_kernel(o_ref, w_ref,                     # inputs
                        val_ref, idx_ref,                 # outputs
                        bval_ref, bidx_ref,               # scratch
                        *, top_t: int, block_v: int, vocab: int):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        bval_ref[...] = jnp.full_like(bval_ref, NEG_INF)
        bidx_ref[...] = jnp.zeros_like(bidx_ref)

    o = o_ref[...].astype(jnp.float32)                    # (RN, d)
    w = w_ref[...].astype(jnp.float32)                    # (d, block_v)
    logits = jax.lax.dot_general(o, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    base = vb * block_v
    lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + base
    logits = jnp.where(lane < vocab, logits, NEG_INF)     # mask vocab pad

    tvals, tids = jax.lax.top_k(logits, top_t)            # (RN, T) tile-local
    cand_v = jnp.concatenate([bval_ref[...], tvals], axis=1)
    cand_i = jnp.concatenate([bidx_ref[...], tids + base], axis=1)
    mvals, sel = jax.lax.top_k(cand_v, top_t)             # merge carry ∪ tile
    bval_ref[...] = mvals
    bidx_ref[...] = jnp.take_along_axis(cand_i, sel, axis=1)

    @pl.when(vb == pl.num_programs(1) - 1)
    def _finish():
        val_ref[...] = bval_ref[...]
        idx_ref[...] = bidx_ref[...]


def fused_heads_topk_pallas(o, w_vocab, *, vocab: int, top_t: int = 4,
                            block_rows: int = 256, block_v: int = 1024,
                            interpret: bool = False):
    """o: (N, d) per-head decoder outputs (rows = flattened (token, head));
    w_vocab: (d, Vp) vocab projection (pre-transposed embed table if tied).

    Returns (top_vals (N, top_t) f32, top_ids (N, top_t) i32) over the
    *logical* vocab (pad lanes never win).
    """
    n, d = o.shape
    vp = w_vocab.shape[1]
    block_v = min(block_v, vp)
    assert vp % block_v == 0, (vp, block_v)
    rn = min(block_rows, max(8, ((n + 7) // 8) * 8))
    n_pad = ((n + rn - 1) // rn) * rn
    op = jnp.pad(o, ((0, n_pad - n), (0, 0)))

    grid = (n_pad // rn, vp // block_v)
    vals, ids = pl.pallas_call(
        functools.partial(_fused_heads_kernel, top_t=top_t, block_v=block_v,
                          vocab=vocab),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rn, d), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((d, block_v), lambda ri, vi: (0, vi)),
        ],
        out_specs=[
            pl.BlockSpec((rn, top_t), lambda ri, vi: (ri, 0)),
            pl.BlockSpec((rn, top_t), lambda ri, vi: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, top_t), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, top_t), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rn, top_t), jnp.float32),
            pltpu.VMEM((rn, top_t), jnp.int32),
        ],
        interpret=interpret,
    )(op, w_vocab)
    return vals[:n], ids[:n]
