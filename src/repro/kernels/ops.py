"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in Pallas interpret mode, which
runs the kernel body in Python/XLA per grid step — correct but slow, so the
model stack uses the jnp paths by default and the kernels are exercised by
tests/benchmarks and on real TPU backends (``use_kernels=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_attention import (
    tree_verify_attention_pallas,
    verify_attention_pallas,
)
from repro.kernels.fused_heads import fused_heads_topk_pallas
from repro.kernels.fused_verify import fused_verify_pallas
from repro.kernels.paged_attention import paged_verify_attention_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "num_meta", "block_kv",
                                             "interpret"))
def verify_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     num_meta: int = 0, block_kv: int = 512,
                     interpret: bool | None = None):
    """BPD verify-substep attention (see kernels.block_attention)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return verify_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                   num_meta=num_meta, block_kv=block_kv,
                                   interpret=interp)


@functools.partial(jax.jit, static_argnames=("window", "num_meta", "block_kv",
                                             "interpret"))
def tree_verify_attention(q, k, v, q_pos, kv_pos, kv_node, anc_bits, *,
                          window: int = 0, num_meta: int = 0,
                          block_kv: int = 512, interpret: bool | None = None):
    """Tree-verification attention: score a whole candidate tree in one
    forward (see kernels.block_attention / kernels.tree_mask)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return tree_verify_attention_pallas(q, k, v, q_pos, kv_pos, kv_node,
                                        anc_bits, window=window,
                                        num_meta=num_meta, block_kv=block_kv,
                                        interpret=interp)


@functools.partial(jax.jit, static_argnames=("window", "num_meta",
                                             "interpret"))
def paged_verify_attention(q, kp, vp, tbl, q_pos, kv_pos, *, window: int = 0,
                           num_meta: int = 0, interpret: bool | None = None):
    """BPD verify attention over a paged KV pool (see kernels.paged_attention)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return paged_verify_attention_pallas(q, kp, vp, tbl, q_pos, kv_pos,
                                         window=window, num_meta=num_meta,
                                         interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 16,
               interpret: bool | None = None):
    """Chunked RWKV-6 wkv scan (see kernels.rwkv6_scan)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return rwkv6_scan_pallas(r, k, v, logw, u, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("criterion", "top_k", "epsilon",
                                             "block_rows", "block_v",
                                             "interpret"))
def fused_verify(p1_logits, proposals, *, criterion: str, top_k: int = 1,
                 epsilon: float = 0.0, block_rows: int = 64,
                 block_v: int = 1024, interpret: bool | None = None):
    """One-pass block verification: streaming top-T + criterion compare +
    prefix-accept scan (see kernels.fused_verify).  Returns (accepts (B, k)
    bool, k̂ (B,) int32, accepted_tokens (B, k), next_greedy (B,))."""
    interp = (not on_tpu()) if interpret is None else interpret
    if p1_logits.shape[1] == 1:                  # degenerate 1-slot block:
        from repro.kernels import ref            # nothing to scan — oracle
        return ref.fused_verify(p1_logits, proposals, criterion=criterion,
                                top_k=top_k, epsilon=epsilon)
    return fused_verify_pallas(p1_logits, proposals, criterion=criterion,
                               top_k=top_k, epsilon=float(epsilon),
                               block_rows=block_rows, block_v=block_v,
                               interpret=interp)


@functools.partial(jax.jit, static_argnames=("vocab", "top_t", "block_rows",
                                             "block_v", "interpret"))
def fused_heads_topk(o, w_vocab, *, vocab: int, top_t: int = 4,
                     block_rows: int = 256, block_v: int = 1024,
                     interpret: bool | None = None):
    """Streaming head-logits top-T (see kernels.fused_heads)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return fused_heads_topk_pallas(o, w_vocab, vocab=vocab, top_t=top_t,
                                   block_rows=block_rows, block_v=block_v,
                                   interpret=interp)
