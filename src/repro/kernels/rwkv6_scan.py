"""Pallas TPU kernel: chunked RWKV-6 ("Finch") wkv scan.

The wkv recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

is sequential per timestep, but within a chunk of C timesteps it has a
closed matmul form (the TPU-native adaptation — the recurrence becomes MXU
work instead of C dependent matvecs):

    a_t   = prod_{s<=t} w_s                      (cumulative decay, (C, D))
    y_t   = (r_t ⊙ a_{t-1}) S_0
            + sum_{s<t} ((r_t ⊙ a_{t-1}/a_s) · k_s) v_s
            + ((r_t ⊙ u) · k_t) v_t
    S_C   = diag(a_C) S_0 + (a_C ⊙ K~)^T V,   K~_s = k_s / a_s

i.e. with R~ = r ⊙ shift(a), K~ = k / a:

    y = (R~ @ S_0) + tril_strict(R~ @ K~^T) @ V + diag((r ⊙ u) · k) V

All products are (C,D)x(D,D), (C,D)x(D,C), (C,C)x(C,D) matmuls.  The (D,D)
state stays resident in VMEM scratch across the sequential chunk axis of the
grid, so HBM traffic per chunk is just the r/k/v/w tiles + y tile.

Numerics: 1/a_s can overflow when decay is strong, so chunks are short
(C = 16 by default, as in flash-linear-attention) and exponents are clamped;
contributions that would overflow are exactly those the decay has already
annihilated downstream.

Grid: (B, H, S // C) — last axis sequential on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LOG_CLAMP = 80.0  # exp(80) ~ 5e34, inside f32; valid terms never need it
                  # unless a chunk decays by more than e^-160 per channel,
                  # at which point the distorted contribution is ~0 anyway.


def _rwkv6_chunk_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref,   # inputs
                        y_ref, sfin_ref,                        # outputs
                        s_ref,                                  # scratch (D,D)
                        *, chunk: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)            # (C, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    logw = logw_ref[0, 0].astype(jnp.float32)      # (C, D) log-decay (<= 0)
    u = u_ref[0].astype(jnp.float32)               # (1, D)
    s0 = s_ref[...]                                # (D, D)

    la = jnp.cumsum(logw, axis=0)                  # log a_t   (C, D), <= 0
    la_prev = la - logw                            # log a_{t-1}
    la_end = la[-1:, :]                            # (1, D)

    # Per-channel midpoint renormalization: scores[t,s] needs
    # exp(la_prev[t] - la[s]) which is <= 1 for every *valid* (s < t) pair,
    # but neither factor alone is bounded.  Splitting at ref = la_end/2 makes
    # both factors <= exp(|la_end|/2) per channel, and ref cancels exactly in
    # the product, so valid entries are exact; invalid (s >= t) entries may
    # saturate the clamp but are masked to zero below.
    ref = 0.5 * la_end
    r_t = r * jnp.exp(jnp.minimum(la_prev - ref, LOG_CLAMP))
    k_t = k * jnp.exp(jnp.minimum(ref - la, LOG_CLAMP))

    dot = lambda a, b, dims: jax.lax.dot_general(
        a, b, (dims, ((), ())), preferred_element_type=jnp.float32)

    scores = dot(r_t, k_t, ((1,), (1,)))           # (C, C)
    c = scores.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    scores = jnp.where(si < ti, scores, 0.0)       # strict causal (s < t)

    diag = jnp.sum(r * u * k, axis=1, keepdims=True)            # (C, 1)
    r_s0 = r * jnp.exp(la_prev)                    # exact, <= |r| per channel
    y = dot(r_s0, s0, ((1,), (0,))) + dot(scores, v, ((1,), (0,))) + diag * v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    k_in = k * jnp.exp(la_end - la)                # a_C/a_s <= 1 (bounded)
    s_ref[...] = jnp.exp(la_end).T * s0 + dot(k_in, v, ((0,), (0,)))

    @pl.when(cb == pl.num_programs(2) - 1)
    def _finish():
        sfin_ref[0, 0] = s_ref[...]


def rwkv6_scan_pallas(r, k, v, logw, u, *, chunk: int = 16,
                      interpret: bool = False):
    """r/k/v/logw: (B, S, H, D); u: (H, D).  logw = -exp(w0 + lora) <= 0.

    Returns (y (B, S, H, D) f32, final_state (B, H, D, D) f32) with zero
    initial state (prefill/training semantics — decode keeps per-step states
    on the jnp path for BPD rollback).
    """
    b, s, h, d = r.shape
    c = min(chunk, s)
    n = (s + c - 1) // c
    pad = n * c - s

    def prep(t, fill=0.0):
        t = t.transpose(0, 2, 1, 3)                              # (B, H, S, D)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)),
                        constant_values=fill)
        return t

    rr, kk, vv = prep(r), prep(k), prep(v)
    lw = prep(logw)                                # pad logw with 0 (w = 1)

    grid = (b, h, n)
    y, sfin = pl.pallas_call(
        functools.partial(_rwkv6_chunk_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, n * c, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, u)

    y = y[:, :, :s, :].transpose(0, 2, 1, 3)                     # (B, S, H, D)
    return y, sfin
