"""Pallas TPU kernel: blockwise-parallel-decode *verify* attention.

The hot spot of BPD serving is scoring a tiny block of k fresh query tokens
(k = block size, ~2-16) against a long KV cache (32k-512k entries).  This is
the opposite regime from training flash-attention: Sq is tiny, Sk is huge, so
the kernel keeps the whole (padded) query block resident in VMEM and streams
the KV cache through in ``block_kv`` tiles with an online softmax
(flash-decoding style).

TPU adaptation (vs the paper's P100 setting, which had no custom kernel):
  * KV tiles are (block_kv, head_dim) with head_dim padded to a multiple of
    128 (lane width) and block_kv a multiple of 8 (sublane) — MXU-aligned.
  * GQA is folded into the query rows: the q block is (kq × G, hd) so the
    kernel row index encodes (query position, group member); the (tiny-q ×
    long-KV) matmul runs on the MXU without materializing repeated K/V.
  * Masking is positional: the cache carries an absolute position per slot
    (ring buffer), and the mask is recomputed from (q_pos, kv_pos) so BPD
    rollback (accepted length shrinking by up to k-1) costs no data movement.
    Stale speculative slots are marked with pos = -1 by the caller.
  * Sliding windows + hymba meta-token exemption are the same positional
    predicate used by the jnp oracle (``ref.verify_attention``).

Grid: (batch, kv_head, num_kv_blocks); the last axis is sequential on TPU so
the online-softmax carry lives in VMEM scratch across KV tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _verify_attn_kernel(qpos_ref, kvpos_ref, q_ref, k_ref, v_ref,  # inputs
                        o_ref,                                     # outputs
                        m_ref, l_ref, acc_ref,                     # scratch
                        *, group: int, window: int, num_meta: int,
                        scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (RQ = kq*G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_kv, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (block_kv, hd)
    qpos = qpos_ref[0]                             # (RQ,) int32 (row -> q pos)
    kvpos = kvpos_ref[0]                           # (block_kv,) int32

    scores = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (RQ, block_kv)

    qp = qpos[:, None]
    kp = kvpos[None, :]
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp < window) | (kp < num_meta)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                            # (RQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                    # (RQ, block_kv)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def verify_attention_pallas(q, k, v, q_pos, kv_pos, *, window: int = 0,
                            num_meta: int = 0, block_kv: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """q: (B, kq, H, hd); k/v: (B, L, KV, hd); q_pos: (B, kq); kv_pos: (B, L).

    Returns (B, kq, H, hd).  Rows whose kv_pos is -1 are masked out.
    """
    b, kq, h, hd = q.shape
    l, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = float(hd) ** -0.5

    # ---- fold GQA groups into query rows; pad for TPU tile alignment -------
    rq = kq * g
    rq_pad = max(8, ((rq + 7) // 8) * 8)
    hd_pad = max(128, ((hd + 127) // 128) * 128)
    block_kv = min(block_kv, ((l + 7) // 8) * 8)
    l_pad = ((l + block_kv - 1) // block_kv) * block_kv

    # head index h = kvh_idx * g + g_idx  (matches models.attention._gqa_attend)
    qr = q.reshape(b, kq, kvh, g, hd).transpose(0, 2, 1, 3, 4).reshape(b, kvh, rq, hd)
    qr = jnp.pad(qr, ((0, 0), (0, 0), (0, rq_pad - rq), (0, hd_pad - hd)))
    kr = jnp.pad(k.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, l_pad - l), (0, hd_pad - hd)))
    vr = jnp.pad(v.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, l_pad - l), (0, hd_pad - hd)))

    # per-row query positions (row = q_idx * g + g_idx)
    qpos_rows = jnp.repeat(q_pos, g, axis=1)                     # (B, rq)
    qpos_rows = jnp.pad(qpos_rows, ((0, 0), (0, rq_pad - rq)),
                        constant_values=-(2 ** 30))
    kvpos_p = jnp.pad(kv_pos, ((0, 0), (0, l_pad - l)), constant_values=-1)

    grid = (b, kvh, l_pad // block_kv)
    out = pl.pallas_call(
        functools.partial(_verify_attn_kernel, group=g, window=window,
                          num_meta=num_meta, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rq_pad), lambda bi, hi, ki: (bi, 0)),
            pl.BlockSpec((1, block_kv), lambda bi, hi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, rq_pad, hd_pad), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd_pad), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd_pad), lambda bi, hi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rq_pad, hd_pad),
                               lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, rq_pad, hd_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rq_pad, 1), jnp.float32),
            pltpu.VMEM((rq_pad, 1), jnp.float32),
            pltpu.VMEM((rq_pad, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_rows, kvpos_p, qr, kr, vr)

    out = out[:, :, :rq, :hd].reshape(b, kvh, kq, g, hd)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, kq, h, hd)


# ---------------------------------------------------------------------------
# Tree-verification variant
# ---------------------------------------------------------------------------
#
# Same streaming structure, but the query block is a *candidate tree* (see
# kernels.tree_mask): a node must attend the committed prefix plus exactly
# its ancestors-or-self inside the block.  The ancestor set rides along as a
# packed int32 bitmask per query row (bit n = node n visible), and KV slots
# carry a node index (-1 for prefix entries) so the kernel picks the bit
# test or the positional predicate per slot.  Positions are logical (RoPE)
# positions — prefix causality and sliding windows use them unchanged.


def _tree_verify_attn_kernel(qpos_ref, abits_ref, kvpos_ref, kvnode_ref,
                             q_ref, k_ref, v_ref,            # inputs
                             o_ref,                          # outputs
                             m_ref, l_ref, acc_ref,          # scratch
                             *, group: int, window: int, num_meta: int,
                             scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (RQ = kq*G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_kv, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (block_kv, hd)
    qpos = qpos_ref[0]                             # (RQ,) int32 logical pos
    abits = abits_ref[0]                           # (RQ,) int32 ancestor bits
    kvpos = kvpos_ref[0]                           # (block_kv,) int32
    kvnode = kvnode_ref[0]                         # (block_kv,) int32 (-1=prefix)

    scores = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (RQ, block_kv)

    qp = qpos[:, None]
    kp = kvpos[None, :]
    kn = kvnode[None, :]
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp < window) | (kp < num_meta)
    # tree slots additionally require the ancestor bit; ancestors sit at
    # shallower depth so (kp <= qp) already holds for every visible one
    bit = jax.lax.shift_right_logical(
        abits[:, None], jnp.clip(kn, 0, 31)) & 1
    mask &= (kn < 0) | (bit != 0)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                            # (RQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                    # (RQ, block_kv)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def tree_verify_attention_pallas(q, k, v, q_pos, kv_pos, kv_node, anc_bits, *,
                                 window: int = 0, num_meta: int = 0,
                                 block_kv: int = 512,
                                 interpret: bool = False) -> jnp.ndarray:
    """Tree-verification attention over a positional KV cache.

    q: (B, kq, H, hd) — the kq candidate-tree nodes; k/v: (B, L, KV, hd);
    q_pos: (B, kq) logical (RoPE) positions, i.e. length + depth[node];
    kv_pos: (B, L) logical positions (-1 = empty/stale);
    kv_node: (B, L) int32 — node index for slots holding this block's tree
    nodes, -1 for committed-prefix slots;
    anc_bits: (B, kq) int32 — packed ancestor-or-self bitmask per node
    (``TreeTopology.anc_bits``; ≤32 nodes).

    Returns (B, kq, H, hd).
    """
    b, kq, h, hd = q.shape
    l, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = float(hd) ** -0.5

    rq = kq * g
    rq_pad = max(8, ((rq + 7) // 8) * 8)
    hd_pad = max(128, ((hd + 127) // 128) * 128)
    block_kv = min(block_kv, ((l + 7) // 8) * 8)
    l_pad = ((l + block_kv - 1) // block_kv) * block_kv

    qr = q.reshape(b, kq, kvh, g, hd).transpose(0, 2, 1, 3, 4).reshape(b, kvh, rq, hd)
    qr = jnp.pad(qr, ((0, 0), (0, 0), (0, rq_pad - rq), (0, hd_pad - hd)))
    kr = jnp.pad(k.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, l_pad - l), (0, hd_pad - hd)))
    vr = jnp.pad(v.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, l_pad - l), (0, hd_pad - hd)))

    qpos_rows = jnp.repeat(q_pos, g, axis=1)                     # (B, rq)
    qpos_rows = jnp.pad(qpos_rows, ((0, 0), (0, rq_pad - rq)),
                        constant_values=-(2 ** 30))
    abits_rows = jnp.repeat(anc_bits.astype(jnp.int32), g, axis=1)
    abits_rows = jnp.pad(abits_rows, ((0, 0), (0, rq_pad - rq)))
    kvpos_p = jnp.pad(kv_pos, ((0, 0), (0, l_pad - l)), constant_values=-1)
    kvnode_p = jnp.pad(kv_node.astype(jnp.int32), ((0, 0), (0, l_pad - l)),
                       constant_values=-1)

    grid = (b, kvh, l_pad // block_kv)
    out = pl.pallas_call(
        functools.partial(_tree_verify_attn_kernel, group=g, window=window,
                          num_meta=num_meta, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rq_pad), lambda bi, hi, ki: (bi, 0)),
            pl.BlockSpec((1, rq_pad), lambda bi, hi, ki: (bi, 0)),
            pl.BlockSpec((1, block_kv), lambda bi, hi, ki: (bi, ki)),
            pl.BlockSpec((1, block_kv), lambda bi, hi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, rq_pad, hd_pad), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd_pad), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd_pad), lambda bi, hi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rq_pad, hd_pad),
                               lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, rq_pad, hd_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rq_pad, 1), jnp.float32),
            pltpu.VMEM((rq_pad, 1), jnp.float32),
            pltpu.VMEM((rq_pad, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_rows, abits_rows, kvpos_p, kvnode_p, qr, kr, vr)

    out = out[:, :, :rq, :hd].reshape(b, kvh, kq, g, hd)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, kq, h, hd)
