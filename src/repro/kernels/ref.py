"""Pure-jnp oracles for the Pallas kernels.

Self-contained (no imports from repro.models) so a kernel test failure
unambiguously implicates the kernel, not the model stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# block_attention oracle
# ---------------------------------------------------------------------------


def verify_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     num_meta: int = 0) -> jnp.ndarray:
    """q: (B, kq, H, hd); k/v: (B, L, KV, hd); q_pos (B, kq); kv_pos (B, L)."""
    b, kq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kq, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp < window) | (kp < num_meta)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgqs,bshk->bqhgk", probs, v.astype(jnp.float32))
    return ctx.reshape(b, kq, h, hd).astype(q.dtype)


def tree_verify_attention(q, k, v, q_pos, kv_pos, kv_node, anc_bits, *,
                          window: int = 0, num_meta: int = 0) -> jnp.ndarray:
    """Tree-verification attention oracle (see block_attention's tree
    variant).  kv_node: (B, L) node index for this block's tree slots, -1
    for committed-prefix slots; anc_bits: (B, kq) packed ancestor-or-self
    bitmask per query node.  Positions are logical (RoPE) positions."""
    b, kq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kq, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    kn = kv_node[:, None, :]
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp < window) | (kp < num_meta)
    bit = jax.lax.shift_right_logical(
        anc_bits.astype(jnp.int32)[:, :, None], jnp.clip(kn, 0, 31)) & 1
    mask &= (kn < 0) | (bit != 0)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgqs,bshk->bqhgk", probs, v.astype(jnp.float32))
    return ctx.reshape(b, kq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged_attention oracle
# ---------------------------------------------------------------------------


def paged_verify_attention(q, kp, vp, tbl, q_pos, kv_pos, *, window: int = 0,
                           num_meta: int = 0) -> jnp.ndarray:
    """q: (B, kq, H, hd); kp/vp: (num_pages, ps, KV, hd); tbl: (B, P);
    kv_pos: (B, P*ps).  Gather the pages densely, then the dense oracle."""
    b, P = tbl.shape
    _, ps, kvh, hd = kp.shape
    k = kp[tbl].reshape(b, P * ps, kvh, hd)
    v = vp[tbl].reshape(b, P * ps, kvh, hd)
    return verify_attention(q, k, v, q_pos, kv_pos, window=window,
                            num_meta=num_meta)


# ---------------------------------------------------------------------------
# rwkv6_scan oracle (sequential recurrence, f32)
# ---------------------------------------------------------------------------


def rwkv6_scan(r, k, v, logw, u):
    """r/k/v/logw: (B, S, H, D); u: (H, D).  Zero initial state.

    Returns (y (B,S,H,D) f32, final_state (B,H,D,D) f32)."""
    b, s, h, d = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                                    # (B, H, D)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        yt = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * kv)
        return wt[..., None] * S + kv, yt

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    state0 = jnp.zeros((b, h, d, d), jnp.float32)
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), final


# ---------------------------------------------------------------------------
# fused_heads oracle
# ---------------------------------------------------------------------------


def heads_topk(o, w_vocab, *, vocab: int, top_t: int = 4):
    """o: (N, d); w_vocab: (d, Vp).  Full-logits top-T over logical vocab."""
    logits = o.astype(jnp.float32) @ w_vocab.astype(jnp.float32)
    lane = jnp.arange(logits.shape[-1])
    logits = jnp.where(lane[None, :] < vocab, logits, NEG_INF)
    vals, ids = jax.lax.top_k(logits, top_t)
    return vals, ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# fused_verify oracle (materialized top-T + prefix-accept scan)
# ---------------------------------------------------------------------------


def fused_verify(p1_logits, proposals, *, criterion: str, top_k: int = 1,
                 epsilon: float = 0.0):
    """p1_logits: (B, k, V); proposals: (B, k) int32 (slot 0 = verified).

    Returns (accepts (B, k) bool, k̂ (B,) int32, accepted_tokens (B, k)
    int32, next_greedy (B,) int32) — same contract and tie-breaking
    (``lax.top_k`` is stable, lowest token id wins) as the Pallas kernel.
    """
    b, k, _ = p1_logits.shape
    top_t = max(1, int(top_k)) if criterion == "topk" else 1
    _, ids = jax.lax.top_k(p1_logits.astype(jnp.float32), top_t)
    greedy = ids[..., 0]                                    # (B, k)
    cand = proposals[:, 1:]
    if criterion == "exact":
        ok = cand == greedy[:, :k - 1]
    elif criterion == "topk":
        ok = jnp.any(ids[:, :k - 1, :] == cand[..., None], axis=-1)
    elif criterion == "distance":
        ok = jnp.abs(cand - greedy[:, :k - 1]).astype(jnp.float32) <= epsilon
    else:
        raise ValueError(f"unknown criterion {criterion!r}")
    acc = jnp.concatenate([jnp.ones((b, 1), jnp.bool_), ok], axis=1)
    rej = jnp.logical_not(acc)
    first = jnp.argmax(rej.astype(jnp.int32), axis=1)
    khat = jnp.where(jnp.any(rej, axis=1), first, k).astype(jnp.int32)
    slot = jnp.arange(k)[None, :]
    toks = jnp.where(slot < khat[:, None], proposals, 0).astype(jnp.int32)
    nxt = jnp.take_along_axis(greedy, (khat - 1)[:, None], axis=1)[:, 0]
    return acc, khat, toks, nxt.astype(jnp.int32)
