"""Static draft-tree topology + packed tree-attention masks.

Tree verification (cf. arXiv:2404.09221, PAPERS.md) scores a whole candidate
tree of draft tokens in ONE verify forward instead of a single chain: each
tree node attends to its root-to-node ancestor chain (plus the committed
cache), so p_1's logits at node n are exactly the chain-conditioned
verification logits for n's token.  The topology is static (fixed per
policy, known at trace time), so everything derived here — depths, sibling
ranks, the ancestor matrix, the root-to-leaf path table, the packed per-row
ancestor bitmasks consumed by the Pallas kernel — is plain numpy computed
once per (parents) tuple and baked into the compiled program as constants.

This module is a *leaf*: it imports nothing from ``repro.core`` or
``repro.models`` so both sides (the ``TopKTreeDrafter`` in ``core.policy``
and the tree-masked attention in ``models.attention`` /
``kernels.block_attention``) can share one topology object without an
import cycle.

Node conventions (mirroring the block-slot conventions of core/policy.py):

  * Node 0 is the root and MUST carry the verified greedy token (the tree
    analogue of "slot 0 of every draft is the verified token"), so the
    accepted path always has length ≥ 1.
  * ``parents[n] < n`` — nodes are listed in topological (BFS-compatible)
    order; node n occupies block slot n in the verify forward, writing its
    KV at storage position ``length + n`` while attending at logical
    position ``length + depth[n]``.
  * With ``block_k`` nodes the tree forward has exactly the same width as
    the chain forward — mean-k̂ gains come at equal FLOPs per iteration.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np

MAX_PACKED_NODES = 32  # packed ancestor bitmasks are int32 (bit n = node n)


@functools.lru_cache(maxsize=None)
def _derived(parents: Tuple[int, ...]):
    """All numpy tables derived from a parents tuple (cached per topology)."""
    n = len(parents)
    depth = np.zeros((n,), np.int32)
    for i in range(1, n):
        depth[i] = depth[parents[i]] + 1
    # sibling rank: i-th child (by node id) of the same parent
    seen: dict = {}
    rank = np.zeros((n,), np.int32)
    for i in range(1, n):
        rank[i] = seen.get(parents[i], 0)
        seen[parents[i]] = rank[i] + 1
    # ancestor-or-self matrix: anc[q, a] == True iff a is on q's root path
    anc = np.zeros((n, n), bool)
    for q in range(n):
        a = q
        while a >= 0:
            anc[q, a] = True
            a = parents[a]
    # path[q, d] = q's ancestor at depth d (-1 beyond q's own depth)
    max_depth = int(depth.max()) if n else 0
    path = np.full((n, max_depth + 1), -1, np.int32)
    for q in range(n):
        a = q
        while a >= 0:
            path[q, depth[a]] = a
            a = parents[a]
    bits = None
    if n <= MAX_PACKED_NODES:
        weights = (1 << np.arange(n, dtype=np.int64))
        bits = (anc.astype(np.int64) @ weights).astype(np.int64)
        bits = bits.astype(np.uint32).view(np.int32)  # wrap bit 31 safely
    return depth, rank, anc, path, bits


@dataclasses.dataclass(frozen=True)
class TreeTopology:
    """A static draft tree: node n's parent is ``parents[n]`` (root = -1)."""

    parents: Tuple[int, ...]

    def __post_init__(self):
        p = tuple(int(x) for x in self.parents)
        object.__setattr__(self, "parents", p)
        if not p or p[0] != -1:
            raise ValueError(f"node 0 must be the root (parents[0] == -1), "
                             f"got {p!r}")
        for i, a in enumerate(p[1:], start=1):
            if not 0 <= a < i:
                raise ValueError(f"parents must be topologically ordered "
                                 f"(0 <= parents[{i}] < {i}), got {a}")

    @property
    def num_nodes(self) -> int:
        return len(self.parents)

    @property
    def depths(self) -> np.ndarray:
        """(N,) int32 — node depths (root = 0)."""
        return _derived(self.parents)[0]

    @property
    def ranks(self) -> np.ndarray:
        """(N,) int32 — sibling rank of each node (i-th child of its parent)."""
        return _derived(self.parents)[1]

    @property
    def max_depth(self) -> int:
        return int(self.depths.max())

    @property
    def anc_matrix(self) -> np.ndarray:
        """(N, N) bool — anc[q, a] iff node a is on q's root path (self incl.)."""
        return _derived(self.parents)[2]

    @property
    def path_matrix(self) -> np.ndarray:
        """(N, max_depth+1) int32 — ancestor of node q at depth d, or -1."""
        return _derived(self.parents)[3]

    @property
    def anc_bits(self) -> np.ndarray:
        """(N,) int32 — packed ancestor bitmask per node (bit a of row q set
        iff ``anc_matrix[q, a]``), the layout the Pallas tree-attention
        kernel consumes.  Requires ≤ 32 nodes."""
        bits = _derived(self.parents)[4]
        if bits is None:
            raise ValueError(
                f"packed tree masks support at most {MAX_PACKED_NODES} "
                f"nodes, got {self.num_nodes}")
        return bits


def default_tree(block_k: int, fanout: int) -> TreeTopology:
    """The default verification tree for ``block_k`` nodes.

    Node 0 (root) carries the verified token; nodes 1..f (f = min(fanout,
    block_k-1)) are the root's children — the verifier gets ``f`` shots at
    the first speculative position; the remaining nodes form a top-1 chain
    below node 1.  Node 1's chain is exactly the classic heads chain
    (rank-0 candidate at every depth), so the tree's accepted path is
    never shorter than the chain's accepted prefix — up to the tree's own
    depth cap of ``block_k - f + 1``.
    """
    if block_k < 1:
        raise ValueError(f"block_k must be >= 1, got {block_k}")
    if block_k == 1:
        return TreeTopology((-1,))
    f = max(1, min(int(fanout), block_k - 1))
    parents = [-1] + [0] * f
    prev = 1
    for n in range(f + 1, block_k):
        parents.append(prev)
        prev = n
    return TreeTopology(tuple(parents))
