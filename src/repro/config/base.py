"""Config dataclasses for the framework.

A single ``ModelConfig`` describes every architecture family in the assigned
pool (dense / MoE / SSM / hybrid / VLM / audio) plus the paper's own
encoder-decoder MT model.  ``DecodeConfig`` carries the blockwise-parallel-
decoding (BPD) parameters from the paper; ``TrainConfig`` the optimizer/loop
parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio | seq2seq
    source: str = ""               # citation for the config numbers

    # --- trunk shape ---------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4             # query heads (ignored for attn-free blocks)
    num_kv_heads: int = 4          # GQA kv heads
    head_dim: int = 0              # 0 -> d_model // num_heads
    d_ff: int = 1024               # dense MLP width (per-expert width for MoE)
    vocab_size: int = 512

    # --- block composition ---------------------------------------------------
    block_type: str = "attn"       # attn | rwkv6 | hymba
    mlp_type: str = "dense"        # dense | moe | rwkv_channel_mix
    activation: str = "silu"       # silu | gelu | relu2 | geglu
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    qk_norm: bool = False
    tie_embeddings: bool = False

    # --- attention -----------------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full attention
    global_attn_layers: Tuple[int, ...] = ()  # layers exempt from the window
    attn_logit_softcap: float = 0.0

    # --- encoder / seq2seq ---------------------------------------------------
    is_encoder_only: bool = False
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_pad_multiple: int = 1   # pad expert count so it shards on `model`
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0    # total width of the shared-expert MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- SSM / hybrid --------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_expand: int = 2            # d_inner = ssm_expand * d_model (mamba)
    ssm_conv_width: int = 4
    rwkv_head_dim: int = 64
    num_meta_tokens: int = 0       # hymba learnable prefix tokens

    # --- modality frontends (stubbed per the brief) --------------------------
    modality: str = "text"         # text | vision_text | audio
    num_patch_tokens: int = 0      # VLM: precomputed patch embeddings
    frontend_dim: int = 0          # dim of the stub embeddings (0 -> d_model)

    # --- blockwise parallel decoding (the paper's technique) -----------------
    bpd_k: int = 8                 # number of prediction heads p_1..p_k
    bpd_hidden: int = 0            # head FFN hidden size (0 -> d_ff heuristic)
    bpd_enabled: bool = True       # hubert: no autoregressive decode
    bpd_identity_p1: bool = True   # paper footnote 1: identity head for p_1

    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    max_seq_len: int = 8192
    remat: bool = False            # per-block activation checkpointing (train)

    # ------------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table and
        lm_head shard evenly on the model axis (MaxText-style padding).  The
        pad logits are masked to -inf in ``project_vocab``; token ids are
        always < vocab_size so embedding lookups never see the pad rows."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def padded_num_experts(self) -> int:
        """Expert count rounded up to ``expert_pad_multiple`` so the expert
        dim of the MoE weights/buffers divides the model mesh axis (qwen2's
        60 experts pad to 64 = 4 dead lanes; the router never selects ids
        >= num_experts, so pad experts receive no tokens)."""
        if not self.num_experts:
            return 0
        m = max(self.expert_pad_multiple, 1)
        return ((self.num_experts + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def resolved_bpd_hidden(self) -> int:
        return self.bpd_hidden or min(self.d_ff, 4 * self.d_model)

    @property
    def compute_dtype(self):
        return DTYPES[self.dtype]

    @property
    def params_dtype(self):
        return DTYPES[self.param_dtype]

    @property
    def num_kv_groups(self) -> int:
        return max(self.num_heads, 1) // max(self.num_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        if self.block_type == "attn" or self.block_type == "hymba":
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                f"{self.name}: num_heads={self.num_heads} not divisible by "
                f"num_kv_heads={self.num_kv_heads}"
            )
        if self.mlp_type == "moe":
            assert self.num_experts > 0 and self.num_experts_per_tok > 0
        if self.block_type == "rwkv6":
            assert self.d_model % self.rwkv_head_dim == 0
        if self.is_encoder_decoder:
            assert self.num_encoder_layers > 0


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """Paper §3-§5 decode-time parameters.

    ``policy`` names a registered ``core.policy.DecodePolicy`` (drafter ×
    acceptor × block schedule); empty string falls back to the legacy
    ``criterion`` alias, so existing configs decode unchanged.  The policy
    builders read their knobs (``top_k``, ``epsilon``, ``min_block``) off
    this config.  Policies whose drafter runs a second model
    (``policy="draft_model"``) additionally need an auxiliary
    ``core.bundle.ModelBundle`` passed to the session / decode entry
    point (``bundles={"draft": ...}``) — model identity lives in bundles,
    never in this config.
    """

    max_new_tokens: int = 64
    block_k: int = 0               # 0 -> model's bpd_k
    criterion: str = "exact"       # exact | topk | distance  (§3, §5.1, §5.2)
    policy: str = ""               # registered DecodePolicy name ("" -> criterion)
    top_k: int = 1                 # §5.1 top-k selection threshold
    epsilon: float = 0.0           # §5.2 distance-based tolerance
    min_block: int = 1             # §5.3 minimum accepted block size
    eos_id: int = -1               # -1: decode for max_new_tokens (image-style)
    temperature: float = 0.0       # 0 = greedy (paper setting)
    cache_backend: str = "dense"   # dense | paged (models.cache.get_backend)
    page_size: int = 16            # tokens per KV page (paged backend only)
    fused_verify: bool = False     # one-pass Pallas accept kernel (token-
    #                                identical opt-in; kernels/fused_verify.py)
    # 2-D raster geometry for the locality-aware image policy
    # (core.policy."locality"): the token stream is an image serialized in
    # the progressive-lattice order of data.synthetic.locality_order.
    image_height: int = 0          # grid rows (0 = not an image workload)
    image_width: int = 0           # grid cols
    locality_stride: int = 4       # coarse-lattice stride (power of two)

    def replace(self, **kw) -> "DecodeConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    global_batch: int = 32
    seq_len: int = 256
    steps: int = 200
    # optimizer
    optimizer: str = "adamw"       # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    schedule: str = "inv_sqrt"     # inv_sqrt | cosine | constant
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.98
    eps: float = 1e-9
    grad_clip: float = 1.0
    # BPD head training (paper §6)
    head_loss: str = "random"      # random (paper) | mean
    freeze_base: bool = False      # §6.1 fine-tuning ablation
    detach_head_residual: bool = False  # stabilized fine-tuning (see heads.py)
    label_smoothing: float = 0.0
    z_loss: float = 1e-4
    # Parallel scheduled sampling (arXiv:1906.04331): one extra no-grad
    # forward predicts every position; the conditioning prefix is mixed
    # gold -> model per position with an annealed probability so heads /
    # draft students train on decode-time distributions (targets stay gold
    # unless ss_self_targets).
    scheduled_sampling: bool = False
    ss_ratio: float = 0.5          # peak probability of a model-token swap
    ss_anneal_steps: int = 0       # linear 0 -> ss_ratio ramp (0 = constant)
    # Self-distilled targets: supervise heads with the frozen base's own
    # (deterministic) chain predictions instead of the (stochastic) gold
    # stream — exact-acceptance verification accepts a slot iff the head
    # matches p_1's chain, so this trains the actual acceptance condition
    # ("consistent mode breaking", the §6.2 distillation effect applied to
    # heads).  Only meaningful with scheduled_sampling and a frozen base.
    ss_self_targets: bool = False

    def __post_init__(self):
        valid_head_loss = ("random", "mean")
        if self.head_loss not in valid_head_loss:
            raise ValueError(
                f"TrainConfig.head_loss must be one of {valid_head_loss}, "
                f"got {self.head_loss!r}")
        if not 0.0 <= self.ss_ratio <= 1.0:
            raise ValueError(
                f"TrainConfig.ss_ratio must be in [0, 1], got {self.ss_ratio}")
        if self.ss_anneal_steps < 0:
            raise ValueError(
                f"TrainConfig.ss_anneal_steps must be >= 0, "
                f"got {self.ss_anneal_steps}")

    def replace(self, **kw) -> "TrainConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Production mesh description (v5e pod target)."""

    multi_pod: bool = False
    data: int = 16
    model: int = 16
    pods: int = 2

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.multi_pod else (self.data, self.model)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = self.data * self.model
        return n * self.pods if self.multi_pod else n


# Input-shape grid assigned to this paper (see DESIGN.md §6).
INPUT_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}
