from repro.config.base import (
    DTYPES,
    INPUT_SHAPES,
    DecodeConfig,
    MeshConfig,
    ModelConfig,
    TrainConfig,
)
from repro.config.registry import (
    get_config,
    get_policy,
    list_archs,
    list_policies,
    register,
)

__all__ = [
    "DTYPES",
    "INPUT_SHAPES",
    "DecodeConfig",
    "MeshConfig",
    "ModelConfig",
    "TrainConfig",
    "get_config",
    "get_policy",
    "list_archs",
    "list_policies",
    "register",
]
