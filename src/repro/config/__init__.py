from repro.config.base import (
    DTYPES,
    INPUT_SHAPES,
    DecodeConfig,
    MeshConfig,
    ModelConfig,
    TrainConfig,
)
from repro.config.registry import get_config, list_archs, register

__all__ = [
    "DTYPES",
    "INPUT_SHAPES",
    "DecodeConfig",
    "MeshConfig",
    "ModelConfig",
    "TrainConfig",
    "get_config",
    "list_archs",
    "register",
]
