"""Architecture registry: ``--arch <id>`` resolution.

Every module in ``repro.configs`` registers a full production config and a
reduced smoke-test config (<=2 layers, d_model<=512, <=4 experts) of the same
family.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, Dict[str, Callable[[], ModelConfig]]] = {}


def register(name: str, config_fn: Callable[[], ModelConfig], smoke_fn: Callable[[], ModelConfig]):
    if name in _REGISTRY:
        raise ValueError(f"duplicate arch registration: {name}")
    _REGISTRY[name] = {"config": config_fn, "smoke": smoke_fn}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    # Import side-effect populates the registry on first use.
    import repro.configs  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]["smoke" if smoke else "config"]()
    cfg.validate()
    return cfg


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Decode policies: ``--policy <name>`` resolution (CLI / config back-compat).
# The canonical registry lives in ``repro.core.policy`` (imported lazily —
# config must stay importable without the decode stack); these wrappers give
# launchers one place to resolve both architectures and policies.
# ---------------------------------------------------------------------------


def get_policy(dec, policy=None):
    """Resolve a ``DecodePolicy`` for ``dec`` (a DecodeConfig).

    ``policy`` may be a registered name, a ``DecodePolicy`` object, or None
    (fall back to ``dec.policy``, then the legacy ``dec.criterion`` alias).

    This is the blessed construction path for decode policies::

        dec = DecodeConfig(policy="topk", top_k=2, block_k=8)
        pol = get_policy(dec)              # DecodePolicy object
        acc = pol.acceptor.accepts(proposals, p1_logits)
        khat, sched_state = pol.schedule.block_size(acc, remaining, state)

    Set ``DecodeConfig.policy`` to a registered name (``list_policies()``)
    and parameterize through the config fields (``top_k``, ``epsilon``,
    ``min_block`` …); pass a hand-built ``DecodePolicy`` object only for
    combinations the registry doesn't name.  ``DecodeConfig.fused_verify``
    (CLI: ``launch/serve.py --fused-verify``) swaps every builder's
    acceptor to the one-pass Pallas accept kernel
    (``kernels/fused_verify``) — token-identical, so policies resolve the
    same tokens with it on or off.  The criterion-string shims that used
    to live in ``repro.core.verify`` (``position_accepts`` /
    ``accepted_block_size``) are REMOVED — they raise ValueError pointing
    back here; this function is the only policy resolution path.
    """
    from repro.core.policy import resolve_policy

    return resolve_policy(dec, policy)


def list_policies() -> list[str]:
    from repro.core.policy import list_policies as _lp

    return _lp()
