from repro.utils.tree import (
    flatten_with_names,
    global_norm,
    match_rules,
    path_str,
    tree_bytes,
    tree_cast,
    tree_map_with_name,
    tree_size,
)

__all__ = [
    "flatten_with_names",
    "global_norm",
    "match_rules",
    "path_str",
    "tree_bytes",
    "tree_cast",
    "tree_map_with_name",
    "tree_size",
]
