"""Pytree utilities used across the framework.

Pure-JAX (no flax/optax in this environment): parameters, optimizer states
and caches are plain nested dicts of jnp arrays.  These helpers keep that
manageable.
"""
from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_size(tree: Pytree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def path_str(path) -> str:
    """Render a jax KeyPath as a '/'-joined string, e.g. 'blocks/3/attn/wq'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # pragma: no cover - future key types
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_name(fn: Callable[[str, Any], Any], tree: Pytree) -> Pytree:
    """tree_map where fn receives ('a/b/c', leaf)."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(path_str(p), x), tree)


def tree_select(pred: Callable[[str], bool], tree: Pytree) -> Pytree:
    """Zero-out (stop-gradient style masks) helper: returns a {0,1} mask tree."""
    return tree_map_with_name(
        lambda name, x: jnp.ones((), x.dtype) if pred(name) else jnp.zeros((), x.dtype),
        tree,
    )


def match_rules(name: str, rules: list[tuple[str, Any]], default: Any) -> Any:
    """First regex rule (searched, not fullmatch) that hits wins."""
    for pattern, value in rules:
        if re.search(pattern, name):
            return value
    return default


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def flatten_with_names(tree: Pytree) -> list[tuple[str, Any]]:
    out: list[tuple[str, Any]] = []
    jax.tree_util.tree_map_with_path(lambda p, x: out.append((path_str(p), x)), tree)
    return out
