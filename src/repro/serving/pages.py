"""Host-side page allocator for the paged KV cache.

The device side (``models/cache.py``'s ``PagedBackend``) stores K/V in a
pool of fixed-size pages plus a per-slot block table; this module owns the
*host* bookkeeping that decides which physical pages each admitted request
maps to:

  * a free list of physical page ids (page 0 is a permanent trash page —
    never allocated, the target of every unmapped block-table entry, so
    writes from inactive rows land somewhere harmless),
  * per-page refcounts (copy-on-write prefix sharing means a page can back
    several slots at once),
  * a prefix map ``{(page_index, prompt_token_prefix): page_id}`` so two
    requests whose prompts agree on every token covered by a page share
    one physical copy, and
  * a reclaim queue (LRU) of zero-refcount pages that still hold a cached
    prefix — they stay reusable for future prompt hits until the pool
    needs the space (vLLM-style cache hold).

Everything here is plain Python over numpy outputs — no jax — so the
allocator is cheap to call per admission and easy to property-test.

Safety argument for sizing: ``plan_admit`` maps exactly
``ceil((prefix + prompt_len + max_new + block_k) / page_size)`` pages.
Admission prefill may write junk K/V for padded prompt positions beyond
that bound; those land on the trash page, and their ``pos`` entries are
never visible (``pos >= length + k`` forever), so the plan is exact, not
conservative.  Decode writes stay inside the mapped range by construction
(text length is monotone and capped at ``prompt_len + max_new``).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np


class PagePoolExhausted(RuntimeError):
    """Raised by ``plan_admit`` when the pool cannot cover a new request.

    The scheduler treats this as back-pressure: the request goes back to
    the queue and admission pauses until ``release`` frees pages.
    """


class PageAllocator:
    """Free-list page allocator with refcounts and CoW prefix sharing.

    Parameters
    ----------
    num_pages : total physical pages in the pool *including* the trash
        page 0 — so ``num_pages - 1`` pages are allocatable.
    page_size : tokens per page.
    pages_per_row : block-table width P (pages addressable per slot).
    prefix_len : model prefix tokens (meta tokens) occupying positions
        ``0..prefix_len-1`` of every row.  They are identical across
        requests, so pages fully covered by ``prefix_len + prompt`` can be
        shared whenever the *prompt* tokens under them agree.
    """

    def __init__(self, num_pages: int, page_size: int, pages_per_row: int,
                 *, prefix_len: int = 0):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.pages_per_row = int(pages_per_row)
        self.prefix_len = int(prefix_len)
        # page 0 reserved; hand out low ids first (stable, test-friendly)
        self.free: List[int] = list(range(num_pages - 1, 0, -1))
        self.refcount: Dict[int, int] = {}
        # (page_index, prompt-token prefix tuple) -> physical page
        self.prefix_map: Dict[Tuple, int] = {}
        self.page_key: Dict[int, Tuple] = {}
        # zero-ref pages still holding a cached prefix, oldest first
        self.reclaimable: "OrderedDict[int, None]" = OrderedDict()
        # slot -> list of mapped physical pages
        self.slot_pages: Dict[int, List[int]] = {}

    # -- internals ----------------------------------------------------------

    def _grab_page(self) -> Optional[int]:
        """A writable page: free list first, then evict the LRU cached
        prefix.  Returns None when the pool is truly exhausted."""
        if self.free:
            return self.free.pop()
        if self.reclaimable:
            page, _ = self.reclaimable.popitem(last=False)
            key = self.page_key.pop(page)
            del self.prefix_map[key]
            return page
        return None

    def _incref(self, page: int) -> None:
        self.refcount[page] = self.refcount.get(page, 0) + 1

    def _decref(self, page: int) -> None:
        n = self.refcount.get(page, 0)
        if n <= 0:
            raise RuntimeError(f"double free of page {page}")
        if n == 1:
            del self.refcount[page]
            if page in self.page_key:
                self.reclaimable[page] = None  # keep the cached prefix
            else:
                self.free.append(page)
        else:
            self.refcount[page] = n - 1

    # -- public API ---------------------------------------------------------

    def pages_needed(self, prompt_len: int, max_new: int,
                     block_k: int = 0) -> int:
        span = self.prefix_len + prompt_len + max_new + block_k
        return -(-span // self.page_size)

    def plan_admit(self, slot: int, prompt_tokens, prompt_len: int,
                   max_new: int, block_k: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Map pages for one admission.

        Returns ``(tbl_row, write_mask)``: the slot's block-table row
        ((P,) int32, trash page 0 beyond the mapped range) and a (P,) bool
        mask of pages the admit prefill must scatter into (False for CoW
        prefix hits — their bytes already exist — and for unmapped tail
        pages).  Raises :class:`PagePoolExhausted` (after rolling back any
        partial mappings) when the pool cannot supply the pages.
        """
        if slot in self.slot_pages:
            raise RuntimeError(f"slot {slot} already holds pages")
        ps, P = self.page_size, self.pages_per_row
        n_alloc = self.pages_needed(prompt_len, max_new, block_k)
        if n_alloc > P:
            raise ValueError(
                f"request needs {n_alloc} pages but rows address only {P}")
        if n_alloc > self.num_pages - 1:
            # not back-pressure: even a drained pool can never satisfy this
            raise ValueError(
                f"request needs {n_alloc} pages but the pool only has "
                f"{self.num_pages - 1} allocatable pages: raise "
                f"EngineConfig.page_pool_pages to at least {n_alloc + 1}")
        prompt = tuple(int(t) for t in np.asarray(prompt_tokens).reshape(-1)
                       [:prompt_len])

        tbl_row = np.zeros((P,), np.int32)
        write_mask = np.zeros((P,), bool)
        mapped: List[int] = []
        fresh_keys: List[int] = []  # prefixes registered by THIS plan
        for i in range(n_alloc):
            key = None
            # shareable iff entirely covered by prefix + real prompt tokens
            if (i + 1) * ps <= self.prefix_len + prompt_len:
                key = (i, prompt[:(i + 1) * ps - self.prefix_len])
            if key is not None and key in self.prefix_map:
                page = self.prefix_map[key]
                self.reclaimable.pop(page, None)  # back in active use
                self._incref(page)
                tbl_row[i] = page
                mapped.append(page)
                continue  # write_mask stays False: bytes already on device
            page = self._grab_page()
            if page is None:
                # Roll back this plan entirely.  Prefixes registered by
                # THIS plan must be unregistered first: the admit prefill
                # never ran, so their bytes don't exist device-side — left
                # registered they would satisfy a later plan as a CoW hit
                # (write_mask False) and serve garbage KV.
                for p in fresh_keys:
                    del self.prefix_map[self.page_key.pop(p)]
                for p in mapped:
                    self._decref(p)
                raise PagePoolExhausted(
                    f"page pool exhausted admitting slot {slot}: needed "
                    f"{n_alloc} pages, {len(mapped)} mapped before running "
                    f"out (pool={self.num_pages - 1} allocatable)")
            self._incref(page)
            if key is not None:  # future identical prefixes share this page
                self.prefix_map[key] = page
                self.page_key[page] = key
                fresh_keys.append(page)
            tbl_row[i] = page
            write_mask[i] = True
            mapped.append(page)
        self.slot_pages[slot] = mapped
        return tbl_row, write_mask

    def release(self, slot: int) -> int:
        """Return all of a slot's pages (on harvest/evict).  Shared pages
        just drop a reference; cached prefixes become reclaimable rather
        than free.  Returns the number of pages released."""
        pages = self.slot_pages.pop(slot, None)
        if pages is None:
            return 0
        for p in pages:
            self._decref(p)
        return len(pages)

    # -- introspection (tests, bench) ---------------------------------------

    def live_pages(self) -> int:
        """Pages currently referenced by at least one slot."""
        return len(self.refcount)

    def available_pages(self) -> int:
        """Pages a new admission could draw on (free + reclaimable)."""
        return len(self.free) + len(self.reclaimable)

    def check_invariants(self) -> None:
        """Internal-consistency assertions (used by property tests)."""
        allp = set(self.free) | set(self.refcount) | set(self.reclaimable)
        assert 0 not in allp, "trash page 0 leaked into the pool"
        assert len(self.free) + len(self.refcount) + len(self.reclaimable) \
            == self.num_pages - 1, "pages lost or duplicated"
        assert not (set(self.free) & set(self.refcount))
        assert not (set(self.free) & set(self.reclaimable))
        assert not (set(self.refcount) & set(self.reclaimable))
        for key, page in self.prefix_map.items():
            assert self.page_key.get(page) == key
        held = [p for pages in self.slot_pages.values() for p in pages]
        counts: Dict[int, int] = {}
        for p in held:
            counts[p] = counts.get(p, 0) + 1
        assert counts == self.refcount, "refcounts out of sync with slots"
