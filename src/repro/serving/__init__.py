"""Continuous-batching serving for blockwise parallel decoding.

Layering:
  types.py     — Request / FinishedRequest / EngineConfig
  engine.py    — SlotBatch device state + compiled admit/step/evict
  scheduler.py — queue, admission policy, workload driver, stats
"""
from repro.serving.engine import ContinuousBatchingEngine, SlotBatch
from repro.serving.scheduler import Scheduler, aggregate_stats
from repro.serving.types import EngineConfig, FinishedRequest, Request

__all__ = [
    "ContinuousBatchingEngine",
    "SlotBatch",
    "Scheduler",
    "aggregate_stats",
    "EngineConfig",
    "FinishedRequest",
    "Request",
]
