"""Continuous-batching serving for blockwise parallel decoding.

Layering:
  types.py     — Request / FinishedRequest / EngineConfig / SlotBatch
  session.py   — DecodeSession: sharding-aware owner of params + the jitted
                 decode functions (shared with core.decode entry points)
  engine.py    — scheduler + slot-metadata shell over a DecodeSession
  scheduler.py — queue, admission policy, workload driver, stats
"""
from repro.serving.engine import ContinuousBatchingEngine, PolicyGroup
from repro.serving.scheduler import Scheduler, aggregate_stats
from repro.serving.session import DecodeSession, ServingFns
from repro.serving.types import (EngineConfig, FinishedRequest, Request,
                                 SlotBatch)

__all__ = [
    "ContinuousBatchingEngine",
    "DecodeSession",
    "PolicyGroup",
    "ServingFns",
    "SlotBatch",
    "Scheduler",
    "aggregate_stats",
    "EngineConfig",
    "FinishedRequest",
    "Request",
]
