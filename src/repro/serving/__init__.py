"""Continuous-batching serving for blockwise parallel decoding.

Layering:
  types.py     — Request / FinishedRequest / PreemptedRequest /
                 EngineConfig / SlotBatch
  session.py   — DecodeSession: sharding-aware owner of params + the jitted
                 decode functions (shared with core.decode entry points)
  engine.py    — scheduler + slot-metadata shell over a DecodeSession
  scheduler.py — queue, admission policy, priorities/deadlines/preemption,
                 workload driver, stats
  frontend.py  — asyncio facade: per-request token streams + back-pressure
  server.py    — stdlib HTTP/1.1 + SSE surface over the frontend
"""
from repro.serving.engine import (ContinuousBatchingEngine, PagePoolExhausted,
                                  PolicyGroup)
from repro.serving.frontend import Backpressure, Frontend, StreamEvent
from repro.serving.scheduler import Scheduler, aggregate_stats
from repro.serving.server import HTTPServer
from repro.serving.session import DecodeSession, ServingFns
from repro.serving.types import (EngineConfig, FinishedRequest,
                                 PreemptedRequest, Request, SlotBatch)

__all__ = [
    "Backpressure",
    "ContinuousBatchingEngine",
    "DecodeSession",
    "Frontend",
    "HTTPServer",
    "PagePoolExhausted",
    "PolicyGroup",
    "PreemptedRequest",
    "ServingFns",
    "SlotBatch",
    "StreamEvent",
    "Scheduler",
    "aggregate_stats",
    "EngineConfig",
    "FinishedRequest",
    "Request",
]
