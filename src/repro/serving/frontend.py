"""Async front end bridging the asyncio serving surface to the
synchronous engine + scheduler loop.

Threading model — one rule: **the engine and scheduler are only ever
touched from the serve-loop tick**, which runs in an executor thread
(``await loop.run_in_executor(None, self._tick)``) so JAX dispatch and the
fused device→host sync never block the event loop.  Everything crossing
the boundary is plain data:

  * submissions: the async side validates against read-only engine config
    (prompt length, known policy group), stamps arrival, and appends the
    ``Request`` to a lock-protected pending list the tick drains;
  * results: the tick returns a flat list of ``(rid, StreamEvent)`` pairs
    (committed-token deltas from ``engine.poll_progress``, preemption
    remainders, stitched finish records) that the async side fans out to
    per-request ``asyncio.Queue`` streams.

Token streams are **exactly-once and in order**: progress polling emits
committed tokens as they land each group step; a preempted request's
unstreamed segment remainder is forwarded at eviction time (its
continuation re-admits with those tokens inside the prompt, so polling
never re-emits them); the finish record's unstreamed tail is emitted
before the ``done`` event.  Summed, the streamed tokens are byte-identical
to ``FinishedRequest.tokens`` — the SLO harness gates on this.

Back-pressure is explicit at admission: ``submit`` raises ``Backpressure``
(HTTP 429 + Retry-After upstream) when the wait queue is saturated.  The
page pool's ``PagePoolExhausted`` feeds the same signal — pool-starved
requests requeue and hold the wait queue open, so a saturated pool
surfaces as a full queue instead of unbounded buffering.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.scheduler import Scheduler
from repro.serving.types import Request

__all__ = ["Backpressure", "Draining", "StreamEvent", "Frontend"]


class Backpressure(RuntimeError):
    """Admission refused: the wait queue (or the page pool behind it) is
    saturated.  ``retry_after_s`` is the server's service-rate-informed
    resubmission hint (the HTTP layer sends it as ``Retry-After``)."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class Draining(RuntimeError):
    """Admission refused: the server is draining toward shutdown — no new
    requests, but everything already in flight runs to completion (the
    HTTP layer maps this to 503 so load balancers fail over)."""


@dataclasses.dataclass
class StreamEvent:
    """One per-request stream item.

    kind = "tokens": ``data`` is a 1-D int array of newly committed tokens.
    kind = "done":   ``data`` is the ``FinishedRequest`` (stitched across
                     preemptions); the stream ends after it.
    """

    kind: str
    data: Any


class Frontend:
    """Asyncio facade over a ``Scheduler``: submit() → per-request event
    stream, driven by a single background serve loop."""

    def __init__(self, scheduler: Scheduler, *, max_queue: int = 16,
                 idle_sleep_s: float = 0.005):
        self.scheduler = scheduler
        self.engine = scheduler.engine
        self.max_queue = max_queue
        self.idle_sleep_s = idle_sleep_s
        self._rid = itertools.count(1)
        self._lock = threading.Lock()       # guards _pending
        self._pending: List[Request] = []
        self._streams: Dict[int, asyncio.Queue] = {}
        self._emitted: Dict[int, int] = {}  # rid -> tokens streamed so far
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._ready = False
        self._draining = False
        # service counters (on top of scheduler/engine ones) for /metrics
        self.requests_total = 0
        self.rejected_total = 0
        self.tokens_streamed = 0
        self.finished_total = 0

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> None:
        if self._task is not None:
            return
        self._running = True
        self._task = asyncio.ensure_future(self._serve_loop())
        # readiness = the compiled serving path actually works: run one
        # no-op tick (compilation happened at engine construction; this
        # proves the loop thread can drive it)
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._tick)
        self._ready = True

    async def stop(self) -> None:
        self._running = False
        if self._task is not None:
            await self._task
            self._task = None
        self._ready = False

    async def drain(self) -> None:
        """Graceful drain: stop admission immediately (``submit`` raises
        ``Draining``; readiness goes false so load balancers route away),
        let every in-flight request — queued, parked in the KV handoff,
        or mid-decode — run to completion with its SSE tail flushed
        through the normal stream path, then stop the serve loop.

        Idempotent and safe to call concurrently with traffic: the serve
        loop itself detects quiescence (between ticks, so it never races
        the engine) and exits; this coroutine just awaits it.
        """
        self._draining = True
        self._ready = False
        if self._task is not None:
            await self._task
            self._task = None
        self._running = False

    @property
    def ready(self) -> bool:
        return self._ready

    @property
    def draining(self) -> bool:
        return self._draining

    # -- admission -----------------------------------------------------------

    def queue_depth(self) -> int:
        with self._lock:
            npend = len(self._pending)
        return npend + len(self.scheduler.queue)

    def _retry_after_s(self) -> float:
        """Resubmission hint: time for the backlog to drain one queue slot
        at the observed service rate, floored at 1s so clients never
        hot-spin against a cold estimator."""
        tpot = self.scheduler.tpot_est
        if tpot <= 0.0:
            return 1.0
        queued = self.scheduler.queue
        mean_new = (sum(r.max_new for r in queued) / len(queued)
                    if queued else self.engine.ecfg.max_new_cap)
        slots = max(self.engine.ecfg.num_slots, 1)
        return max(1.0, tpot * mean_new / slots)

    def submit(self, prompt, max_new: int, *, policy: Optional[str] = None,
               priority: int = 0, deadline_s: Optional[float] = None,
               src=None) -> Tuple[int, asyncio.Queue]:
        """Admit a request; returns ``(rid, event_queue)``.

        Raises ``Backpressure`` when the wait queue is saturated and
        ``ValueError`` for invalid prompts/policies — both decided here,
        synchronously, so a rejected request never occupies queue space.
        ``deadline_s`` is relative (seconds from now); it becomes the
        absolute monotonic deadline the scheduler preempts for.
        """
        if self._draining:
            self.rejected_total += 1
            raise Draining("server is draining: no new admissions")
        if self.queue_depth() >= self.max_queue:
            self.rejected_total += 1
            raise Backpressure(
                f"wait queue is full ({self.max_queue} requests): the slot "
                f"slab and page pool are saturated — retry later",
                self._retry_after_s())
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        p, cap = len(prompt), self.engine.ecfg.max_prompt_len
        if not 0 < p <= cap:
            raise ValueError(f"prompt length {p} outside (0, {cap}]")
        self.engine.group_for(policy)   # unknown policy -> ValueError (read-only)
        now = time.monotonic()
        req = Request(
            rid=next(self._rid), prompt=prompt, max_new=int(max_new),
            arrival=now, policy=policy, src=src, priority=int(priority),
            deadline=None if deadline_s is None else now + float(deadline_s))
        q: asyncio.Queue = asyncio.Queue()
        self._streams[req.rid] = q
        self._emitted[req.rid] = 0
        with self._lock:
            self._pending.append(req)
        self.requests_total += 1
        return req.rid, q

    # -- serve loop ----------------------------------------------------------

    def _tick(self) -> List[Tuple[int, StreamEvent]]:
        """One scheduler step, run on the executor thread — the ONLY place
        the engine/scheduler state is touched after start()."""
        with self._lock:
            drained, self._pending = self._pending, []
        for req in drained:
            self.scheduler.submit(req)
        if self.scheduler.drained():
            return []
        finished = self.scheduler.step()
        events: List[Tuple[int, StreamEvent]] = []
        # committed-token deltas for every live slot (one extra host pull
        # per active group; see engine.poll_progress)
        for req, toks in self.engine.poll_progress():
            self._emitted[req.rid] += len(toks)
            events.append((req.rid, StreamEvent("tokens", toks)))
        # preempted segments: forward the unstreamed remainder NOW — the
        # continuation carries these tokens inside its prompt, so progress
        # polling will never emit them again
        for rec in self.scheduler.take_preempt_events():
            rem = rec.tokens[rec.streamed:]
            if len(rem):
                self._emitted[rec.req.rid] += len(rem)
                events.append((rec.req.rid, StreamEvent("tokens", rem)))
        for f in finished:
            tail = f.tokens[self._emitted.pop(f.rid, 0):]
            if len(tail):
                events.append((f.rid, StreamEvent("tokens", tail)))
            events.append((f.rid, StreamEvent("done", f)))
        return events

    async def _serve_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while self._running:
            events = await loop.run_in_executor(None, self._tick)
            for rid, ev in events:
                if ev.kind == "tokens":
                    self.tokens_streamed += len(ev.data)
                q = self._streams.get(rid)
                if q is not None:
                    q.put_nowait(ev)
                    if ev.kind == "done":
                        self.finished_total += 1
                        del self._streams[rid]
            if not events:
                if self._draining and not self._streams:
                    with self._lock:
                        idle = not self._pending
                    # quiescence read between ticks (executor calls are
                    # strictly sequential, so this never races the engine)
                    if idle and await loop.run_in_executor(
                            None, self.scheduler.drained):
                        return      # drain complete: the loop retires itself
                await asyncio.sleep(self.idle_sleep_s)

    # -- observability -------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        """Flat counter/gauge snapshot for the /metrics endpoint."""
        sch, eng = self.scheduler, self.engine
        return {
            "requests_total": self.requests_total,
            "rejected_total": self.rejected_total,
            "finished_total": self.finished_total,
            "tokens_streamed_total": self.tokens_streamed,
            "preemptions_total": sch.preemptions,
            "backpressure_requeues_total": sch.backpressure_events,
            "queue_depth": self.queue_depth(),
            "active_slots": (eng.ecfg.num_slots - len(eng.free_slots())),
            "num_slots": eng.ecfg.num_slots,
            "engine_steps_total": eng.num_steps,
            "engine_admits_total": eng.num_admits,
            "host_syncs_total": eng.num_host_syncs,
            "stream_syncs_total": eng.num_stream_syncs,
            "tpot_estimate_seconds": sch.tpot_est,
            "draining": int(self._draining),
            # disaggregated prefill/decode + async-stream attribution
            "disaggregated": int(eng.disaggregated),
            "prefill_batches_total": eng.num_prefill_batches,
            "handoff_backlog": eng.handoff_backlog(),
            "attach_backpressure_total": eng.num_attach_backpressure,
            "overlap_harvests_total": eng.num_overlap_harvests,
            "time_in_prefill_seconds": eng.time_in_prefill,
            "time_in_decode_dispatch_seconds": eng.time_in_decode_dispatch,
            "time_in_harvest_seconds": eng.time_in_harvest,
        }
