"""Mesh-sharded decode sessions: one sharding-aware driver for every decode
entry point.

A ``DecodeSession`` owns the model parameters (device_put with
``sharding.policy.param_shardings`` when a mesh is given) and the jitted
decode functions, each built **once** per geometry from explicit
``in_shardings`` / ``out_shardings``:

  * run-to-completion — ``decode`` (bpd), ``greedy``, ``decode_seq2seq``:
    the loop-carried ``BPDState`` / ``GreedyState`` is pinned with
    ``sharding.policy.state_specs`` (batch over the data axes, caches via
    ``cache_specs`` — kv-heads or buffer length over ``model``), so GSPMD
    keeps it partitioned through the whole ``while_loop``.
  * serving — ``serving_fns(ecfg)`` returns the engine's compile-once
    ``init`` / ``admit`` / ``step`` / ``evict`` with ``SlotBatch`` pinned by
    ``slot_specs`` and the loop-carried state **donated** (``donate_argnums``)
    so HBM never holds two copies of the KV buffers between steps.
    Admission is a global scatter under a sharding constraint: the padded
    single-row prefill is replicated, then written into the batch-sharded
    slot buffers as a masked local write on the owning data shard.

Placement modes:

  * ``mesh=None`` (default): trace-transparent local mode — identical to
    the historical eager paths, safe under an outer ``jax.jit``.
  * ``mesh=None, jit=True``: compile-once entry points without placement
    (the static-batch benchmark baseline).
  * ``mesh=Mesh(..., ("data", "model"))``: fully sharded — Megatron-style
    tensor parallelism over ``model``, batch/slot parallelism over
    ``data`` (+ ``pod``).

All three decode entry points in ``core.decode`` and the
``ContinuousBatchingEngine`` run through this one session layer, so the
static-batch paper baselines and continuous batching share a single
driver.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import DecodeConfig, ModelConfig
from repro.core import decode as decode_lib
from repro.core import policy as policy_lib
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.serving.types import EngineConfig, SlotBatch
from repro.sharding import policy as sharding_policy

I32 = jnp.int32


class PagedGeometry(NamedTuple):
    """Static page-pool geometry of a serving slot group — everything the
    engine's host-side ``serving.pages.PageAllocator`` needs to mirror the
    device block tables."""

    page_size: int      # tokens per KV page
    pages_per_row: int  # block-table width P
    num_pages: int      # physical pool size (incl. trash page 0)
    prefix_len: int     # model prefix (meta tokens) before the prompt


def _structs(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _geometry(batch: Dict) -> tuple:
    return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                        for k, v in batch.items()))


class ServingFns(NamedTuple):
    """The engine's device functions, compiled once per (policy, geometry).

    ``aux`` is the session's {bundle name: params} dict of auxiliary
    models (empty for single-model sessions); it rides along wherever the
    decode policy may run a model of its own.  ``init`` takes the policy
    slot-group id (traced, so every group of the same policy and geometry
    shares one compiled function); ``admit`` additionally takes the
    request's source tokens (padded like the prompt) for source-drafting
    policies.
    """

    init: Callable      # (gid) -> SlotBatch (mesh-placed when sharded)
    admit: Callable     # (params, aux, state, slot, prompt, plen, max_new,
                        #  src[, tbl_row, write_mask]) -> state — the two
                        # trailing page-mapping args exist iff paged
    step: Callable      # (params, aux, state) -> (state, status (S,) int8)
    evict: Callable     # (state, mask) -> state
    paged: Optional["PagedGeometry"] = None  # page-pool geometry (None=dense)


class DecodeSession:
    """Sharding-aware owner of the model bundles + jitted decode entry
    points.

    ``policy`` fixes the session's DEFAULT decode policy (drafter ×
    acceptor × block schedule): every entry point is jitted once per
    (bundles, policy, geometry) — bundles are fixed at construction, so
    the per-session jit cache keys on (``DecodePolicy.cache_key``,
    geometry) — and the policy's loop-carried state is part of the
    sharded decode state (``sharding.policy.state_specs`` /
    ``slot_specs`` treat its batch-leading leaves like any other per-row
    array, with model-backed drafter caches spec'd under their own
    bundle's config).  ``serving_fns(policy=...)`` additionally builds
    per-policy serving functions for the engine's slot groups, sharing
    the same cache — one session serves heterogeneous per-request
    policies without recompiling.

    ``bundles`` ({name: core.bundle.ModelBundle}) are the session's
    auxiliary models — e.g. ``{"draft": ModelBundle(draft_params,
    draft_cfg)}`` for the ``draft_model`` policy.  Each bundle's params
    are device_put with its own ``param_shardings`` and threaded into
    every jitted entry point as an explicit argument, so they shard and
    cache-key exactly like the primary parameters; the static half of
    each bundle (cfg / kv_chunk / backend factory) is bound into the
    policy up front (``DecodePolicy.bind``), so incompatible bundles fail
    at construction, not at trace time.
    """

    def __init__(self, params, cfg: ModelConfig, dec: DecodeConfig, *,
                 mesh=None, kv_chunk: int = 0, backend=None,
                 jit: Optional[bool] = None, donate: Optional[bool] = None,
                 policy=None, bundles=None):
        self.cfg = cfg
        self.dec = dec
        self.bundles = dict(bundles or {})
        self.policy = policy_lib.resolve_policy(dec, policy).bind(
            self.bundles, cfg)
        self.mesh = mesh
        self.kv_chunk = kv_chunk
        self.backend = backend
        self.jit = (mesh is not None) if jit is None else bool(jit)
        self._donate = donate
        # a model-backed drafter exposes its bound model config as .cfg —
        # the sharding policy specs its loop-carried cache under it
        self.draft_cfg = getattr(self.policy.drafter, "cfg", None)
        if mesh is not None:
            self.param_shardings = sharding_policy.param_shardings(params, mesh)
            self.params = jax.device_put(params, self.param_shardings)
            self.aux_shardings = sharding_policy.bundle_param_shardings(
                self.bundles, mesh)
            self.aux_params = {n: jax.device_put(b.params,
                                                 self.aux_shardings[n])
                               for n, b in self.bundles.items()}
        else:
            self.param_shardings = None
            self.params = params
            self.aux_shardings = {}
            self.aux_params = {n: b.params for n, b in self.bundles.items()}
        self._fns: Dict[Any, Callable] = {}

    # -- placement helpers ---------------------------------------------------

    @property
    def donate(self) -> bool:
        """Donate loop-carried state buffers.  Defaults on for accelerator
        devices — XLA:CPU cannot alias donated buffers (it would only warn
        and copy), so host-mesh debug runs stay quiet.  Keyed off the
        session mesh's devices (the buffers live there), not the process
        default backend."""
        if self._donate is None:
            platform = (self.mesh.devices.flat[0].platform
                        if self.mesh is not None else jax.default_backend())
            self._donate = platform in ("gpu", "tpu")
        return self._donate

    def _with_mesh(self, fn):
        """Run (and, on first call, trace) ``fn`` under the session mesh so
        the model's internal GSPMD hints (``policy.maybe_shard``) activate."""
        if self.mesh is None:
            return fn
        mesh = self.mesh

        def call(*args):
            with mesh:
                return fn(*args)

        call._cache_size = getattr(fn, "_cache_size", None)
        return call

    def _constrain(self) -> Optional[Callable]:
        """State-constraint hook handed to the loop impls: pins the
        loop-carried NamedTuple state to its ``state_specs`` shardings."""
        if self.mesh is None:
            return None
        cfg, mesh = self.cfg, self.mesh

        draft_cfg = self.draft_cfg

        def constrain(state):
            specs = sharding_policy.state_specs(cfg, state, mesh,
                                                draft_cfg=draft_cfg)
            return jax.lax.with_sharding_constraint(
                state, sharding_policy.named(mesh, specs))

        return constrain

    def _out_shardings(self, fn, batch_size: int, *arg_structs):
        """Explicit output shardings: batch-leading arrays over the data
        axes, scalars/aggregates replicated."""
        mesh = self.mesh
        ax = sharding_policy.batch_axes(mesh, batch_size)

        def rule(s):
            if s.ndim >= 1 and s.shape[0] == batch_size:
                return NamedSharding(mesh, P(*([ax] + [None] * (s.ndim - 1))))
            return NamedSharding(mesh, P())

        return jax.tree_util.tree_map(rule, jax.eval_shape(fn, *arg_structs))

    def _get(self, key, build):
        fn = self._fns.get(key)
        if fn is None:
            fn = build()
            self._fns[key] = fn
        return fn

    def _jit_entry(self, fn, batch: Dict, extra_in=(), extra_structs=()):
        """jit one run-to-completion entry point with explicit shardings.

        Every entry point takes ``(params, aux, batch, *extra)`` — ``aux``
        is the {bundle name: params} dict of auxiliary models, sharded per
        bundle (empty dict for single-model sessions)."""
        if self.mesh is None:
            return jax.jit(fn)
        mesh = self.mesh
        b = next(iter(batch.values())).shape[0]
        in_sh = (self.param_shardings, self.aux_shardings,
                 sharding_policy.named(
                     mesh, sharding_policy.batch_specs(mesh, batch)),
                 *extra_in)
        out_sh = self._out_shardings(fn, b, _structs(self.params),
                                     _structs(self.aux_params),
                                     _structs(batch), *extra_structs)
        return self._with_mesh(
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh))

    # -- run-to-completion entry points -------------------------------------

    def decode(self, batch: Dict, *, max_new_rows=None):
        """Blockwise parallel decode (causal LM).  See core.decode.bpd_decode."""
        cfg, dec, pol = self.cfg, self.dec, self.policy
        if not self.jit:
            return decode_lib._bpd_decode_impl(
                self.params, cfg, dec, batch, max_new_rows,
                backend=self.backend, kv_chunk=self.kv_chunk, policy=pol,
                aux_params=self.aux_params)

        b = batch["tokens"].shape[0]
        budget = (jnp.full((b,), dec.max_new_tokens, I32)
                  if max_new_rows is None else jnp.asarray(max_new_rows, I32))

        def build():
            backend, kv_chunk = self.backend, self.kv_chunk
            constrain = self._constrain()

            def fn(params, aux, batch, budget):
                return decode_lib._bpd_decode_impl(
                    params, cfg, dec, batch, budget, backend=backend,
                    kv_chunk=kv_chunk, constrain=constrain, policy=pol,
                    aux_params=aux)

            extra_in, extra_structs = (), (jax.ShapeDtypeStruct((b,), I32),)
            if self.mesh is not None:
                ax = sharding_policy.batch_axes(self.mesh, b)
                extra_in = (NamedSharding(self.mesh, P(ax)),)
            return self._jit_entry(fn, batch, extra_in, extra_structs)

        fn = self._get(("bpd", pol.cache_key) + _geometry(batch), build)
        return fn(self.params, self.aux_params, batch, budget)

    def greedy(self, batch: Dict):
        """Greedy baseline (p_1 only).  See core.decode.greedy_decode."""
        cfg, dec = self.cfg, self.dec
        if not self.jit:
            return decode_lib._greedy_decode_impl(
                self.params, cfg, dec, batch, kv_chunk=self.kv_chunk)

        def build():
            kv_chunk = self.kv_chunk
            constrain = self._constrain()

            def fn(params, aux, batch):
                del aux  # greedy never drafts — uniform signature only
                return decode_lib._greedy_decode_impl(
                    params, cfg, dec, batch, kv_chunk=kv_chunk,
                    constrain=constrain)

            return self._jit_entry(fn, batch)

        fn = self._get(("greedy",) + _geometry(batch), build)
        return fn(self.params, self.aux_params, batch)

    def decode_seq2seq(self, batch: Dict):
        """Encode once, BPD the decoder.  See core.decode.bpd_decode_seq2seq."""
        cfg, dec, pol = self.cfg, self.dec, self.policy
        if not self.jit:
            return decode_lib._bpd_decode_seq2seq_impl(
                self.params, cfg, dec, batch, policy=pol,
                aux_params=self.aux_params)

        def build():
            constrain = self._constrain()

            def fn(params, aux, batch):
                return decode_lib._bpd_decode_seq2seq_impl(
                    params, cfg, dec, batch, constrain=constrain, policy=pol,
                    aux_params=aux)

            return self._jit_entry(fn, batch)

        fn = self._get(("s2s", pol.cache_key) + _geometry(batch), build)
        return fn(self.params, self.aux_params, batch)

    # -- serving (continuous batching) ---------------------------------------

    def bound_policy(self, policy=None):
        """Resolve ``policy`` (a registered name / DecodePolicy / None for
        the session default) and bind the session's bundles to it — the
        form every serving slot group runs."""
        if policy is None:
            return self.policy
        return policy_lib.resolve_policy(self.dec, policy).bind(
            self.bundles, self.cfg)

    def serving_fns(self, ecfg: EngineConfig, *, policy=None) -> ServingFns:
        """Compile-once device functions for the continuous-batching engine.

        All four are geometry-fixed by ``ecfg``: prompts are padded to
        ``max_prompt_len`` and slot indices are traced int32 scalars, so
        admit/step/evict each compile exactly once regardless of traffic —
        on a single device and on a ``("data", "model")`` mesh alike.

        ``policy`` overrides the session default for one policy slot group
        (per-request decode policies): the returned functions are built for
        that policy and CACHED per (policy identity, geometry) — the jit
        cache keys on ``DecodePolicy.cache_key``, so two groups running the
        same policy at the same geometry share one compiled step, and a
        heterogeneous engine compiles exactly one step per distinct
        (policy, geometry) with no per-step recompilation.
        """
        pol = self.bound_policy(policy)
        key = ("serving", pol.cache_key, ecfg)
        return self._get(key, lambda: self._build_serving_fns(ecfg, pol))

    def _build_serving_fns(self, ecfg: EngineConfig,
                           pol) -> ServingFns:
        cfg, dec, mesh = self.cfg, self.dec, self.mesh
        block_k = dec.block_k or cfg.bpd_k
        prefix = cfg.num_meta_tokens
        context_len = prefix + ecfg.max_prompt_len + ecfg.max_new_cap
        buf_len = ecfg.max_prompt_len + ecfg.max_new_cap + block_k
        backend = self.backend or decode_lib.causal_lm_backend(
            cfg, kv_chunk=self.kv_chunk)
        s = ecfg.num_slots

        # KV-cache backend (dense slab vs managed page pool).  One host
        # allocator per slot group drives the mapping for every layer, so
        # the block-table geometry is computed here once.
        paged_geom = None
        if dec.cache_backend == "paged":
            ps = dec.page_size
            P_ = cache_lib.pages_per_row(context_len, block_k, ps)
            pool = ecfg.page_pool_pages or (1 + s * P_)
            kv_backend: cache_lib.KVCacheBackend = cache_lib.PagedBackend(
                ps, num_pages=pool, managed=True)
            paged_geom = PagedGeometry(page_size=ps, pages_per_row=P_,
                                       num_pages=pool, prefix_len=prefix)
        else:
            kv_backend = cache_lib.get_backend(dec)

        def slots_batch(n: int) -> Dict:
            """Pseudo decode-entry batch for policy-state builders: the
            engine admits padded prompts, so drafters see a zeroed
            ``tokens`` batch of the admission geometry — this keeps their
            state SHAPES identical across init (n = num_slots, no params),
            admit (n = 1, prefilled for real) and evict (reset rows).
            ``src`` (same padded geometry) lets source-drafting policies
            (``input_copy``) serve through the engine: admission scatters
            the request's real source row over these zeros."""
            z = jnp.zeros((n, ecfg.max_prompt_len), I32)
            return {"tokens": z, "src": z}

        def init_slots(gid) -> SlotBatch:
            zeros = lambda: jnp.zeros((s,), I32)  # noqa: E731
            return SlotBatch(
                tokens=jnp.zeros((s, buf_len), I32),
                text_len=zeros(),
                prompt_len=zeros(),
                proposals=jnp.zeros((s, block_k), I32),
                caches=model_lib.init_caches(cfg, s, context_len, block_k,
                                             backend=kv_backend),
                active=jnp.zeros((s,), bool),
                finished=jnp.ones((s,), bool),  # empty slots read as finished
                generated=zeros(),
                max_new=zeros(),
                invocations=zeros(),
                policy_state=pol.init_state(cfg, dec, slots_batch(s), s),
                group=jnp.full((s,), gid, I32),
            )

        slot_sh = cache_sh = None
        if mesh is not None:
            struct = jax.eval_shape(init_slots, jax.ShapeDtypeStruct((), I32))
            slot_sh = sharding_policy.named(
                mesh, sharding_policy.slot_specs(cfg, struct, mesh,
                                                 policy=pol))
            cache_sh = slot_sh.caches

        def admit(params, aux, state: SlotBatch, slot, prompt, prompt_len,
                  max_new, src, tbl_row=None, write_mask=None) -> SlotBatch:
            """Prefill one padded prompt into row ``slot``.

            The single-row prefill is replicated work (batch 1 never splits
            the data axis); the writes into the slot batch are a global
            scatter constrained back to the slot shardings, so only the
            data shard owning ``slot`` mutates its rows.

            Under the paged backend the prefill still runs on a dense
            batch-1 workspace (page-aligned buffers, see
            ``PagedBackend.row_init``); ``tbl_row`` ((P,) int32) and
            ``write_mask`` ((P,) bool) are the host allocator's physical
            mapping for this slot — copy-on-write prefix hits arrive with
            ``write_mask=False`` and are left untouched in the pool.
            """
            row_caches = kv_backend.row_init(cfg, context_len, block_k)
            h = model_lib.embed_inputs(params, cfg, {"tokens": prompt[None]})
            positions = jnp.arange(h.shape[1], dtype=I32)
            hidden, _, row_caches = model_lib.forward_hidden(
                params, cfg, h, positions=positions, caches=row_caches,
                moe_full_capacity=True)
            last = jax.lax.dynamic_index_in_dim(
                hidden[0], prefix + prompt_len - 1, axis=0, keepdims=False)
            logits = model_lib.all_head_logits(params, cfg, last)  # (K, V)

            # per-slot policy state resets on admission — a fresh request
            # must not inherit the previous occupant's drafter/schedule
            # state — and the policy's drafter proposes the first block
            # (a model-backed drafter prefills its own cache on the padded
            # prompt here, with its params from ``aux``; a source-drafting
            # policy stores the request's src row)
            row_ps = pol.init_state(cfg, dec,
                                    {"tokens": prompt[None],
                                     "src": src[None]}, 1, aux=aux)
            last_tok = jnp.take(prompt, jnp.maximum(prompt_len - 1, 0))
            row_props, row_ds = decode_lib.initial_draft(
                pol, logits[None], prompt_len, block_k, row_ps.drafter,
                prev_token=last_tok[None], aux_params=aux)
            proposals = row_props[0]
            row_ps = row_ps._replace(drafter=row_ds)

            row_tokens = jnp.zeros((buf_len,), I32)
            row_tokens = row_tokens.at[:ecfg.max_prompt_len].set(prompt)
            upd = lambda arr, val: arr.at[slot].set(val)  # noqa: E731
            policy_state = jax.tree_util.tree_map(
                lambda full, row: full.at[slot].set(row[0]),
                state.policy_state, row_ps)
            return state._replace(
                tokens=upd(state.tokens, row_tokens),
                text_len=upd(state.text_len, prompt_len),
                prompt_len=upd(state.prompt_len, prompt_len),
                proposals=upd(state.proposals, proposals),
                caches=model_lib.scatter_cache_row(state.caches, row_caches,
                                                   slot, constraint=cache_sh,
                                                   tbl_row=tbl_row,
                                                   write_mask=write_mask),
                active=upd(state.active, True),
                finished=upd(state.finished, False),
                generated=upd(state.generated, 0),
                max_new=upd(state.max_new, max_new),
                invocations=upd(state.invocations, 1),  # the prefill call
                policy_state=policy_state,
            )

        def step(params, aux, state: SlotBatch):
            bst = decode_lib.BPDState(
                tokens=state.tokens, text_len=state.text_len,
                proposals=state.proposals, caches=state.caches,
                finished=state.finished, iters=jnp.zeros((), I32),
                generated=state.generated, policy_state=state.policy_state)
            out = decode_lib.bpd_iteration(
                params, cfg, dec, backend, bst, prefix_offset=prefix,
                max_new=state.max_new, active=state.active, policy=pol,
                aux_params=aux)
            stepped = state.active & ~state.finished
            new_state = state._replace(
                tokens=out.tokens, text_len=out.text_len,
                proposals=out.proposals, caches=out.caches,
                finished=out.finished, generated=out.generated,
                invocations=state.invocations + stepped.astype(I32),
                policy_state=out.policy_state)
            # fused harvest decision: one tiny (S,) array carries both the
            # active and the finished bits, so the host loop round-trips a
            # single transfer per step (bit 0 = active, bit 1 = harvestable)
            status = (state.active.astype(jnp.int8)
                      + 2 * (state.active & out.finished).astype(jnp.int8))
            return new_state, status

        def evict(state: SlotBatch, mask) -> SlotBatch:
            # evicted slots also drop their policy state, so a paused slot
            # can never leak schedule/drafter history into a later request
            # (paramless init: model-backed drafters reset to empty caches
            # of the same admission geometry — admit rebuilds them anyway)
            fresh = pol.init_state(cfg, dec, slots_batch(s), s)
            policy_state = jax.tree_util.tree_map(
                lambda full, init: jnp.where(
                    mask.reshape((-1,) + (1,) * (init.ndim - 1)), init, full),
                state.policy_state, fresh)
            return state._replace(
                active=state.active & ~mask,
                caches=model_lib.reset_cache_rows(state.caches, mask),
                policy_state=policy_state)

        if mesh is None:
            return ServingFns(init=jax.jit(init_slots),
                              admit=jax.jit(admit),
                              step=jax.jit(step),
                              evict=jax.jit(evict),
                              paged=paged_geom)

        rep = NamedSharding(mesh, P())
        mask_sh = NamedSharding(mesh, P(sharding_policy.batch_axes(mesh, s)))
        aux_sh = self.aux_shardings
        state_dn = (2,) if self.donate else ()  # state follows (params, aux)
        admit_in = (self.param_shardings, aux_sh, slot_sh, rep,
                    rep, rep, rep, rep)
        if paged_geom is not None:
            admit_in = admit_in + (rep, rep)  # tbl_row, write_mask
        return ServingFns(
            init=self._with_mesh(jax.jit(init_slots, in_shardings=(rep,),
                                         out_shardings=slot_sh)),
            admit=self._with_mesh(jax.jit(
                admit,
                in_shardings=admit_in,
                out_shardings=slot_sh, donate_argnums=state_dn)),
            step=self._with_mesh(jax.jit(
                step, in_shardings=(self.param_shardings, aux_sh, slot_sh),
                out_shardings=(slot_sh, rep), donate_argnums=state_dn)),
            evict=self._with_mesh(jax.jit(
                evict, in_shardings=(slot_sh, mask_sh),
                out_shardings=slot_sh,
                donate_argnums=(0,) if self.donate else ())),
            paged=paged_geom,
        )
