"""Mesh-sharded decode sessions: one sharding-aware driver for every decode
entry point.

A ``DecodeSession`` owns the model parameters (device_put with
``sharding.policy.param_shardings`` when a mesh is given) and the jitted
decode functions, each built **once** per geometry from explicit
``in_shardings`` / ``out_shardings``:

  * run-to-completion — ``decode`` (bpd), ``greedy``, ``decode_seq2seq``:
    the loop-carried ``BPDState`` / ``GreedyState`` is pinned with
    ``sharding.policy.state_specs`` (batch over the data axes, caches via
    ``cache_specs`` — kv-heads or buffer length over ``model``), so GSPMD
    keeps it partitioned through the whole ``while_loop``.
  * serving — ``serving_fns(ecfg)`` returns the engine's compile-once
    ``init`` / ``admit`` / ``step`` / ``evict`` with ``SlotBatch`` pinned by
    ``slot_specs`` and the loop-carried state **donated** (``donate_argnums``)
    so HBM never holds two copies of the KV buffers between steps.
    Admission is a global scatter under a sharding constraint: the padded
    single-row prefill is replicated, then written into the batch-sharded
    slot buffers as a masked local write on the owning data shard.

Placement modes:

  * ``mesh=None`` (default): trace-transparent local mode — identical to
    the historical eager paths, safe under an outer ``jax.jit``.
  * ``mesh=None, jit=True``: compile-once entry points without placement
    (the static-batch benchmark baseline).
  * ``mesh=Mesh(..., ("data", "model"))``: fully sharded — Megatron-style
    tensor parallelism over ``model``, batch/slot parallelism over
    ``data`` (+ ``pod``).

All three decode entry points in ``core.decode`` and the
``ContinuousBatchingEngine`` run through this one session layer, so the
static-batch paper baselines and continuous batching share a single
driver.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import DecodeConfig, ModelConfig
from repro.core import decode as decode_lib
from repro.core import policy as policy_lib
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.serving.types import EngineConfig, SlotBatch
from repro.sharding import policy as sharding_policy

I32 = jnp.int32


class PagedGeometry(NamedTuple):
    """Static page-pool geometry of a serving slot group — everything the
    engine's host-side ``serving.pages.PageAllocator`` needs to mirror the
    device block tables."""

    page_size: int      # tokens per KV page
    pages_per_row: int  # block-table width P
    num_pages: int      # physical pool size (incl. trash page 0)
    prefix_len: int     # model prefix (meta tokens) before the prompt


def _structs(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _geometry(batch: Dict) -> tuple:
    return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                        for k, v in batch.items()))


class PrefillPacket(NamedTuple):
    """Finished prefill state for a batch of prompts, before any slot is
    chosen — the unit of work a prefill worker hands to a decode group
    through the engine's KV-handoff queue.

    Every leaf leads with the prefill width ``W``; row ``i`` is one
    request's complete admission state (token buffer, first-block
    proposals, prefilled KV caches, fresh per-row policy state).  A packet
    is slot-independent by construction: ``attach`` scatters one row into
    any free slot later, so prefill never serializes behind a decode step.
    Under a pod mesh the packet shards its rows over the ``pod`` axis
    (``sharding.policy.packet_specs``) — the attach-time resharding into
    the ("pod", "data")-sharded slot slab IS the prefill→decode KV
    handoff transfer.
    """

    tokens: Any        # (W, buf_len) slot token buffer rows (padded prompt)
    prompt_len: Any    # (W,) real prompt lengths
    proposals: Any     # (W, k) first-block draft proposals
    caches: Any        # prefilled KV caches, batch dim = W (row workspace)
    policy_state: Any  # fresh per-row DecodePolicy state (W-leading leaves)


class ServingFns(NamedTuple):
    """The engine's device functions, compiled once per (policy, geometry).

    ``aux`` is the session's {bundle name: params} dict of auxiliary
    models (empty for single-model sessions); it rides along wherever the
    decode policy may run a model of its own.  ``init`` takes the policy
    slot-group id (traced, so every group of the same policy and geometry
    shares one compiled function); ``admit`` additionally takes the
    request's source tokens (padded like the prompt) for source-drafting
    policies.

    ``admit`` IS ``attach ∘ prefill`` at width 1: the unified engine's
    admission and the disaggregated engine's prefill-worker path trace the
    same prefill body and the same scatter, so the two modes are
    token-identical by construction rather than by test alone.
    """

    init: Callable      # (gid) -> SlotBatch (mesh-placed when sharded)
    admit: Callable     # (params, aux, state, slot, prompt, plen, max_new,
                        #  src[, tbl_row, write_mask]) -> state — the two
                        # trailing page-mapping args exist iff paged
    step: Callable      # (params, aux, state) -> (state, status (S,) int8)
    evict: Callable     # (state, mask) -> state
    prefill: Callable   # (params, aux, prompts (W,P), plens (W,),
                        #  srcs (W,P)) -> PrefillPacket — the slot-free
                        # half of admission, batched to the prefill width
    attach: Callable    # (state, packet, row, slot, max_new
                        #  [, tbl_row, write_mask]) -> state — the
                        # scatter-only half (the KV handoff)
    attach_many: Callable = None  # (state, packet, rows (W,), slots (W,),
                        #  max_news (W,), valid (W,)[, tbl_rows (W,P),
                        #  write_masks (W,P)]) -> state — up to W handoffs
                        # in ONE dispatch (invalid lanes write nothing)
    paged: Optional["PagedGeometry"] = None  # page-pool geometry (None=dense)


class DecodeSession:
    """Sharding-aware owner of the model bundles + jitted decode entry
    points.

    ``policy`` fixes the session's DEFAULT decode policy (drafter ×
    acceptor × block schedule): every entry point is jitted once per
    (bundles, policy, geometry) — bundles are fixed at construction, so
    the per-session jit cache keys on (``DecodePolicy.cache_key``,
    geometry) — and the policy's loop-carried state is part of the
    sharded decode state (``sharding.policy.state_specs`` /
    ``slot_specs`` treat its batch-leading leaves like any other per-row
    array, with model-backed drafter caches spec'd under their own
    bundle's config).  ``serving_fns(policy=...)`` additionally builds
    per-policy serving functions for the engine's slot groups, sharing
    the same cache — one session serves heterogeneous per-request
    policies without recompiling.

    ``bundles`` ({name: core.bundle.ModelBundle}) are the session's
    auxiliary models — e.g. ``{"draft": ModelBundle(draft_params,
    draft_cfg)}`` for the ``draft_model`` policy.  Each bundle's params
    are device_put with its own ``param_shardings`` and threaded into
    every jitted entry point as an explicit argument, so they shard and
    cache-key exactly like the primary parameters; the static half of
    each bundle (cfg / kv_chunk / backend factory) is bound into the
    policy up front (``DecodePolicy.bind``), so incompatible bundles fail
    at construction, not at trace time.
    """

    def __init__(self, params, cfg: ModelConfig, dec: DecodeConfig, *,
                 mesh=None, kv_chunk: int = 0, backend=None,
                 jit: Optional[bool] = None, donate: Optional[bool] = None,
                 policy=None, bundles=None):
        self.cfg = cfg
        self.dec = dec
        self.bundles = dict(bundles or {})
        self.policy = policy_lib.resolve_policy(dec, policy).bind(
            self.bundles, cfg)
        self.mesh = mesh
        self.kv_chunk = kv_chunk
        self.backend = backend
        self.jit = (mesh is not None) if jit is None else bool(jit)
        self._donate = donate
        # a model-backed drafter exposes its bound model config as .cfg —
        # the sharding policy specs its loop-carried cache under it
        self.draft_cfg = getattr(self.policy.drafter, "cfg", None)
        if mesh is not None:
            self.param_shardings = sharding_policy.param_shardings(params, mesh)
            self.params = jax.device_put(params, self.param_shardings)
            self.aux_shardings = sharding_policy.bundle_param_shardings(
                self.bundles, mesh)
            self.aux_params = {n: jax.device_put(b.params,
                                                 self.aux_shardings[n])
                               for n, b in self.bundles.items()}
        else:
            self.param_shardings = None
            self.params = params
            self.aux_shardings = {}
            self.aux_params = {n: b.params for n, b in self.bundles.items()}
        self._fns: Dict[Any, Callable] = {}

    # -- placement helpers ---------------------------------------------------

    @property
    def donate(self) -> bool:
        """Donate loop-carried state buffers.  Defaults on for accelerator
        devices — XLA:CPU cannot alias donated buffers (it would only warn
        and copy), so host-mesh debug runs stay quiet.  Keyed off the
        session mesh's devices (the buffers live there), not the process
        default backend."""
        if self._donate is None:
            platform = (self.mesh.devices.flat[0].platform
                        if self.mesh is not None else jax.default_backend())
            self._donate = platform in ("gpu", "tpu")
        return self._donate

    def _with_mesh(self, fn):
        """Run (and, on first call, trace) ``fn`` under the session mesh so
        the model's internal GSPMD hints (``policy.maybe_shard``) activate."""
        if self.mesh is None:
            return fn
        mesh = self.mesh

        def call(*args):
            with mesh:
                return fn(*args)

        call._cache_size = getattr(fn, "_cache_size", None)
        call._jitted = fn      # AOT access (launch/dryrun lowers these)
        return call

    def _constrain(self) -> Optional[Callable]:
        """State-constraint hook handed to the loop impls: pins the
        loop-carried NamedTuple state to its ``state_specs`` shardings."""
        if self.mesh is None:
            return None
        cfg, mesh = self.cfg, self.mesh

        draft_cfg = self.draft_cfg

        def constrain(state):
            specs = sharding_policy.state_specs(cfg, state, mesh,
                                                draft_cfg=draft_cfg)
            return jax.lax.with_sharding_constraint(
                state, sharding_policy.named(mesh, specs))

        return constrain

    def _out_shardings(self, fn, batch_size: int, *arg_structs):
        """Explicit output shardings: batch-leading arrays over the data
        axes, scalars/aggregates replicated."""
        mesh = self.mesh
        ax = sharding_policy.batch_axes(mesh, batch_size)

        def rule(s):
            if s.ndim >= 1 and s.shape[0] == batch_size:
                return NamedSharding(mesh, P(*([ax] + [None] * (s.ndim - 1))))
            return NamedSharding(mesh, P())

        return jax.tree_util.tree_map(rule, jax.eval_shape(fn, *arg_structs))

    def _get(self, key, build):
        fn = self._fns.get(key)
        if fn is None:
            fn = build()
            self._fns[key] = fn
        return fn

    def _jit_entry(self, fn, batch: Dict, extra_in=(), extra_structs=()):
        """jit one run-to-completion entry point with explicit shardings.

        Every entry point takes ``(params, aux, batch, *extra)`` — ``aux``
        is the {bundle name: params} dict of auxiliary models, sharded per
        bundle (empty dict for single-model sessions)."""
        if self.mesh is None:
            return jax.jit(fn)
        mesh = self.mesh
        b = next(iter(batch.values())).shape[0]
        in_sh = (self.param_shardings, self.aux_shardings,
                 sharding_policy.named(
                     mesh, sharding_policy.batch_specs(mesh, batch)),
                 *extra_in)
        out_sh = self._out_shardings(fn, b, _structs(self.params),
                                     _structs(self.aux_params),
                                     _structs(batch), *extra_structs)
        return self._with_mesh(
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh))

    # -- run-to-completion entry points -------------------------------------

    def decode(self, batch: Dict, *, max_new_rows=None):
        """Blockwise parallel decode (causal LM).  See core.decode.bpd_decode."""
        cfg, dec, pol = self.cfg, self.dec, self.policy
        if not self.jit:
            return decode_lib._bpd_decode_impl(
                self.params, cfg, dec, batch, max_new_rows,
                backend=self.backend, kv_chunk=self.kv_chunk, policy=pol,
                aux_params=self.aux_params)

        b = batch["tokens"].shape[0]
        budget = (jnp.full((b,), dec.max_new_tokens, I32)
                  if max_new_rows is None else jnp.asarray(max_new_rows, I32))

        def build():
            backend, kv_chunk = self.backend, self.kv_chunk
            constrain = self._constrain()

            def fn(params, aux, batch, budget):
                return decode_lib._bpd_decode_impl(
                    params, cfg, dec, batch, budget, backend=backend,
                    kv_chunk=kv_chunk, constrain=constrain, policy=pol,
                    aux_params=aux)

            extra_in, extra_structs = (), (jax.ShapeDtypeStruct((b,), I32),)
            if self.mesh is not None:
                ax = sharding_policy.batch_axes(self.mesh, b)
                extra_in = (NamedSharding(self.mesh, P(ax)),)
            return self._jit_entry(fn, batch, extra_in, extra_structs)

        fn = self._get(("bpd", pol.cache_key) + _geometry(batch), build)
        return fn(self.params, self.aux_params, batch, budget)

    def greedy(self, batch: Dict):
        """Greedy baseline (p_1 only).  See core.decode.greedy_decode."""
        cfg, dec = self.cfg, self.dec
        if not self.jit:
            return decode_lib._greedy_decode_impl(
                self.params, cfg, dec, batch, kv_chunk=self.kv_chunk)

        def build():
            kv_chunk = self.kv_chunk
            constrain = self._constrain()

            def fn(params, aux, batch):
                del aux  # greedy never drafts — uniform signature only
                return decode_lib._greedy_decode_impl(
                    params, cfg, dec, batch, kv_chunk=kv_chunk,
                    constrain=constrain)

            return self._jit_entry(fn, batch)

        fn = self._get(("greedy",) + _geometry(batch), build)
        return fn(self.params, self.aux_params, batch)

    def decode_seq2seq(self, batch: Dict):
        """Encode once, BPD the decoder.  See core.decode.bpd_decode_seq2seq."""
        cfg, dec, pol = self.cfg, self.dec, self.policy
        if not self.jit:
            return decode_lib._bpd_decode_seq2seq_impl(
                self.params, cfg, dec, batch, policy=pol,
                aux_params=self.aux_params)

        def build():
            constrain = self._constrain()

            def fn(params, aux, batch):
                return decode_lib._bpd_decode_seq2seq_impl(
                    params, cfg, dec, batch, constrain=constrain, policy=pol,
                    aux_params=aux)

            return self._jit_entry(fn, batch)

        fn = self._get(("s2s", pol.cache_key) + _geometry(batch), build)
        return fn(self.params, self.aux_params, batch)

    # -- serving (continuous batching) ---------------------------------------

    def bound_policy(self, policy=None):
        """Resolve ``policy`` (a registered name / DecodePolicy / None for
        the session default) and bind the session's bundles to it — the
        form every serving slot group runs."""
        if policy is None:
            return self.policy
        return policy_lib.resolve_policy(self.dec, policy).bind(
            self.bundles, self.cfg)

    def serving_fns(self, ecfg: EngineConfig, *, policy=None) -> ServingFns:
        """Compile-once device functions for the continuous-batching engine.

        All four are geometry-fixed by ``ecfg``: prompts are padded to
        ``max_prompt_len`` and slot indices are traced int32 scalars, so
        admit/step/evict each compile exactly once regardless of traffic —
        on a single device and on a ``("data", "model")`` mesh alike.

        ``policy`` overrides the session default for one policy slot group
        (per-request decode policies): the returned functions are built for
        that policy and CACHED per (policy identity, geometry) — the jit
        cache keys on ``DecodePolicy.cache_key``, so two groups running the
        same policy at the same geometry share one compiled step, and a
        heterogeneous engine compiles exactly one step per distinct
        (policy, geometry) with no per-step recompilation.
        """
        pol = self.bound_policy(policy)
        key = ("serving", pol.cache_key, ecfg)
        return self._get(key, lambda: self._build_serving_fns(ecfg, pol))

    def _build_serving_fns(self, ecfg: EngineConfig,
                           pol) -> ServingFns:
        cfg, dec, mesh = self.cfg, self.dec, self.mesh
        block_k = dec.block_k or cfg.bpd_k
        prefix = cfg.num_meta_tokens
        context_len = prefix + ecfg.max_prompt_len + ecfg.max_new_cap
        buf_len = ecfg.max_prompt_len + ecfg.max_new_cap + block_k
        backend = self.backend or decode_lib.causal_lm_backend(
            cfg, kv_chunk=self.kv_chunk)
        s = ecfg.num_slots

        # KV-cache backend (dense slab vs managed page pool).  One host
        # allocator per slot group drives the mapping for every layer, so
        # the block-table geometry is computed here once.
        paged_geom = None
        if dec.cache_backend == "paged":
            ps = dec.page_size
            P_ = cache_lib.pages_per_row(context_len, block_k, ps)
            pool = ecfg.page_pool_pages or (1 + s * P_)
            kv_backend: cache_lib.KVCacheBackend = cache_lib.PagedBackend(
                ps, num_pages=pool, managed=True)
            paged_geom = PagedGeometry(page_size=ps, pages_per_row=P_,
                                       num_pages=pool, prefix_len=prefix)
        else:
            kv_backend = cache_lib.get_backend(dec)

        def slots_batch(n: int) -> Dict:
            """Pseudo decode-entry batch for policy-state builders: the
            engine admits padded prompts, so drafters see a zeroed
            ``tokens`` batch of the admission geometry — this keeps their
            state SHAPES identical across init (n = num_slots, no params),
            admit (n = 1, prefilled for real) and evict (reset rows).
            ``src`` (same padded geometry) lets source-drafting policies
            (``input_copy``) serve through the engine: admission scatters
            the request's real source row over these zeros."""
            z = jnp.zeros((n, ecfg.max_prompt_len), I32)
            return {"tokens": z, "src": z}

        def init_slots(gid) -> SlotBatch:
            zeros = lambda: jnp.zeros((s,), I32)  # noqa: E731
            return SlotBatch(
                tokens=jnp.zeros((s, buf_len), I32),
                text_len=zeros(),
                prompt_len=zeros(),
                proposals=jnp.zeros((s, block_k), I32),
                caches=model_lib.init_caches(cfg, s, context_len, block_k,
                                             backend=kv_backend),
                active=jnp.zeros((s,), bool),
                finished=jnp.ones((s,), bool),  # empty slots read as finished
                generated=zeros(),
                max_new=zeros(),
                invocations=zeros(),
                policy_state=pol.init_state(cfg, dec, slots_batch(s), s),
                group=jnp.full((s,), gid, I32),
            )

        slot_sh = cache_sh = None
        if mesh is not None:
            struct = jax.eval_shape(init_slots, jax.ShapeDtypeStruct((), I32))
            slot_sh = sharding_policy.named(
                mesh, sharding_policy.slot_specs(cfg, struct, mesh,
                                                 policy=pol))
            cache_sh = slot_sh.caches

        def prefill(params, aux, prompts, plens, srcs) -> PrefillPacket:
            """The slot-free half of admission: prefill ``W`` padded
            prompts in ONE forward and return their handoff packet.

            Per-row computation is identical to the historical batch-1
            admission prefill (rows never mix — embeddings, attention and
            the per-row policy init are all row-local), so a packet row
            attached later is bit-for-bit the state ``admit`` would have
            scattered directly.  Batching amortizes the per-dispatch host
            overhead across ``W`` prompts — the disaggregated engine's
            main throughput lever — and gives prefill its own wide-
            sequence compute shape, distinct from the decode step's
            memory-bound block-verify geometry.

            Per-slot policy state is built fresh here — a packet row never
            inherits a previous occupant's drafter/schedule state — and
            the policy's drafter proposes the first block (a model-backed
            drafter prefills its own cache on the padded prompts, with its
            params from ``aux``; a source-drafting policy stores the
            request's src rows).
            """
            w = prompts.shape[0]
            row_caches = kv_backend.row_init(cfg, context_len, block_k,
                                             batch=w)
            h = model_lib.embed_inputs(params, cfg, {"tokens": prompts})
            positions = jnp.arange(h.shape[1], dtype=I32)
            hidden, _, row_caches = model_lib.forward_hidden(
                params, cfg, h, positions=positions, caches=row_caches,
                moe_full_capacity=True)
            idx = (prefix + plens - 1)[:, None, None]
            last = jnp.take_along_axis(
                hidden, jnp.broadcast_to(idx, (w, 1, hidden.shape[2])),
                axis=1)[:, 0]
            logits = model_lib.all_head_logits(params, cfg, last)  # (W, K, V)

            row_ps = pol.init_state(cfg, dec,
                                    {"tokens": prompts, "src": srcs}, w,
                                    aux=aux)
            last_tok = jnp.take_along_axis(
                prompts, jnp.maximum(plens - 1, 0)[:, None], axis=1)[:, 0]
            proposals, row_ds = decode_lib.initial_draft(
                pol, logits, plens, block_k, row_ps.drafter,
                prev_token=last_tok, aux_params=aux)
            row_ps = row_ps._replace(drafter=row_ds)

            tokens = jnp.zeros((w, buf_len), I32)
            tokens = tokens.at[:, :ecfg.max_prompt_len].set(prompts)
            return PrefillPacket(tokens=tokens,
                                 prompt_len=jnp.asarray(plens, I32),
                                 proposals=proposals, caches=row_caches,
                                 policy_state=row_ps)

        def attach(state: SlotBatch, packet: PrefillPacket, row, slot,
                   max_new, tbl_row=None, write_mask=None) -> SlotBatch:
            """The scatter-only half of admission: install packet ``row``
            into slot ``slot`` — the prefill→decode KV handoff.

            The packet row is replicated work (its slice never splits the
            data axis); the writes into the slot batch are a global scatter
            constrained back to the slot shardings, so only the data shard
            owning ``slot`` mutates its rows.  Under a pod mesh the packet
            rows live on the ``pod`` axis and the scatter reshards them
            into the ("pod", "data")-split slot slab — the measured
            device-to-device handoff transfer (launch/dryrun.py).

            Under the paged backend the packet rows are dense page-aligned
            workspaces (``PagedBackend.row_init``); ``tbl_row`` ((P,)
            int32) and ``write_mask`` ((P,) bool) are the host allocator's
            physical mapping for this slot — copy-on-write prefix hits
            arrive with ``write_mask=False`` and are left untouched in the
            pool.
            """
            take = lambda x: jax.lax.dynamic_slice_in_dim(  # noqa: E731
                x, row, 1, axis=0)
            row_caches = jax.tree_util.tree_map(take, packet.caches)
            row_ps = jax.tree_util.tree_map(take, packet.policy_state)
            prompt_len = take(packet.prompt_len)[0]
            upd = lambda arr, val: arr.at[slot].set(val)  # noqa: E731
            policy_state = jax.tree_util.tree_map(
                lambda full, r: full.at[slot].set(r[0]),
                state.policy_state, row_ps)
            return state._replace(
                tokens=upd(state.tokens, take(packet.tokens)[0]),
                text_len=upd(state.text_len, prompt_len),
                prompt_len=upd(state.prompt_len, prompt_len),
                proposals=upd(state.proposals, take(packet.proposals)[0]),
                caches=model_lib.scatter_cache_row(state.caches, row_caches,
                                                   slot, constraint=cache_sh,
                                                   tbl_row=tbl_row,
                                                   write_mask=write_mask),
                active=upd(state.active, True),
                finished=upd(state.finished, False),
                generated=upd(state.generated, 0),
                max_new=upd(state.max_new, max_new),
                invocations=upd(state.invocations, 1),  # the prefill call
                policy_state=policy_state,
            )

        def attach_many(state: SlotBatch, packet: PrefillPacket, rows, slots,
                        max_news, valid, tbl_rows=None,
                        write_masks=None) -> SlotBatch:
            """Batched KV handoff: install up to W packet rows into W freed
            slots in ONE dispatch.  A per-request attach call would hand
            back the admission dispatch overhead that batching the prefill
            just amortized — this keeps the whole admission path at O(1)
            dispatches per worker batch.  ``valid`` masks the short final
            batch: invalid lanes are skipped entirely (``lax.cond``), so
            padding writes nothing and the call compiles once at width W.
            """
            w_ = rows.shape[0]
            for i in range(w_):
                extra = (() if tbl_rows is None
                         else (tbl_rows[i], write_masks[i]))

                def _install(st, i=i, extra=extra):
                    return attach(st, packet, rows[i], slots[i],
                                  max_news[i], *extra)

                state = jax.lax.cond(valid[i], _install, lambda st: st,
                                     state)
            return state

        def admit(params, aux, state: SlotBatch, slot, prompt, prompt_len,
                  max_new, src, tbl_row=None, write_mask=None) -> SlotBatch:
            """Unified admission = ``attach ∘ prefill`` at width 1: prefill
            one padded prompt and scatter it into row ``slot`` in a single
            jitted call.  Composing the two halves (instead of duplicating
            their bodies) is what makes the disaggregated engine token-
            identical to this path by construction."""
            packet = prefill(params, aux, prompt[None],
                             jnp.asarray(prompt_len, I32)[None], src[None])
            return attach(state, packet, jnp.zeros((), I32), slot, max_new,
                          tbl_row, write_mask)

        def step(params, aux, state: SlotBatch):
            bst = decode_lib.BPDState(
                tokens=state.tokens, text_len=state.text_len,
                proposals=state.proposals, caches=state.caches,
                finished=state.finished, iters=jnp.zeros((), I32),
                generated=state.generated, policy_state=state.policy_state)
            out = decode_lib.bpd_iteration(
                params, cfg, dec, backend, bst, prefix_offset=prefix,
                max_new=state.max_new, active=state.active, policy=pol,
                aux_params=aux)
            stepped = state.active & ~state.finished
            new_state = state._replace(
                tokens=out.tokens, text_len=out.text_len,
                proposals=out.proposals, caches=out.caches,
                finished=out.finished, generated=out.generated,
                invocations=state.invocations + stepped.astype(I32),
                policy_state=out.policy_state)
            # fused harvest decision: one tiny (S,) array carries both the
            # active and the finished bits, so the host loop round-trips a
            # single transfer per step (bit 0 = active, bit 1 = harvestable)
            status = (state.active.astype(jnp.int8)
                      + 2 * (state.active & out.finished).astype(jnp.int8))
            return new_state, status

        k_win = max(int(getattr(ecfg, "steps_per_sync", 1)), 1)

        def step_windowed(params, aux, state: SlotBatch):
            """Up to ``steps_per_sync`` decode iterations fused into ONE
            dispatch — a bounded while_loop over the SAME traced step
            body, so the commit stream is bitwise identical to stepping
            one iteration at a time.  The loop exits the moment any row
            becomes harvestable: finished slots surface to the host at
            the same iteration they would have with per-step syncs, so
            slot refill (the continuous-batching win) keeps its timing;
            only the admission of NEW arrivals can lag by at most
            ``steps_per_sync - 1`` iterations.  Returns the number of
            iterations actually run so the engine's model-invocation
            accounting stays honest (a window is 1..k dispatched
            forwards, not one)."""
            if k_win == 1:
                nst, status = step(params, aux, state)
                return nst, status, jnp.ones((), I32)

            def body(carry):
                st, _, i = carry
                nst, status = step(params, aux, st)
                return nst, status, i + 1

            def cond(carry):
                _, status, i = carry
                return (i < k_win) & ~jnp.any((status & 2) > 0)

            st, status, iters = jax.lax.while_loop(
                cond, body,
                (state, jnp.zeros((s,), jnp.int8), jnp.zeros((), I32)))
            return st, status, iters

        def evict(state: SlotBatch, mask) -> SlotBatch:
            # evicted slots also drop their policy state, so a paused slot
            # can never leak schedule/drafter history into a later request
            # (paramless init: model-backed drafters reset to empty caches
            # of the same admission geometry — admit rebuilds them anyway)
            fresh = pol.init_state(cfg, dec, slots_batch(s), s)
            policy_state = jax.tree_util.tree_map(
                lambda full, init: jnp.where(
                    mask.reshape((-1,) + (1,) * (init.ndim - 1)), init, full),
                state.policy_state, fresh)
            return state._replace(
                active=state.active & ~mask,
                caches=model_lib.reset_cache_rows(state.caches, mask),
                policy_state=policy_state)

        if mesh is None:
            return ServingFns(init=jax.jit(init_slots),
                              admit=jax.jit(admit),
                              step=jax.jit(step_windowed),
                              evict=jax.jit(evict),
                              prefill=jax.jit(prefill),
                              attach=jax.jit(attach),
                              attach_many=jax.jit(attach_many),
                              paged=paged_geom)

        rep = NamedSharding(mesh, P())
        mask_sh = NamedSharding(mesh, P(sharding_policy.batch_axes(mesh, s)))
        aux_sh = self.aux_shardings
        state_dn = (2,) if self.donate else ()  # state follows (params, aux)
        admit_in = (self.param_shardings, aux_sh, slot_sh, rep,
                    rep, rep, rep, rep)
        if paged_geom is not None:
            admit_in = admit_in + (rep, rep)  # tbl_row, write_mask
        # prefill-worker geometry: packet rows shard over the pod axis
        # (prefill workers own their data-axis slice); the attach scatter
        # reshards them into the ("pod", "data")-split slot slab — the
        # sharding-constrained prefill→decode handoff transfer
        w = max(ecfg.prefill_slots, 1)
        pkt_struct = jax.eval_shape(
            prefill, _structs(self.params), _structs(self.aux_params),
            jax.ShapeDtypeStruct((w, ecfg.max_prompt_len), I32),
            jax.ShapeDtypeStruct((w,), I32),
            jax.ShapeDtypeStruct((w, ecfg.max_prompt_len), I32))
        pkt_sh = sharding_policy.named(
            mesh, sharding_policy.packet_specs(cfg, pkt_struct, mesh,
                                               policy=pol))
        pre_ax = sharding_policy.prefill_axes(mesh, w)
        prompts_sh = NamedSharding(mesh, P(pre_ax, None))
        plens_sh = NamedSharding(mesh, P(pre_ax))
        attach_in = (slot_sh, pkt_sh, rep, rep, rep)
        attach_many_in = (slot_sh, pkt_sh, rep, rep, rep, rep)
        if paged_geom is not None:
            attach_in = attach_in + (rep, rep)  # tbl_row, write_mask
            attach_many_in = attach_many_in + (rep, rep)
        return ServingFns(
            init=self._with_mesh(jax.jit(init_slots, in_shardings=(rep,),
                                         out_shardings=slot_sh)),
            admit=self._with_mesh(jax.jit(
                admit,
                in_shardings=admit_in,
                out_shardings=slot_sh, donate_argnums=state_dn)),
            step=self._with_mesh(jax.jit(
                step_windowed,
                in_shardings=(self.param_shardings, aux_sh, slot_sh),
                out_shardings=(slot_sh, rep, rep),
                donate_argnums=state_dn)),
            evict=self._with_mesh(jax.jit(
                evict, in_shardings=(slot_sh, mask_sh),
                out_shardings=slot_sh,
                donate_argnums=(0,) if self.donate else ())),
            prefill=self._with_mesh(jax.jit(
                prefill,
                in_shardings=(self.param_shardings, aux_sh, prompts_sh,
                              plens_sh, prompts_sh),
                out_shardings=pkt_sh)),
            attach=self._with_mesh(jax.jit(
                attach, in_shardings=attach_in, out_shardings=slot_sh,
                donate_argnums=(0,) if self.donate else ())),
            attach_many=self._with_mesh(jax.jit(
                attach_many, in_shardings=attach_many_in,
                out_shardings=slot_sh,
                donate_argnums=(0,) if self.donate else ())),
            paged=paged_geom,
        )
