"""Admission scheduler for the continuous-batching engine.

The scheduler owns the request queue and the admission policy; the engine
owns the device slots.  One ``step()`` is the unit of serving work a
production loop would run: admit every eligible queued request into free
slots, then run one BPD iteration over every active policy slot group and
retire whatever finished.

Policies:
  * ``fcfs`` — first come, first served (arrival order).
  * ``sjf``  — shortest job first by requested ``max_new``; reduces mean
               latency under mixed-length traffic at the cost of fairness.

Per-request decode policies: each ``Request.policy`` routes to the engine
slot group running that policy, so the scheduler buckets admission per
group — a free ``topk_tree`` slot is filled by the best eligible
``topk_tree`` request even when an older ``exact`` request is still
queued (its slots are a different group).  The admission order (fcfs/sjf)
applies within each bucket.

``run()`` drives a whole workload to completion on a real clock: requests
with future arrival times are invisible until the clock reaches them
(Poisson open-loop traffic in benchmarks/serve_throughput.py).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.serving.engine import ContinuousBatchingEngine, PagePoolExhausted
from repro.serving.types import FinishedRequest, Request, percentile

POLICIES = ("fcfs", "sjf")


class Scheduler:
    def __init__(self, engine: ContinuousBatchingEngine,
                 policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.engine = engine
        self.policy = policy
        self.queue: List[Request] = []
        self.finished: List[FinishedRequest] = []

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue a request; invalid requests are rejected here, before
        they can abort the serving loop mid-drain — including requests
        whose decode policy the engine has no slot group for."""
        p = len(req.prompt)
        cap = self.engine.ecfg.max_prompt_len
        if not 0 < p <= cap:
            raise ValueError(
                f"request {req.rid}: prompt length {p} outside (0, {cap}]")
        self.engine.group_for(req.policy)   # unknown policy -> ValueError
        if req.arrival is None:
            req.arrival = time.monotonic()
        self.queue.append(req)

    def pending(self, now: Optional[float] = None) -> List[Request]:
        """Requests that have arrived and await a slot."""
        if now is None:
            now = time.monotonic()
        return [r for r in self.queue if r.arrival <= now]

    def _pop_next(self, now: float,
                  group: Optional[str] = None) -> Optional[Request]:
        """Best eligible request — optionally only those routed to the
        ``group`` policy slot group."""
        eligible = [r for r in self.queue if r.arrival <= now]
        if group is not None:
            # delegate routing to the engine — one source of truth for
            # which group a request's policy lands in
            eligible = [r for r in eligible
                        if self.engine.group_for(r.policy).name == group]
        if not eligible:
            return None
        if self.policy == "sjf":
            pick = min(eligible, key=lambda r: (r.max_new, r.arrival))
        else:
            pick = min(eligible, key=lambda r: (r.arrival, r.rid))
        self.queue.remove(pick)
        return pick

    # -- serving loop --------------------------------------------------------

    def step(self, now: Optional[float] = None) -> List[FinishedRequest]:
        """Admit eligible requests into each group's free slots, then one
        engine step (= one BPD iteration per active group)."""
        t = time.monotonic() if now is None else now
        for name in self.engine.policy_names():
            for _ in range(len(self.engine.free_slots(name))):
                req = self._pop_next(t, group=name)
                if req is None:
                    break
                try:
                    self.engine.admit(req, now=now)
                except PagePoolExhausted:
                    # back-pressure: the paged KV pool can oversubscribe the
                    # slot slab — requeue and stop admitting to this group
                    # until decode steps retire requests and free pages
                    self.queue.append(req)
                    break
        if not self.engine.has_active():
            return []
        done = self.engine.step(now=now)
        self.finished.extend(done)
        return done

    def drained(self) -> bool:
        return not self.queue and not self.engine.has_active()

    def run(self, max_steps: int = 100_000) -> List[FinishedRequest]:
        """Drive until every submitted request has been served."""
        steps = 0
        while not self.drained():
            if steps >= max_steps:
                raise RuntimeError(f"scheduler did not drain in {max_steps} "
                                   f"steps ({len(self.queue)} queued)")
            now = time.monotonic()
            if not self.engine.has_active() and not self.pending(now):
                # idle: sleep until the next arrival
                nxt = min(r.arrival for r in self.queue)
                time.sleep(max(nxt - now, 0.0))
                continue
            self.step()
            steps += 1
        return self.finished


def aggregate_stats(finished: List[FinishedRequest],
                    wall_seconds: float) -> Dict:
    """Serving-level summary: aggregate throughput + latency percentiles."""
    lat = [f.latency for f in finished]
    total_tokens = sum(f.generated for f in finished)
    total_inv = sum(f.invocations for f in finished)
    return {
        "requests": len(finished),
        "total_tokens": total_tokens,
        "total_invocations": total_inv,
        "tokens_per_sec": total_tokens / wall_seconds if wall_seconds else 0.0,
        "mean_accepted": (sum(f.mean_accepted for f in finished)
                          / len(finished)) if finished else 0.0,
        "latency_p50_s": percentile(lat, 50),
        "latency_p95_s": percentile(lat, 95),
        "wall_seconds": wall_seconds,
    }
