"""Admission scheduler for the continuous-batching engine: ordering,
priorities, deadlines, preemption, and back-pressure.

The scheduler owns the request queue and the admission policy; the engine
owns the device slots.  One ``step()`` is the unit of serving work a
production loop would run: admit every eligible queued request into free
slots, preempt where a deadline demands it, then run one BPD iteration
over every active policy slot group and retire whatever finished.

Ordering (within a policy slot group):

  * requests sort by **priority first** (higher served first), then by the
    base policy:
  * ``fcfs`` — first come, first served: ``(arrival, rid)``.
  * ``sjf``  — shortest job first: ``(max_new, arrival, rid)``; reduces
               mean latency under mixed-length traffic at the cost of
               fairness.  The ``(arrival, rid)`` tie-break makes the order
               fully deterministic — two equal-length jobs pop in arrival
               order, and two simultaneous arrivals pop in rid order.

Back-pressure (``PagePoolExhausted``): when the paged KV pool cannot cover
an admission, the request is requeued with its ``backpressured`` flag set,
which moves it AHEAD of every same-priority request of its group until it
is admitted.  Under ``sjf`` this is the anti-starvation guarantee: a large
request that keeps losing the pool race would otherwise lose to every
later-arriving small request forever; the flag gives it head-of-line
ownership of the next pages that free up.

Deadlines + priority preemption: a queued request with a ``deadline`` may
evict a strictly-lower-priority mid-flight request from its policy group
when waiting for a natural slot would miss that deadline (estimated from
an EWMA of observed seconds-per-token).  The victim's committed tokens are
pulled, its slot evicted, and a CONTINUATION request — same rid, prompt
extended by the committed tokens, budget reduced by them — goes back to
the queue, re-admitting through the ordinary padded-prefill path.  On
finish the scheduler stitches the carried segments back together, so a
preempted request retires with the same tokens, original prompt length,
and a ``preempted`` count.  Token identity holds for every policy whose
commit stream is a deterministic function of the committed context — all
registered built-ins: exact-acceptance policies commit greedy tokens
regardless of drafter/schedule state, and the non-exact built-ins draft
from context-deterministic state (custom policies carrying loop state that
influences *which* tokens commit are the documented exception).

``run()`` drives a whole workload to completion on a real clock: requests
with future arrival times are invisible until the clock reaches them
(Poisson open-loop traffic in benchmarks/serve_throughput.py).  The async
HTTP front end (``serving.frontend``) drives ``step()`` itself and drains
``take_preempt_events()`` for stream bookkeeping.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import (ContinuousBatchingEngine, PagePoolExhausted,
                                  PolicyGroup)
from repro.serving.types import (FinishedRequest, PreemptedRequest, Request,
                                 percentile)

POLICIES = ("fcfs", "sjf")


class Scheduler:
    def __init__(self, engine: ContinuousBatchingEngine,
                 policy: str = "fcfs", *, preempt_margin_s: float = 0.0):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.engine = engine
        self.policy = policy
        self.queue: List[Request] = []
        self.finished: List[FinishedRequest] = []
        # deadline risk estimate: EWMA of observed seconds-per-token.
        # Seeded at 0 so preemption starts conservative (only fires once a
        # deadline is actually reached) and sharpens as finishes stream in.
        self.tpot_est = 0.0
        self.preempt_margin_s = preempt_margin_s
        self.preemptions = 0            # evict-and-requeue events
        self.backpressure_events = 0    # PagePoolExhausted requeues
        # rid -> stitched-progress of preempted segments
        self._carried: Dict[int, dict] = {}
        self._preempt_events: List[PreemptedRequest] = []

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue a request; invalid requests are rejected here, before
        they can abort the serving loop mid-drain — including requests
        whose decode policy the engine has no slot group for."""
        p = len(req.prompt)
        cap = self.engine.ecfg.max_prompt_len
        if not 0 < p <= cap:
            raise ValueError(
                f"request {req.rid}: prompt length {p} outside (0, {cap}]")
        self.engine.group_for(req.policy)   # unknown policy -> ValueError
        if req.arrival is None:
            req.arrival = time.monotonic()
        self.queue.append(req)

    def pending(self, now: Optional[float] = None) -> List[Request]:
        """Requests that have arrived and await a slot."""
        if now is None:
            now = time.monotonic()
        return [r for r in self.queue if r.arrival <= now]

    def _key(self, r: Request):
        """Deterministic admission order within a group: priority first
        (higher wins), then backpressured head-of-line, then fcfs/sjf."""
        base = ((r.max_new, r.arrival, r.rid) if self.policy == "sjf"
                else (r.arrival, r.rid))
        return (-r.priority, 0 if r.backpressured else 1) + base

    def _pop_next(self, now: float,
                  group: Optional[str] = None) -> Optional[Request]:
        """Best eligible request — optionally only those routed to the
        ``group`` policy slot group."""
        eligible = [r for r in self.queue if r.arrival <= now]
        if group is not None:
            # delegate routing to the engine — one source of truth for
            # which group a request's policy lands in
            eligible = [r for r in eligible
                        if self.engine.group_for(r.policy).name == group]
        if not eligible:
            return None
        pick = min(eligible, key=self._key)
        self.queue.remove(pick)
        return pick

    # -- preemption ----------------------------------------------------------

    def est_service_s(self, req: Request) -> float:
        """Pessimistic-enough finish estimate for deadline-risk checks."""
        return req.max_new * self.tpot_est + self.preempt_margin_s

    def take_preempt_events(self) -> List[PreemptedRequest]:
        """Drain preemption records since the last call (the streaming
        front end forwards each record's unstreamed token remainder)."""
        out, self._preempt_events = self._preempt_events, []
        return out

    def _pick_victim(self, g: PolicyGroup, req: Request,
                     generated: np.ndarray) -> Optional[int]:
        """Lowest-priority feasible victim in ``g`` (local slot index).

        Feasible = strictly lower priority than ``req``, its continuation
        prompt (prompt + committed tokens) still fits ``max_prompt_len``,
        and it is not about to finish anyway (remaining budget >= 1).
        Ties break toward the victim with the MOST remaining work (evicting
        it wastes the least imminent completion), then the highest slot —
        fully deterministic.
        """
        cap = self.engine.ecfg.max_prompt_len
        cands = []
        for i in range(g.num_slots):
            meta = g.slot_meta[i]
            if not (g.status[i] & 1) or meta is None:
                continue
            victim: Request = meta["req"]
            remaining = meta["max_new"] - int(generated[i])
            if (victim.priority < req.priority
                    and meta["prompt_len"] + int(generated[i]) <= cap
                    and remaining >= 1):
                cands.append((victim.priority, -remaining, -i, i))
        return min(cands)[3] if cands else None

    def _maybe_preempt(self, t: float) -> None:
        """Evict-and-requeue pass: for each queued deadline-bearing request
        (best first) whose group is full and whose deadline would be missed
        by waiting, preempt one strictly-lower-priority victim and admit
        the urgent request into the freed slot."""
        at_risk = sorted((r for r in self.queue
                          if r.arrival <= t and r.deadline is not None),
                         key=self._key)
        for r in at_risk:
            g = self.engine.group_for(r.policy)
            if g.free_local():
                continue            # normal admission will take it
            if t + self.est_service_s(r) < r.deadline:
                continue            # not at risk yet
            pulled = self.engine.pull_group(g)
            tokens, text_len, generated, invocations = pulled
            slot = self._pick_victim(g, r, generated)
            if slot is None:
                continue            # nobody strictly lower / feasible
            rec = self.engine.preempt(g, slot, pulled=pulled)
            self.preemptions += 1
            self._preempt_events.append(rec)
            self._requeue_continuation(rec)
            self.queue.remove(r)
            try:
                self.engine.admit(r, now=t)
            except PagePoolExhausted:
                r.backpressured += 1
                self.backpressure_events += 1
                self.queue.append(r)

    def _requeue_continuation(self, rec: PreemptedRequest) -> None:
        """Queue the evicted request's continuation: same rid/priority/
        deadline/policy, prompt extended by the committed tokens, budget
        reduced by them; stitch bookkeeping accumulates across repeated
        preemptions."""
        prev = rec.req
        carried = self._carried.get(prev.rid)
        if carried is None:
            carried = {"tokens": np.zeros((0,), np.int32),
                       "prompt_len": len(prev.prompt),
                       "invocations": 0, "count": 0}
            self._carried[prev.rid] = carried
        carried["tokens"] = np.concatenate([carried["tokens"], rec.tokens])
        carried["invocations"] += rec.invocations
        carried["count"] += 1
        # budget against the CLAMPED cap: re-admission clamps afresh, so a
        # request with max_new > max_new_cap must not win a new cap per
        # segment
        budget = min(prev.max_new, self.engine.ecfg.max_new_cap)
        cont = Request(
            rid=prev.rid,
            prompt=np.concatenate([prev.prompt, rec.tokens]),
            max_new=budget - rec.generated,
            arrival=prev.arrival,           # keeps its fcfs position
            policy=prev.policy, src=prev.src,
            priority=prev.priority, deadline=prev.deadline)
        self.queue.append(cont)

    def _stitch(self, f: FinishedRequest) -> FinishedRequest:
        """Fold carried preempted segments back into a finished record so
        callers see one request: full token stream, original prompt
        length, summed invocations, recomputed k̂."""
        carried = self._carried.pop(f.rid, None)
        if carried is None:
            return f
        f.tokens = np.concatenate([carried["tokens"], f.tokens])
        f.generated += len(carried["tokens"])
        f.prompt_len = carried["prompt_len"]
        f.invocations += carried["invocations"]
        f.preempted = carried["count"]
        # one prefill per segment: iterations = invocations - (count + 1)
        iters = max(f.invocations - (carried["count"] + 1), 1)
        f.mean_accepted = f.generated / iters
        return f

    # -- serving loop --------------------------------------------------------

    def step(self, now: Optional[float] = None) -> List[FinishedRequest]:
        """Admit eligible requests into each group's free slots (preempting
        where a deadline demands it), then one engine step (= one BPD
        iteration per active group)."""
        t = time.monotonic() if now is None else now
        # preemption runs BEFORE admission/staging: a deadline-at-risk
        # request must claim its slot while it is still in the queue — the
        # disaggregated staging loop below would otherwise move it into
        # the handoff queue, where it waits behind the very decode it was
        # entitled to evict (groups with free slots are skipped, so this
        # never steals an admission a natural free slot would satisfy)
        self._maybe_preempt(t)
        if self.engine.disaggregated:
            # disaggregated admission: stage arrivals for the prefill
            # workers while handoff capacity lasts — admission never waits
            # for (or serializes behind) a decode slot — then dispatch the
            # worker batches and install parked rows into freed slots.
            # Page-pool back-pressure is handled at attach inside the
            # engine (head-of-line wait in the handoff queue).
            while self.engine.handoff_free() > 0:
                req = self._pop_next(t)
                if req is None:
                    break
                self.engine.queue_prefill(req, now=now)
            self.engine.run_prefills(now=now)
            self.engine.attach_ready(now=now)
        else:
            for name in self.engine.policy_names():
                for _ in range(len(self.engine.free_slots(name))):
                    req = self._pop_next(t, group=name)
                    if req is None:
                        break
                    try:
                        self.engine.admit(req, now=now)
                    except PagePoolExhausted:
                        # back-pressure: the paged KV pool can oversubscribe
                        # the slot slab — requeue with head-of-line ownership
                        # and stop admitting to this group until decode steps
                        # retire requests and free pages
                        req.backpressured += 1
                        self.backpressure_events += 1
                        self.queue.append(req)
                        break
        if not self.engine.has_active():
            return []
        done = [self._stitch(f) for f in self.engine.step(now=now)]
        for f in done:
            if f.generated > 0:
                obs = (f.finish_time - f.admit_time) / f.generated
                self.tpot_est = (obs if self.tpot_est == 0.0
                                 else 0.5 * self.tpot_est + 0.5 * obs)
        self.finished.extend(done)
        return done

    def drained(self) -> bool:
        return (not self.queue and not self.engine.has_active()
                and self.engine.handoff_backlog() == 0)

    def run(self, max_steps: int = 100_000) -> List[FinishedRequest]:
        """Drive until every submitted request has been served."""
        steps = 0
        while not self.drained():
            if steps >= max_steps:
                raise RuntimeError(f"scheduler did not drain in {max_steps} "
                                   f"steps ({len(self.queue)} queued)")
            now = time.monotonic()
            if (not self.engine.has_active() and not self.pending(now)
                    and self.engine.handoff_backlog() == 0):
                # idle: sleep until the next arrival (drained() was false
                # with nothing in flight, so the queue is non-empty)
                nxt = min(r.arrival for r in self.queue)
                time.sleep(max(nxt - now, 0.0))
                continue
            self.step()
            steps += 1
        return self.finished


def aggregate_stats(finished: List[FinishedRequest],
                    wall_seconds: float) -> Dict:
    """Serving-level summary: aggregate throughput + latency percentiles."""
    lat = [f.latency for f in finished]
    total_tokens = sum(f.generated for f in finished)
    total_inv = sum(f.invocations for f in finished)
    return {
        "requests": len(finished),
        "total_tokens": total_tokens,
        "total_invocations": total_inv,
        "tokens_per_sec": total_tokens / wall_seconds if wall_seconds else 0.0,
        "mean_accepted": (sum(f.mean_accepted for f in finished)
                          / len(finished)) if finished else 0.0,
        "latency_p50_s": percentile(lat, 50),
        "latency_p95_s": percentile(lat, 95),
        "preempted_requests": sum(1 for f in finished if f.preempted),
        "preemptions": sum(f.preempted for f in finished),
        "wall_seconds": wall_seconds,
    }
