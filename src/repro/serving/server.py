"""Minimal asyncio HTTP/1.1 + SSE server over the serving ``Frontend``.

Stdlib-only by design: the repo's install surface is ``jax + numpy`` (see
pyproject.toml) and the serving layer must run wherever the engine runs —
no web framework, just ``asyncio.start_server`` and hand-rolled HTTP/1.1
parsing for the five routes the service needs:

  POST /v1/generate   decode a prompt.  JSON body:
                        {"prompt": [int token ids, ...],   required
                         "max_new": int,                   required
                         "policy": str | null,             optional
                         "priority": int,                  optional (higher wins)
                         "deadline_s": float | null,       optional (relative)
                         "stream": bool}                   default true
                      stream=true  → ``text/event-stream`` (SSE):
                        event: token   data: {"rid": R, "tokens": [...]}
                        event: done    data: {"rid": R, "tokens": [all],
                                              "generated": N, "policy": ...,
                                              "preempted": P, "ttft_s": ...,
                                              "latency_s": ...}
                      stream=false → one JSON object (the done payload).
  GET  /healthz       liveness — 200 once the process serves HTTP.
  GET  /readyz        readiness — 200 only after the compiled decode path
                      has run a tick; 503 before (load balancers gate on
                      this so cold replicas don't take traffic).
  GET  /metrics       Prometheus-style ``name value`` lines from
                      ``Frontend.metrics()``.
  POST /drain         graceful shutdown: 202 immediately, admission stops
                      (new submits get 503, /readyz flips to 503
                      "draining"), in-flight requests finish and flush
                      their SSE tails, then the listener closes and
                      ``serve_forever()`` returns.  SIGTERM takes the
                      same path (wired in launch/serve.py).

Back-pressure: a saturated wait queue (or the page pool behind it —
``PagePoolExhausted`` requeues keep the queue full) rejects with **429**
and a ``Retry-After`` header derived from the observed service rate.
Invalid requests get 400 with the validation message; the connection
stays request-scoped (``Connection: close``) — one request per
connection keeps the parser honest and the failure modes boring.
"""
from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serving.frontend import Backpressure, Draining, Frontend

__all__ = ["HTTPServer", "sse_event"]

_MAX_BODY = 1 << 20     # 1 MiB request-body cap


def sse_event(event: str, data: dict) -> bytes:
    """One Server-Sent Event frame: ``event:`` + JSON ``data:`` lines."""
    return (f"event: {event}\ndata: {json.dumps(data)}\n\n").encode()


def _response(status: int, reason: str, body: bytes,
              content_type: str = "application/json",
              extra_headers: Optional[Dict[str, str]] = None) -> bytes:
    head = [f"HTTP/1.1 {status} {reason}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    for k, v in (extra_headers or {}).items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


def _json_response(status: int, reason: str, obj: dict,
                   extra_headers: Optional[Dict[str, str]] = None) -> bytes:
    return _response(status, reason, (json.dumps(obj) + "\n").encode(),
                     extra_headers=extra_headers)


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, Dict[str, str], bytes]]:
    """Parse one HTTP/1.1 request: (method, path, headers, body) or None
    on EOF/overflow/malformed input."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
        return None
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) != 3:
        return None
    method, path = parts[0], parts[1]
    headers: Dict[str, str] = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", "0") or "0")
    if n < 0 or n > _MAX_BODY:
        return None
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


class HTTPServer:
    """The serving process: one ``Frontend`` + one asyncio TCP listener."""

    def __init__(self, frontend: Frontend, host: str = "127.0.0.1",
                 port: int = 8000):
        self.frontend = frontend
        self.host = host
        self.port = port            # rebound to the real port on start()
        self._server: Optional[asyncio.base_events.Server] = None
        self._drain_task: Optional[asyncio.Task] = None

    async def start(self) -> None:
        await self.frontend.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.frontend.stop()

    async def serve_forever(self) -> None:
        assert self._server is not None, "start() first"
        try:
            async with self._server:
                await self._server.serve_forever()
        except asyncio.CancelledError:
            # a completed drain closes the listener, which cancels
            # serve_forever — that is the graceful-exit path, not an error
            if self._drain_task is None or not self._drain_task.done():
                raise

    # -- graceful drain ------------------------------------------------------

    def begin_drain(self) -> asyncio.Task:
        """Start a graceful drain exactly once (idempotent): admission
        stops immediately (new submits get 503), every in-flight request
        — queued, parked in the KV handoff, or mid-decode — finishes and
        flushes its SSE tail, then the listener closes so
        ``serve_forever()`` returns.  Wired to SIGTERM and ``POST /drain``
        by ``launch/serve.py``."""
        if self._drain_task is None:
            self._drain_task = asyncio.ensure_future(self._drain_and_close())
        return self._drain_task

    async def _drain_and_close(self) -> None:
        await self.frontend.drain()
        if self._server is not None:
            self._server.close()

    # -- request handling ----------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await _read_request(reader)
            if parsed is None:
                writer.write(_json_response(400, "Bad Request",
                                            {"error": "malformed request"}))
            else:
                method, path, _headers, body = parsed
                await self._route(method, path, body, writer)
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass                    # client went away mid-stream
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _route(self, method: str, path: str, body: bytes,
                     writer: asyncio.StreamWriter) -> None:
        if method == "GET" and path == "/healthz":
            writer.write(_response(200, "OK", b"ok\n", "text/plain"))
        elif method == "GET" and path == "/readyz":
            if self.frontend.ready:
                writer.write(_response(200, "OK", b"ready\n", "text/plain"))
            else:
                msg = (b"draining\n" if self.frontend.draining
                       else b"warming up\n")
                writer.write(_response(503, "Service Unavailable",
                                       msg, "text/plain"))
        elif method == "GET" and path == "/metrics":
            lines = "".join(f"repro_serving_{k} {v}\n"
                            for k, v in self.frontend.metrics().items())
            writer.write(_response(200, "OK", lines.encode(), "text/plain"))
        elif method == "POST" and path == "/drain":
            self.begin_drain()
            writer.write(_json_response(202, "Accepted", {
                "draining": True,
                "in_flight": int(self.frontend.metrics()["active_slots"]),
                "queued": self.frontend.queue_depth()}))
        elif method == "POST" and path == "/v1/generate":
            await self._generate(body, writer)
        else:
            writer.write(_json_response(404, "Not Found",
                                        {"error": f"no route {method} {path}"}))

    async def _generate(self, body: bytes,
                        writer: asyncio.StreamWriter) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = spec["prompt"]
            max_new = int(spec["max_new"])
        except (json.JSONDecodeError, KeyError, TypeError, ValueError) as e:
            writer.write(_json_response(400, "Bad Request", {
                "error": f"body must be JSON with integer-token 'prompt' "
                         f"and 'max_new': {e!r}"}))
            return
        stream = bool(spec.get("stream", True))
        try:
            rid, q = self.frontend.submit(
                prompt, max_new,
                policy=spec.get("policy"),
                priority=int(spec.get("priority", 0)),
                deadline_s=spec.get("deadline_s"),
                src=spec.get("src"))
        except Draining as e:
            writer.write(_json_response(503, "Service Unavailable",
                                        {"error": str(e)}))
            return
        except Backpressure as e:
            retry = max(1, int(np.ceil(e.retry_after_s)))
            writer.write(_json_response(
                429, "Too Many Requests",
                {"error": str(e), "retry_after_s": retry},
                extra_headers={"Retry-After": str(retry)}))
            return
        except ValueError as e:
            writer.write(_json_response(400, "Bad Request",
                                        {"error": str(e)}))
            return
        if stream:
            await self._stream_sse(rid, q, writer)
        else:
            await self._collect_json(rid, q, writer)

    @staticmethod
    def _done_payload(rid: int, f, tokens) -> dict:
        return {
            "rid": rid,
            "tokens": [int(t) for t in tokens],
            "generated": int(f.generated),
            "policy": f.policy,
            "preempted": int(f.preempted),
            "invocations": int(f.invocations),
            "mean_accepted": float(f.mean_accepted),
            "queue_delay_s": float(f.queue_delay),
            "latency_s": float(f.latency),
        }

    async def _stream_sse(self, rid: int, q: asyncio.Queue,
                          writer: asyncio.StreamWriter) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        streamed = []
        while True:
            ev = await q.get()
            if ev.kind == "tokens":
                toks = [int(t) for t in ev.data]
                streamed.extend(toks)
                writer.write(sse_event("token", {"rid": rid, "tokens": toks}))
            elif ev.kind == "done":
                writer.write(sse_event(
                    "done", self._done_payload(rid, ev.data, streamed)))
                await writer.drain()
                return
            await writer.drain()

    async def _collect_json(self, rid: int, q: asyncio.Queue,
                            writer: asyncio.StreamWriter) -> None:
        streamed = []
        while True:
            ev = await q.get()
            if ev.kind == "tokens":
                streamed.extend(int(t) for t in ev.data)
            elif ev.kind == "done":
                writer.write(_json_response(
                    200, "OK", self._done_payload(rid, ev.data, streamed)))
                return
