"""Continuous-batching engine on top of the BPD decode loop.

The run-to-completion ``bpd_decode`` keeps a whole batch resident until its
slowest row finishes — dead rows still cost a model invocation per
iteration.  This engine generalizes ``BPDState`` to a slot-based
``SlotBatch`` (see serving/types.py): a *static* device batch of
``num_slots`` rows where

  * finished rows are evicted (``active`` goes False) and their KV rows are
    invalidated (``pos = -1``) so the slot is immediately reusable,
  * a queued request is admitted mid-flight by a single-row prefill that is
    scattered into the freed slot (``models.cache.scatter_row``) while the
    other slots keep decoding,
  * every slot carries its own prompt length, generation budget and
    statistics, so a decode step is one ``bpd_iteration`` over a slot
    group with a per-slot ``active`` mask and per-slot ``max_new``.

**Per-request decode policies (policy slot grouping).**  The engine's slot
slab is partitioned into per-policy *slot groups*: ``policies={"exact": 2,
"topk_tree": 2}`` gives each named policy its own contiguous range of the
``num_slots`` slab, materialized as a group-local ``SlotBatch`` view with
its own compile-once init/admit/step/evict from
``DecodeSession.serving_fns(policy=...)`` — one jitted step per distinct
(policy, geometry), shared between groups via the session's
``DecodePolicy.cache_key``-keyed jit cache.  An admitted request routes to
the group running its ``Request.policy`` (``None`` = the session default);
the host loop round-robins the active groups each ``step()``, dispatching
every group's step before reading any status back, so device work overlaps
and each *group step* costs exactly ONE fused device→host sync.

The engine itself is a **scheduler + slot-metadata shell**: all device
functions are owned by a ``serving.session.DecodeSession`` — the same
sharding-aware driver behind ``bpd_decode`` — and compile exactly once per
(policy, geometry) (padded prompts, traced slot indices and group ids).
Pass ``mesh=`` (or a prebuilt ``session=``) to shard every group's slot
batch over the data axes and the model over the tensor axis; each group's
slot count must then divide the data axes on its own.

The host loop performs exactly ONE device→host sync per group step: the
jitted step returns a fused (S,) int8 status (bit 0 = active, bit 1 =
harvestable) alongside the donated slot state, and ``free_slots`` /
``has_active`` / a no-finish ``harvest`` read the host-side mirror
(``num_host_syncs`` counts the transfers per GROUP STEP, never per slot —
gated in tests).

Padded prefill is safe because cache visibility is governed by absolute
positions: a stale entry with stored position p is only attended when
``p < length + k``, and the decode step with that length rewrites position
p in ``cache_write`` *before* attending (see models/cache.py).  That
argument covers KV caches only — recurrent-state families (rwkv6 / hymba)
would fold pad tokens into their final state, so the engine is gated to
``block_type == "attn"``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (Any, Deque, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.serving.pages import PageAllocator, PagePoolExhausted
from repro.serving.session import DecodeSession, ServingFns
from repro.serving.types import (EngineConfig, FinishedRequest,
                                 PreemptedRequest, Request, SlotBatch)

__all__ = ["ContinuousBatchingEngine", "PolicyGroup", "SlotBatch",
           "PagePoolExhausted", "PreemptedRequest", "HandoffRecord"]

I32 = jnp.int32


@dataclasses.dataclass
class PolicyGroup:
    """Host-side record of one policy slot group: a contiguous view of the
    engine's slot slab ([offset, offset + num_slots)) stepped by its own
    compiled functions under its own decode policy."""

    gid: int                    # group index (== SlotBatch.group rows)
    name: str                   # registered policy name (routing key)
    policy: object              # the bound DecodePolicy
    offset: int                 # first global slot id of this group
    num_slots: int              # slots in this group's view
    fns: ServingFns             # compiled init/admit/step/evict
    state: SlotBatch            # the group-local device state
    status: np.ndarray          # host mirror, (num_slots,) int8
    slot_meta: List[Optional[dict]]
    pages: Optional[PageAllocator] = None  # host page allocator (paged only)

    def free_local(self) -> List[int]:
        """Group-local indices of free slots (host mirror, bit 0 clear) —
        the one definition of "free" shared by admission and the engine's
        global free-slot view."""
        return [i for i in range(self.num_slots) if not self.status[i] & 1]


@dataclasses.dataclass
class HandoffRecord:
    """One finished prefill parked in the KV-handoff queue: row ``row`` of
    the device-side ``packet`` (a ``session.PrefillPacket``, shared by up
    to ``prefill_slots`` records from the same worker batch) plus the host
    metadata ``attach`` needs to install it into a freed slot."""

    req: Request
    packet: Any                 # device PrefillPacket (shared per batch)
    row: int                    # this request's row inside the packet
    prompt_len: int
    max_new: int
    prefill_time: float         # when the prefill batch was dispatched


def _normalize_groups(policies, default_name: str,
                      num_slots: int) -> List[Tuple[str, int]]:
    """policies: None | {name: slots} | [(name, slots), ...] -> ordered
    [(name, slots)] partitioning ``num_slots``."""
    if policies is None:
        return [(default_name, num_slots)]
    items = (list(policies.items()) if isinstance(policies, dict)
             else [tuple(p) for p in policies])
    if not items:
        raise ValueError("policies must name at least one slot group")
    names = [n for n, _ in items]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate policy group names in {names}: one "
                         f"slot group per policy")
    for n, sl in items:
        if sl <= 0:
            raise ValueError(f"policy group {n!r} has {sl} slots: every "
                             f"group needs at least one")
    total = sum(sl for _, sl in items)
    if total != num_slots:
        raise ValueError(
            f"policy groups {dict(items)} cover {total} slots but "
            f"EngineConfig.num_slots={num_slots}: groups must partition "
            f"the slot slab exactly")
    return items


class ContinuousBatchingEngine:
    """Slot-based continuous batching for the decoder-only BPD loop,
    with per-request decode policies via policy slot groups."""

    def __init__(self, params, cfg: ModelConfig, dec: DecodeConfig,
                 ecfg: EngineConfig, *, mesh=None,
                 session: Optional[DecodeSession] = None, policy=None,
                 bundles=None,
                 policies: Union[None, Dict[str, int],
                                 Sequence[Tuple[str, int]]] = None):
        if cfg.block_type != "attn":
            raise NotImplementedError(
                f"serving engine requires an attention-cache family "
                f"(block_type='attn'), got {cfg.block_type!r}: recurrent "
                f"states cannot be prefilled from a padded prompt")
        if cfg.modality != "text":
            raise NotImplementedError(
                "serving engine v1 is text-only (per-request vision prefixes "
                "would make the prefill shape dynamic)")
        if cfg.is_encoder_only or cfg.is_encoder_decoder:
            raise NotImplementedError("serving engine is decoder-only")

        self.session = session if session is not None else DecodeSession(
            params, cfg, dec, mesh=mesh, policy=policy, bundles=bundles)
        for name, b in self.session.bundles.items():
            if b.cfg.block_type != "attn":
                raise NotImplementedError(
                    f"auxiliary bundle {name!r} has block_type="
                    f"{b.cfg.block_type!r}: the engine's padded admission "
                    f"prefill is only sound for attention caches (same "
                    f"argument as the primary model)")
        ecfg.validate(dec=self.session.dec, mesh=self.session.mesh)
        self.policy = self.session.policy

        # the session is the source of truth for model/decode config — a
        # caller-provided session may differ from the cfg/dec args, and the
        # device functions are built from the session's copies
        self.cfg = cfg = self.session.cfg
        self.dec = dec = self.session.dec
        self.ecfg = ecfg
        self.block_k = dec.block_k or cfg.bpd_k
        self.prefix = cfg.num_meta_tokens
        self.context_len = self.prefix + ecfg.max_prompt_len + ecfg.max_new_cap
        self.buf_len = ecfg.max_prompt_len + ecfg.max_new_cap + self.block_k

        # -- policy slot groups: partition the slab, one compiled fns set
        # per distinct (policy, geometry), one state view per group --------
        self.default_policy = self.policy.name
        specs = _normalize_groups(policies, self.default_policy,
                                  ecfg.num_slots)
        self.groups: List[PolicyGroup] = []
        offset = 0
        for gid, (name, slots) in enumerate(specs):
            gecfg = dataclasses.replace(ecfg, num_slots=slots)
            # per-group mesh validation: each group's view shards the data
            # axes on its own, so every group's slot count must divide them
            gecfg.validate(dec=dec, mesh=self.session.mesh)
            # the default group (policies=None) serves the session's BOUND
            # policy object — re-resolving its name through the registry
            # would silently replace a caller-supplied / hand-built
            # DecodePolicy with the registry default of the same name
            pol_arg = None if policies is None else name
            fns = self.session.serving_fns(gecfg, policy=pol_arg)
            # each group owns its page pool (its SlotBatch holds a separate
            # kp/vp buffer), so the host allocator is per-group too
            pages = None
            if fns.paged is not None:
                geom = fns.paged
                pages = PageAllocator(geom.num_pages, geom.page_size,
                                      geom.pages_per_row,
                                      prefix_len=geom.prefix_len)
            self.groups.append(PolicyGroup(
                gid=gid, name=name,
                policy=self.session.bound_policy(pol_arg),
                offset=offset, num_slots=slots, fns=fns,
                state=fns.init(jnp.asarray(gid, I32)),
                status=np.zeros((slots,), np.int8),
                slot_meta=[None] * slots,
                pages=pages))
            offset += slots
        self._by_name = {g.name: g for g in self.groups}
        self._rr = 0            # round-robin pointer over group steps

        # -- disaggregated prefill/decode (prefill_slots > 0): dedicated
        # prefill workers batch prompt prefills and park the finished KV
        # state in a bounded handoff queue; decode groups pull rows into
        # freed slots without ever serializing admission behind a step ----
        self.disaggregated = ecfg.prefill_slots > 0
        self.prefill_width = max(ecfg.prefill_slots, 1)
        self.handoff_cap = ecfg.handoff_cap or max(2 * ecfg.num_slots,
                                                   ecfg.prefill_slots)
        self._staged: Dict[str, List[Tuple[Request, float]]] = {
            g.name: [] for g in self.groups}         # awaiting a prefill
        self._handoff: Dict[str, Deque[HandoffRecord]] = {
            g.name: deque() for g in self.groups}    # awaiting a slot

        self.num_admits = 0     # requests entering a slot (admit or attach)
        self.num_steps = 0      # decode ITERATIONS (full-width model
                                # forwards; a windowed step adds every
                                # iteration its while_loop actually ran)
        self.num_host_syncs = 0  # device->host readbacks (regression guard)
        self.num_stream_syncs = 0  # poll_progress readbacks (streaming only)
        self.num_prefill_batches = 0   # prefill-worker forwards dispatched
        self.num_attach_backpressure = 0  # attach stalls (page pool full)
        # per-phase host wall-clock attribution (the speedup ledger):
        # where the serving loop actually spends its host time
        self.time_in_prefill = 0.0          # prefill dispatch (admit incl.)
        self.time_in_decode_dispatch = 0.0  # group-step dispatch, no sync
        self.time_in_harvest = 0.0          # status pulls + retirement
        # harvest of one group completed while ANOTHER stepped group's
        # status was still unpulled (its device step still in flight) —
        # the async per-group stream overlap, asserted in tests
        self.num_overlap_harvests = 0

    @property
    def params(self):
        """Mesh-placed parameters (owned by the DecodeSession)."""
        return self.session.params

    @property
    def aux_params(self):
        """Auxiliary bundle params (e.g. the draft model's), mesh-placed
        per bundle by the DecodeSession."""
        return self.session.aux_params

    @property
    def state(self) -> SlotBatch:
        """The slot state — single-group engines only (the historical
        engine API).  Multi-group engines expose per-group views via
        ``groups`` / ``group_for``."""
        if len(self.groups) != 1:
            raise AttributeError(
                f"engine has {len(self.groups)} policy slot groups — read "
                f"engine.groups[gid].state (or group_for(policy).state) "
                f"instead of the single-group .state shorthand")
        return self.groups[0].state

    # -- group routing -------------------------------------------------------

    def group_for(self, policy: Optional[str]) -> PolicyGroup:
        """The slot group serving ``policy`` (None = the session default).
        Raises ValueError for policies the engine was not configured with,
        resolving the name through ``config.registry`` first so unknown
        names fail with the registry's message."""
        name = policy or self.default_policy
        g = self._by_name.get(name)
        if g is None:
            from repro.config import get_policy

            get_policy(self.dec, name)  # unknown name -> registry ValueError
            raise ValueError(
                f"request policy {name!r} has no slot group in this engine "
                f"(groups: {sorted(self._by_name)}): configure it via "
                f"ContinuousBatchingEngine(policies={{{name!r}: n, ...}})")
        return g

    def policy_names(self) -> List[str]:
        return [g.name for g in self.groups]

    # -- host-side API -------------------------------------------------------

    def free_slots(self, policy: Optional[str] = None) -> List[int]:
        """Global ids of free slots — all groups (default), or the single
        group serving ``policy`` (a name; pass the default policy's name
        to query the default group alone)."""
        groups = self.groups if policy is None else [self.group_for(policy)]
        return [g.offset + i for g in groups for i in g.free_local()]

    def has_active(self) -> bool:
        return any(bool(np.any(g.status & 1)) for g in self.groups)

    def _padded(self, req: Request) -> Tuple[np.ndarray, int, np.ndarray, int]:
        """Pad a request's prompt/src rows to the admission geometry (the
        one definition shared by unified admit and the prefill workers)."""
        p = len(req.prompt)
        if not 0 < p <= self.ecfg.max_prompt_len:
            raise ValueError(
                f"prompt length {p} outside (0, {self.ecfg.max_prompt_len}]")
        prompt = np.zeros((self.ecfg.max_prompt_len,), np.int32)
        prompt[:p] = req.prompt
        # source tokens for drafting policies: the request's src (padded /
        # truncated to the admission geometry), defaulting to the prompt
        src_toks = req.prompt if req.src is None else req.src
        src = np.zeros((self.ecfg.max_prompt_len,), np.int32)
        n_src = min(len(src_toks), self.ecfg.max_prompt_len)
        src[:n_src] = src_toks[:n_src]
        max_new = int(np.clip(req.max_new, 1, self.ecfg.max_new_cap))
        return prompt, p, src, max_new

    def admit(self, req: Request, *, now: Optional[float] = None) -> int:
        """Admit a request into a free slot of its policy's group; returns
        the global slot index."""
        g = self.group_for(req.policy)
        free = g.free_local()
        if not free:
            raise RuntimeError(
                f"no free slot in policy group {g.name!r} — poll "
                f"step()/harvest first")
        slot = free[0]
        prompt, p, src, max_new = self._padded(req)
        extra = ()
        if g.pages is not None:
            # host-side page plan first: raises PagePoolExhausted (back-
            # pressure, the scheduler requeues) before any device work, and
            # reuses pooled pages for identical prompt prefixes (CoW)
            tbl_row, write_mask = g.pages.plan_admit(
                slot, req.prompt, p, max_new, self.block_k)
            extra = (jnp.asarray(tbl_row), jnp.asarray(write_mask))
        t0 = time.monotonic()
        g.state = g.fns.admit(
            self.params, self.aux_params, g.state, jnp.asarray(slot, I32),
            jnp.asarray(prompt), jnp.asarray(p, I32),
            jnp.asarray(max_new, I32), jnp.asarray(src), *extra)
        self.time_in_prefill += time.monotonic() - t0
        g.status[slot] = 1          # known host-side: no readback needed
        self.num_admits += 1
        admit_time = time.monotonic() if now is None else now
        if req.arrival is None:
            req.arrival = admit_time
        g.slot_meta[slot] = {
            "req": req, "prompt_len": p, "max_new": max_new,
            "admit_time": admit_time, "emitted": 0,
        }
        return g.offset + slot

    # -- disaggregated prefill/decode ----------------------------------------

    def handoff_backlog(self) -> int:
        """Requests staged for prefill plus rows parked in the KV-handoff
        queue — work admitted to the engine that holds no slot yet."""
        return (sum(len(v) for v in self._staged.values())
                + sum(len(v) for v in self._handoff.values()))

    def handoff_free(self) -> int:
        """Remaining capacity of the bounded handoff pipeline (staged +
        parked share one bound so prefill output can never pile up
        unboundedly when decode stalls)."""
        return self.handoff_cap - self.handoff_backlog()

    def queue_prefill(self, req: Request, *, now: Optional[float] = None) -> None:
        """Stage a request for the prefill workers (disaggregated mode
        only).  Validates geometry now so malformed requests fail at
        submission, not inside a worker batch; raises RuntimeError when the
        handoff pipeline is full (back-pressure — callers check
        ``handoff_free()`` first, exactly like ``free_slots`` for admit)."""
        if not self.disaggregated:
            raise RuntimeError(
                "queue_prefill requires a disaggregated engine "
                "(EngineConfig.prefill_slots > 0); unified engines admit "
                "directly")
        g = self.group_for(req.policy)
        self._padded(req)           # geometry validation only
        if self.handoff_free() <= 0:
            raise RuntimeError(
                f"KV-handoff queue full ({self.handoff_cap} staged+parked) "
                f"— poll attach_ready()/step() first")
        t = time.monotonic() if now is None else now
        if req.arrival is None:
            req.arrival = t
        self._staged[g.name].append((req, t))

    def run_prefills(self, *, now: Optional[float] = None) -> int:
        """Dispatch prefill-worker batches for everything staged: each
        batch prefills up to ``prefill_slots`` prompts in ONE forward
        (short batches are padded with inert dummy rows — same static
        shape, so the worker compiles once) and parks its rows in the
        handoff queue as ``HandoffRecord``s sharing the device packet.
        Dispatch-only — no device→host sync.  Returns rows parked."""
        t0 = time.monotonic()
        parked = 0
        w = self.prefill_width
        for g in self.groups:
            staged = self._staged[g.name]
            while staged:
                if (len(staged) < w
                        and (self._handoff[g.name]
                             or not g.free_local())):
                    # coalesce: parked rows already cover the free slots
                    # (or none are free), so a partial batch buys no TTFT
                    # — hold the stage until a full-width batch forms.
                    # The moment a slot opens with nothing parked, the
                    # next call dispatches whatever is staged: deferring
                    # past that point idles decode slots, which costs
                    # more than the padded partial forward saves
                    break
                batch, self._staged[g.name] = staged[:w], staged[w:]
                staged = self._staged[g.name]
                prompts = np.zeros((w, self.ecfg.max_prompt_len), np.int32)
                plens = np.ones((w,), np.int32)   # dummy rows: 1-token prompt
                srcs = np.zeros((w, self.ecfg.max_prompt_len), np.int32)
                rows = []
                for r, (req, _) in enumerate(batch):
                    prompt, p, src, max_new = self._padded(req)
                    prompts[r], plens[r], srcs[r] = prompt, p, src
                    rows.append((req, r, p, max_new))
                packet = g.fns.prefill(self.params, self.aux_params,
                                       jnp.asarray(prompts),
                                       jnp.asarray(plens), jnp.asarray(srcs))
                self.num_prefill_batches += 1
                t = time.monotonic() if now is None else now
                for req, r, p, max_new in rows:
                    self._handoff[g.name].append(HandoffRecord(
                        req=req, packet=packet, row=r, prompt_len=p,
                        max_new=max_new, prefill_time=t))
                    parked += 1
        self.time_in_prefill += time.monotonic() - t0
        return parked

    def attach_ready(self, *, now: Optional[float] = None) -> int:
        """Install parked handoff rows into freed decode slots (the
        prefill→decode KV handoff — under a pod mesh this is the
        sharding-constrained device-to-device transfer).  FIFO per group;
        a page-pool-exhausted head waits in place (head-of-line, so
        admission order within a group is preserved).

        Consecutive records sharing one prefill packet install in ONE
        ``attach_many`` dispatch (a per-record attach call would hand
        back the dispatch overhead that batching the prefill amortized).
        Returns the number of requests attached."""
        attached = 0
        w = self.prefill_width
        for g in self.groups:
            q = self._handoff[g.name]
            while q:
                free = g.free_local()
                if not free:
                    break
                # gather up to W head records from the SAME packet that
                # have both a free slot and (if paged) a page plan
                pkt = q[0].packet
                batch, blocked = [], False
                while (q and q[0].packet is pkt and len(batch) < len(free)
                       and len(batch) < w):
                    rec, slot = q[0], free[len(batch)]
                    extra = None
                    if g.pages is not None:
                        try:
                            extra = g.pages.plan_admit(
                                slot, rec.req.prompt, rec.prompt_len,
                                rec.max_new, self.block_k)
                        except PagePoolExhausted:
                            # head-of-line: the failed record waits for a
                            # release; whatever fit still attaches below
                            self.num_attach_backpressure += 1
                            blocked = True
                            break
                    q.popleft()
                    batch.append((rec, slot, extra))
                if not batch:
                    break
                rows = np.zeros((w,), np.int32)
                slots = np.zeros((w,), np.int32)
                maxn = np.zeros((w,), np.int32)
                valid = np.zeros((w,), bool)
                for i, (rec, slot, _) in enumerate(batch):
                    rows[i], slots[i] = rec.row, slot
                    maxn[i], valid[i] = rec.max_new, True
                pextra = ()
                if g.pages is not None:
                    P_ = g.fns.paged.pages_per_row
                    tbls = np.zeros((w, P_), np.int32)
                    masks = np.zeros((w, P_), bool)
                    for i, (_, _, (tbl_row, write_mask)) in enumerate(batch):
                        tbls[i], masks[i] = tbl_row, write_mask
                    pextra = (jnp.asarray(tbls), jnp.asarray(masks))
                g.state = g.fns.attach_many(
                    g.state, pkt, jnp.asarray(rows), jnp.asarray(slots),
                    jnp.asarray(maxn), jnp.asarray(valid), *pextra)
                t = time.monotonic() if now is None else now
                for rec, slot, _ in batch:
                    g.status[slot] = 1  # known host-side: no readback needed
                    self.num_admits += 1
                    g.slot_meta[slot] = {
                        "req": rec.req, "prompt_len": rec.prompt_len,
                        "max_new": rec.max_new, "admit_time": t, "emitted": 0,
                    }
                attached += len(batch)
                if blocked:
                    break
        return attached

    def step(self, *, now: Optional[float] = None) -> List[FinishedRequest]:
        """One BPD iteration over every active slot group, then
        harvest+evict.

        Async per-group streams: groups step round-robin (the starting
        group rotates so no policy is systematically served first), ALL
        group steps are dispatched before any status is read back, and
        each stepped group is then pulled AND harvested in dispatch order
        — so the host-side harvest of group A (status pull, token copies,
        retirement, evict dispatch) overlaps group B's still-in-flight
        device step (counted in ``num_overlap_harvests``).  Each group
        step still costs exactly one fused device→host sync, now off the
        critical path of the other groups' device work.
        """
        t0 = time.monotonic()
        n = len(self.groups)
        order = [self.groups[(self._rr + i) % n] for i in range(n)]
        self._rr = (self._rr + 1) % n
        stepped = []
        for g in order:
            if not np.any(g.status & 1):
                continue                     # idle group: no device work
            g.state, status, iters = g.fns.step(self.params,
                                                self.aux_params, g.state)
            stepped.append((g, status, iters))
        self.time_in_decode_dispatch += time.monotonic() - t0
        # the ONE per-group-step device->host round-trip: a fused (S,) int8
        # array carrying both the active and the finished bits (the harvest
        # decision) — pulled only after every group's step is in flight,
        # and each group's harvest runs before the NEXT group's pull
        out: List[FinishedRequest] = []
        t1 = time.monotonic()
        for idx, (g, status, iters) in enumerate(stepped):
            # one fused pull: the (S,) status plus the window's iteration
            # count (a windowed step is 1..steps_per_sync forwards — the
            # invocation accounting must count every one of them)
            status_h, it = jax.device_get((status, iters))
            g.status = np.array(status_h)    # writable host copy
            self.num_steps += int(it)
            self.num_host_syncs += 1
            out += self._harvest_group(g, now=now)
            if idx < len(stepped) - 1:
                # host work above ran while the later stepped groups'
                # statuses were still unpulled (their device steps free to
                # proceed) — the measurable async-stream overlap
                self.num_overlap_harvests += 1
        self.time_in_harvest += time.monotonic() - t1
        return out

    def harvest(self, *, now: Optional[float] = None) -> List[FinishedRequest]:
        """Retire finished slots of every group: copy outputs out, free
        the slots (host-cached status decides — a no-finish group costs
        zero additional device syncs)."""
        out: List[FinishedRequest] = []
        for g in self.groups:
            out += self._harvest_group(g, now=now)
        return out

    def _harvest_group(self, g: PolicyGroup, *,
                       now: Optional[float] = None) -> List[FinishedRequest]:
        """Retire the finished slots of ONE group.

        Decides from the host-cached status — the common no-finish group
        step costs zero additional device syncs; the big per-slot arrays
        are only pulled when something actually finished (one pull per
        finishing group, counted in ``num_host_syncs``).
        """
        done_mask = (g.status & 2).astype(bool)
        if not done_mask.any():
            return []
        t = time.monotonic() if now is None else now
        out: List[FinishedRequest] = []
        # one FUSED transfer for all four arrays — a single host round-trip
        # instead of four sequential blocking pulls
        tokens, text_len, generated, invocations = jax.device_get(
            (g.state.tokens, g.state.text_len,
             g.state.generated, g.state.invocations))
        self.num_host_syncs += 1  # one harvest pull per finishing group
        for i in np.nonzero(done_mask)[0]:
            meta = g.slot_meta[i]
            req: Request = meta["req"]
            p = meta["prompt_len"]
            iters = max(int(invocations[i]) - 1, 1)  # minus the prefill
            out.append(FinishedRequest(
                rid=req.rid, prompt_len=p,
                tokens=tokens[i, p:int(text_len[i])].copy(),
                generated=int(generated[i]),
                invocations=int(invocations[i]),
                mean_accepted=float(generated[i]) / iters,
                arrival=req.arrival, admit_time=meta["admit_time"],
                finish_time=t, policy=g.name))
            g.slot_meta[i] = None
            if g.pages is not None:
                g.pages.release(int(i))
        g.state = g.fns.evict(g.state, jnp.asarray(done_mask))
        g.status[done_mask] = 0     # known host-side: freed, inactive
        return out

    # -- streaming + preemption (serving front end) --------------------------

    def poll_progress(self) -> List[Tuple[Request, np.ndarray]]:
        """Committed-but-unstreamed tokens per ACTIVE slot since the last
        poll: ``[(request, new_tokens), ...]``.

        This is the streaming read the HTTP/SSE front end runs after each
        ``step()``; it costs one extra device→host pull per group with
        active slots (counted in ``num_stream_syncs``, separate from the
        engine's one-fused-sync-per-group-step contract — callers that
        never stream never pay it).  A slot that finished in the preceding
        step was already harvested (its meta is gone); its tail tokens
        reach the front end through ``FinishedRequest.tokens`` instead.
        """
        out: List[Tuple[Request, np.ndarray]] = []
        for g in self.groups:
            live = [i for i in range(g.num_slots)
                    if (g.status[i] & 1) and g.slot_meta[i] is not None]
            if not live:
                continue
            tokens, text_len = jax.device_get(
                (g.state.tokens, g.state.text_len))
            self.num_stream_syncs += 1
            for i in live:
                meta = g.slot_meta[i]
                start = meta["prompt_len"] + meta["emitted"]
                end = int(text_len[i])
                if end > start:
                    out.append((meta["req"], tokens[i, start:end].copy()))
                    meta["emitted"] = end - meta["prompt_len"]
        return out

    def pull_group(self, g: PolicyGroup) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, np.ndarray]:
        """One host pull of group ``g``'s per-slot progress arrays
        ``(tokens, text_len, generated, invocations)`` — the scheduler
        reads these to pick a preemption victim (feasibility needs
        ``generated``), then hands them back to ``preempt`` so choosing
        and evicting cost a single sync together."""
        pulled = (np.asarray(g.state.tokens), np.asarray(g.state.text_len),
                  np.asarray(g.state.generated),
                  np.asarray(g.state.invocations))
        self.num_host_syncs += 1
        return pulled

    def preempt(self, g: PolicyGroup, slot: int,
                pulled=None) -> PreemptedRequest:
        """Evict the ACTIVE request in group ``g``'s local ``slot`` and
        return its committed progress for requeueing.

        Mirrors harvest's cleanup exactly (evict + page release + status/
        meta clear) but for one mid-flight slot: the committed tokens
        survive in the returned record, uncommitted block proposals are
        discarded (they live beyond ``text_len`` and were never part of
        the result stream).  The caller (scheduler) re-admits the request
        as a continuation whose prompt is ``prompt + tokens`` — the same
        padded-prefill path as any admission, so the continuation's stream
        is the decode of the identical committed context.

        ``pulled`` is an optional ``pull_group(g)`` result to reuse (victim
        selection already paid the sync); None pulls fresh.
        """
        if not g.status[slot] & 1 or g.slot_meta[slot] is None:
            raise RuntimeError(
                f"preempt: slot {slot} of group {g.name!r} holds no active "
                f"request")
        tokens, text_len, generated, invocations = (
            pulled if pulled is not None else self.pull_group(g))
        meta = g.slot_meta[slot]
        rec = PreemptedRequest(
            req=meta["req"],
            tokens=tokens[slot, meta["prompt_len"]:int(text_len[slot])].copy(),
            generated=int(generated[slot]),
            invocations=int(invocations[slot]),
            streamed=meta["emitted"])
        mask = np.zeros((g.num_slots,), bool)
        mask[slot] = True
        g.state = g.fns.evict(g.state, jnp.asarray(mask))
        g.status[slot] = 0
        g.slot_meta[slot] = None
        if g.pages is not None:
            g.pages.release(slot)
        return rec

    # -- diagnostics ---------------------------------------------------------

    def compile_counts(self) -> dict:
        """jit cache sizes — the recompilation regression guard.  Each entry
        must be ≤ 1 after any amount of traffic (static shapes by design).
        Distinct (policy, geometry) fns are counted once even when several
        groups share them (the session's jit cache dedups); multi-group
        engines prefix entries with the policy name."""
        single = len(self.groups) == 1
        out, seen = {}, set()
        for g in self.groups:
            if id(g.fns) in seen:
                continue
            seen.add(id(g.fns))
            for part in ("admit", "prefill", "attach", "attach_many",
                         "step", "evict"):
                n = getattr(g.fns, part)._cache_size()
                if n == 0:
                    # never traced — unified engines don't call the
                    # prefill/attach pair, disaggregated ones only reach
                    # admit through preemption; an uncalled fn can't have
                    # recompiled, so a 0 would only trip the strict ==1
                    # gates for paths a run legitimately never took
                    continue
                key = part if single else f"{g.name}/{part}"
                out[key] = n
        return out
