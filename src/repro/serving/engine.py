"""Continuous-batching engine on top of the BPD decode loop.

The run-to-completion ``bpd_decode`` keeps a whole batch resident until its
slowest row finishes — dead rows still cost a model invocation per
iteration.  This engine generalizes ``BPDState`` to a slot-based
``SlotBatch`` (see serving/types.py): a *static* device batch of
``num_slots`` rows where

  * finished rows are evicted (``active`` goes False) and their KV rows are
    invalidated (``pos = -1``) so the slot is immediately reusable,
  * a queued request is admitted mid-flight by a single-row prefill that is
    scattered into the freed slot (``models.cache.scatter_row``) while the
    other slots keep decoding,
  * every slot carries its own prompt length, generation budget and
    statistics, so the decode step is one ``bpd_iteration`` over the full
    slot batch with a per-slot ``active`` mask and per-slot ``max_new``.

The engine itself is a **scheduler + slot-metadata shell**: all device
functions (init / admit / step / evict) are owned by a
``serving.session.DecodeSession`` — the same sharding-aware driver behind
``bpd_decode`` — and compile exactly once (padded prompts, traced slot
indices).  Pass ``mesh=`` (or a prebuilt ``session=``) to shard the slot
batch over the data axes and the model over the tensor axis; the engine's
host logic is identical in both placements.  ``policy=`` (or the
session's) selects the ``DecodePolicy``; per-slot policy state lives in
``SlotBatch.policy_state`` and is reset on admit/evict.

The host loop performs exactly ONE device→host sync per step: the jitted
step returns a fused (S,) int8 status (bit 0 = active, bit 1 =
harvestable) alongside the donated slot state, and ``free_slots`` /
``has_active`` / a no-finish ``harvest`` read the host-side mirror
(``num_host_syncs`` counts the transfers; gated in tests).

Padded prefill is safe because cache visibility is governed by absolute
positions: a stale entry with stored position p is only attended when
``p < length + k``, and the decode step with that length rewrites position
p in ``cache_write`` *before* attending (see models/cache.py).  That
argument covers KV caches only — recurrent-state families (rwkv6 / hymba)
would fold pad tokens into their final state, so the engine is gated to
``block_type == "attn"``.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.serving.session import DecodeSession
from repro.serving.types import (EngineConfig, FinishedRequest, Request,
                                 SlotBatch)

__all__ = ["ContinuousBatchingEngine", "SlotBatch"]

I32 = jnp.int32


class ContinuousBatchingEngine:
    """Slot-based continuous batching for the decoder-only BPD loop."""

    def __init__(self, params, cfg: ModelConfig, dec: DecodeConfig,
                 ecfg: EngineConfig, *, mesh=None,
                 session: Optional[DecodeSession] = None, policy=None,
                 bundles=None):
        if cfg.block_type != "attn":
            raise NotImplementedError(
                f"serving engine requires an attention-cache family "
                f"(block_type='attn'), got {cfg.block_type!r}: recurrent "
                f"states cannot be prefilled from a padded prompt")
        if cfg.modality != "text":
            raise NotImplementedError(
                "serving engine v1 is text-only (per-request vision prefixes "
                "would make the prefill shape dynamic)")
        if cfg.is_encoder_only or cfg.is_encoder_decoder:
            raise NotImplementedError("serving engine is decoder-only")

        self.session = session if session is not None else DecodeSession(
            params, cfg, dec, mesh=mesh, policy=policy, bundles=bundles)
        for name, b in self.session.bundles.items():
            if b.cfg.block_type != "attn":
                raise NotImplementedError(
                    f"auxiliary bundle {name!r} has block_type="
                    f"{b.cfg.block_type!r}: the engine's padded admission "
                    f"prefill is only sound for attention caches (same "
                    f"argument as the primary model)")
        ecfg.validate(dec=self.session.dec, mesh=self.session.mesh)
        self.policy = self.session.policy

        # the session is the source of truth for model/decode config — a
        # caller-provided session may differ from the cfg/dec args, and the
        # device functions are built from the session's copies
        self.cfg = cfg = self.session.cfg
        self.dec = dec = self.session.dec
        self.ecfg = ecfg
        self.block_k = dec.block_k or cfg.bpd_k
        self.prefix = cfg.num_meta_tokens
        self.context_len = self.prefix + ecfg.max_prompt_len + ecfg.max_new_cap
        self.buf_len = ecfg.max_prompt_len + ecfg.max_new_cap + self.block_k
        self._fns = self.session.serving_fns(ecfg)
        self.state = self._fns.init()
        self.slot_meta: List[Optional[dict]] = [None] * ecfg.num_slots
        self.num_admits = 0     # prefill calls — device work accounting
        self.num_steps = 0      # batch iteration calls
        # host mirror of the per-slot status (bit 0 = active, bit 1 =
        # harvestable).  step() refreshes it from the device in ONE fused
        # transfer; admit/evict update it host-side (their effects are known
        # without a readback), so free_slots/has_active/harvest never sync.
        self._status = np.zeros((ecfg.num_slots,), np.int8)
        self.num_host_syncs = 0  # device->host readbacks (regression guard)

    @property
    def params(self):
        """Mesh-placed parameters (owned by the DecodeSession)."""
        return self.session.params

    @property
    def aux_params(self):
        """Auxiliary bundle params (e.g. the draft model's), mesh-placed
        per bundle by the DecodeSession."""
        return self.session.aux_params

    # -- host-side API -------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i in range(self.ecfg.num_slots)
                if not self._status[i] & 1]

    def has_active(self) -> bool:
        return bool(np.any(self._status & 1))

    def admit(self, req: Request, *, now: Optional[float] = None) -> int:
        """Admit a request into a free slot; returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot — poll step()/harvest first")
        p = len(req.prompt)
        if not 0 < p <= self.ecfg.max_prompt_len:
            raise ValueError(
                f"prompt length {p} outside (0, {self.ecfg.max_prompt_len}]")
        slot = free[0]
        prompt = np.zeros((self.ecfg.max_prompt_len,), np.int32)
        prompt[:p] = req.prompt
        max_new = int(np.clip(req.max_new, 1, self.ecfg.max_new_cap))
        self.state = self._fns.admit(
            self.params, self.aux_params, self.state, jnp.asarray(slot, I32),
            jnp.asarray(prompt), jnp.asarray(p, I32),
            jnp.asarray(max_new, I32))
        self._status[slot] = 1          # known host-side: no readback needed
        self.num_admits += 1
        admit_time = time.monotonic() if now is None else now
        if req.arrival is None:
            req.arrival = admit_time
        self.slot_meta[slot] = {
            "req": req, "prompt_len": p, "max_new": max_new,
            "admit_time": admit_time,
        }
        return slot

    def step(self, *, now: Optional[float] = None) -> List[FinishedRequest]:
        """One BPD iteration over all active slots, then harvest+evict."""
        self.num_steps += 1
        self.state, status = self._fns.step(self.params, self.aux_params,
                                            self.state)
        # the ONE per-step device->host round-trip: a fused (S,) int8 array
        # carrying both the active and the finished bits (the harvest
        # decision), instead of pulling state.active and state.finished
        # separately
        self._status = np.array(status)  # writable host copy
        self.num_host_syncs += 1
        return self.harvest(now=now)

    def harvest(self, *, now: Optional[float] = None) -> List[FinishedRequest]:
        """Retire finished slots: copy outputs out, free the slots.

        Decides from the host-cached status — the common no-finish step
        costs zero additional device syncs; the big per-slot arrays are
        only pulled when something actually finished.
        """
        done_mask = (self._status & 2).astype(bool)
        if not done_mask.any():
            return []
        t = time.monotonic() if now is None else now
        tokens = np.asarray(self.state.tokens)
        text_len = np.asarray(self.state.text_len)
        generated = np.asarray(self.state.generated)
        invocations = np.asarray(self.state.invocations)
        self.num_host_syncs += 1  # one harvest pull (4 arrays, one sync site)
        out = []
        for i in np.nonzero(done_mask)[0]:
            meta = self.slot_meta[i]
            req: Request = meta["req"]
            p = meta["prompt_len"]
            iters = max(int(invocations[i]) - 1, 1)  # minus the prefill call
            out.append(FinishedRequest(
                rid=req.rid, prompt_len=p,
                tokens=tokens[i, p:int(text_len[i])].copy(),
                generated=int(generated[i]),
                invocations=int(invocations[i]),
                mean_accepted=float(generated[i]) / iters,
                arrival=req.arrival, admit_time=meta["admit_time"],
                finish_time=t))
            self.slot_meta[i] = None
        self.state = self._fns.evict(self.state, jnp.asarray(done_mask))
        self._status[done_mask] = 0     # known host-side: freed, inactive
        return out

    # -- diagnostics ---------------------------------------------------------

    def compile_counts(self) -> dict:
        """jit cache sizes — the recompilation regression guard.  Each entry
        must be ≤ 1 after any amount of traffic (static shapes by design)."""
        return {
            "admit": self._fns.admit._cache_size(),
            "step": self._fns.step._cache_size(),
            "evict": self._fns.evict._cache_size(),
        }
