"""Continuous-batching engine on top of the BPD decode loop.

The run-to-completion ``bpd_decode`` keeps a whole batch resident until its
slowest row finishes — dead rows still cost a model invocation per
iteration.  This engine generalizes ``BPDState`` to a slot-based
``SlotBatch``: a *static* device batch of ``num_slots`` rows where

  * finished rows are evicted (``active`` goes False) and their KV rows are
    invalidated (``pos = -1``) so the slot is immediately reusable,
  * a queued request is admitted mid-flight by a single-row prefill that is
    scattered into the freed slot (``models.cache.scatter_row``) while the
    other slots keep decoding,
  * every slot carries its own prompt length, generation budget and
    statistics, so the decode step is one ``bpd_iteration`` over the full
    slot batch with a per-slot ``active`` mask and per-slot ``max_new``.

All three device functions (admit / step / evict) compile exactly once:
prompts are padded to ``max_prompt_len`` and slot indices are traced int32
scalars.  Padded prefill is safe because cache visibility is governed by
absolute positions: a stale entry with stored position p is only attended
when ``p < length + k``, and the decode step with that length rewrites
position p in ``cache_write`` *before* attending (see models/cache.py).
That argument covers KV caches only — recurrent-state families
(rwkv6 / hymba) would fold pad tokens into their final state, so the
engine is gated to ``block_type == "attn"``.
"""
from __future__ import annotations

import time
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.core import decode as decode_lib
from repro.models import model as model_lib
from repro.serving.types import EngineConfig, FinishedRequest, Request

I32 = jnp.int32


class SlotBatch(NamedTuple):
    """Device-side state: ``BPDState`` generalized to reusable slots."""

    tokens: jnp.ndarray        # (S, buf) per-slot prompt+output buffer
    text_len: jnp.ndarray      # (S,) valid tokens in the buffer
    prompt_len: jnp.ndarray    # (S,) prompt portion of text_len
    proposals: jnp.ndarray     # (S, k) next-block proposals
    caches: Any                # per-layer cache pytree (batch dim = S)
    active: jnp.ndarray        # (S,) bool — slot holds a live request
    finished: jnp.ndarray      # (S,) bool — request hit EOS / budget
    generated: jnp.ndarray     # (S,) accepted tokens so far
    max_new: jnp.ndarray       # (S,) per-slot generation budget
    invocations: jnp.ndarray   # (S,) model calls spent on this request


class ContinuousBatchingEngine:
    """Slot-based continuous batching for the decoder-only BPD loop."""

    def __init__(self, params, cfg: ModelConfig, dec: DecodeConfig,
                 ecfg: EngineConfig):
        if cfg.block_type != "attn":
            raise NotImplementedError(
                f"serving engine requires an attention-cache family "
                f"(block_type='attn'), got {cfg.block_type!r}: recurrent "
                f"states cannot be prefilled from a padded prompt")
        if cfg.modality != "text":
            raise NotImplementedError(
                "serving engine v1 is text-only (per-request vision prefixes "
                "would make the prefill shape dynamic)")
        if cfg.is_encoder_only or cfg.is_encoder_decoder:
            raise NotImplementedError("serving engine is decoder-only")

        self.params = params
        self.cfg = cfg
        self.dec = dec
        self.ecfg = ecfg
        self.block_k = dec.block_k or cfg.bpd_k
        self.prefix = cfg.num_meta_tokens
        self.context_len = self.prefix + ecfg.max_prompt_len + ecfg.max_new_cap
        self.buf_len = ecfg.max_prompt_len + ecfg.max_new_cap + self.block_k
        self._backend = decode_lib.causal_lm_backend(cfg)
        self.state = self._init_state()
        self.slot_meta: List[Optional[dict]] = [None] * ecfg.num_slots
        self.num_admits = 0     # prefill calls — device work accounting
        self.num_steps = 0      # batch iteration calls

        self._admit_fn = jax.jit(self._make_admit_fn())
        self._step_fn = jax.jit(self._make_step_fn())
        self._evict_fn = jax.jit(self._make_evict_fn())

    # -- state construction --------------------------------------------------

    def _init_state(self) -> SlotBatch:
        s, k = self.ecfg.num_slots, self.block_k
        zeros = lambda: jnp.zeros((s,), I32)
        return SlotBatch(
            tokens=jnp.zeros((s, self.buf_len), I32),
            text_len=zeros(),
            prompt_len=zeros(),
            proposals=jnp.zeros((s, k), I32),
            caches=model_lib.init_caches(self.cfg, s, self.context_len, k),
            active=jnp.zeros((s,), bool),
            finished=jnp.ones((s,), bool),   # empty slots read as finished
            generated=zeros(),
            max_new=zeros(),
            invocations=zeros(),
        )

    # -- compiled device functions ------------------------------------------

    def _make_admit_fn(self):
        cfg, ecfg = self.cfg, self.ecfg
        block_k, prefix = self.block_k, self.prefix
        context_len, buf_len = self.context_len, self.buf_len

        def admit(params, state: SlotBatch, slot, prompt, prompt_len,
                  max_new) -> SlotBatch:
            """Prefill one padded prompt into row ``slot``.

            prompt: (max_prompt_len,) int32; slot/prompt_len/max_new are
            traced int32 scalars so admission never recompiles.
            """
            row_caches = model_lib.init_caches(cfg, 1, context_len, block_k)
            h = model_lib.embed_inputs(params, cfg, {"tokens": prompt[None]})
            positions = jnp.arange(h.shape[1], dtype=I32)
            hidden, _, row_caches = model_lib.forward_hidden(
                params, cfg, h, positions=positions, caches=row_caches,
                moe_full_capacity=True)
            last = jax.lax.dynamic_index_in_dim(
                hidden[0], prefix + prompt_len - 1, axis=0, keepdims=False)
            logits = model_lib.all_head_logits(params, cfg, last)  # (K, V)
            proposals = jnp.argmax(logits[:block_k], axis=-1).astype(I32)

            row_tokens = jnp.zeros((buf_len,), I32)
            row_tokens = row_tokens.at[:ecfg.max_prompt_len].set(prompt)
            upd = lambda arr, val: arr.at[slot].set(val)
            return state._replace(
                tokens=upd(state.tokens, row_tokens),
                text_len=upd(state.text_len, prompt_len),
                prompt_len=upd(state.prompt_len, prompt_len),
                proposals=upd(state.proposals, proposals),
                caches=model_lib.scatter_cache_row(state.caches,
                                                   row_caches, slot),
                active=upd(state.active, True),
                finished=upd(state.finished, False),
                generated=upd(state.generated, 0),
                max_new=upd(state.max_new, max_new),
                invocations=upd(state.invocations, 1),  # the prefill call
            )

        return admit

    def _make_step_fn(self):
        cfg, dec, backend, prefix = self.cfg, self.dec, self._backend, self.prefix

        def step(params, state: SlotBatch) -> SlotBatch:
            bst = decode_lib.BPDState(
                tokens=state.tokens, text_len=state.text_len,
                proposals=state.proposals, caches=state.caches,
                finished=state.finished, iters=jnp.zeros((), I32),
                generated=state.generated)
            out = decode_lib.bpd_iteration(
                params, cfg, dec, backend, bst, prefix_offset=prefix,
                max_new=state.max_new, active=state.active)
            stepped = state.active & ~state.finished
            return state._replace(
                tokens=out.tokens, text_len=out.text_len,
                proposals=out.proposals, caches=out.caches,
                finished=out.finished, generated=out.generated,
                invocations=state.invocations + stepped.astype(I32))

        return step

    def _make_evict_fn(self):
        def evict(state: SlotBatch, mask) -> SlotBatch:
            return state._replace(
                active=state.active & ~mask,
                caches=model_lib.reset_cache_rows(state.caches, mask))

        return evict

    # -- host-side API -------------------------------------------------------

    def free_slots(self) -> List[int]:
        active = np.asarray(self.state.active)
        return [i for i in range(self.ecfg.num_slots) if not active[i]]

    def has_active(self) -> bool:
        return bool(np.any(np.asarray(self.state.active)))

    def admit(self, req: Request, *, now: Optional[float] = None) -> int:
        """Admit a request into a free slot; returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot — poll step()/harvest first")
        p = len(req.prompt)
        if not 0 < p <= self.ecfg.max_prompt_len:
            raise ValueError(
                f"prompt length {p} outside (0, {self.ecfg.max_prompt_len}]")
        slot = free[0]
        prompt = np.zeros((self.ecfg.max_prompt_len,), np.int32)
        prompt[:p] = req.prompt
        max_new = int(np.clip(req.max_new, 1, self.ecfg.max_new_cap))
        self.state = self._admit_fn(
            self.params, self.state, jnp.asarray(slot, I32),
            jnp.asarray(prompt), jnp.asarray(p, I32),
            jnp.asarray(max_new, I32))
        self.num_admits += 1
        admit_time = time.monotonic() if now is None else now
        if req.arrival is None:
            req.arrival = admit_time
        self.slot_meta[slot] = {
            "req": req, "prompt_len": p, "max_new": max_new,
            "admit_time": admit_time,
        }
        return slot

    def step(self, *, now: Optional[float] = None) -> List[FinishedRequest]:
        """One BPD iteration over all active slots, then harvest+evict."""
        self.num_steps += 1
        self.state = self._step_fn(self.params, self.state)
        return self.harvest(now=now)

    def harvest(self, *, now: Optional[float] = None) -> List[FinishedRequest]:
        """Retire finished slots: copy outputs out, free the slots."""
        done_mask = np.asarray(self.state.active & self.state.finished)
        if not done_mask.any():
            return []
        t = time.monotonic() if now is None else now
        tokens = np.asarray(self.state.tokens)
        text_len = np.asarray(self.state.text_len)
        generated = np.asarray(self.state.generated)
        invocations = np.asarray(self.state.invocations)
        out = []
        for i in np.nonzero(done_mask)[0]:
            meta = self.slot_meta[i]
            req: Request = meta["req"]
            p = meta["prompt_len"]
            iters = max(int(invocations[i]) - 1, 1)  # minus the prefill call
            out.append(FinishedRequest(
                rid=req.rid, prompt_len=p,
                tokens=tokens[i, p:int(text_len[i])].copy(),
                generated=int(generated[i]),
                invocations=int(invocations[i]),
                mean_accepted=float(generated[i]) / iters,
                arrival=req.arrival, admit_time=meta["admit_time"],
                finish_time=t))
            self.slot_meta[i] = None
        self.state = self._evict_fn(self.state, jnp.asarray(done_mask))
        return out

    # -- diagnostics ---------------------------------------------------------

    def compile_counts(self) -> dict:
        """jit cache sizes — the recompilation regression guard.  Each entry
        must be ≤ 1 after any amount of traffic (static shapes by design)."""
        return {
            "admit": self._admit_fn._cache_size(),
            "step": self._step_fn._cache_size(),
            "evict": self._evict_fn._cache_size(),
        }
