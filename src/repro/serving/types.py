"""Request/response and configuration types for the continuous-batching
BPD serving engine, plus the device-side ``SlotBatch`` state.

A ``Request`` is one decode job (prompt + generation budget).  The engine
holds ``EngineConfig.num_slots`` requests in flight at once; finished slots
are evicted and refilled from the scheduler queue without recompiling
(static batch shape, per-slot active mask).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, NamedTuple, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - annotations only
    import jax.numpy as jnp


class SlotBatch(NamedTuple):
    """Device-side state: ``BPDState`` generalized to reusable slots.

    The slot dimension IS the decode batch dimension — under a mesh it
    shards over the data axes (``sharding.policy.slot_specs``) exactly like
    a static decode batch, and admission/eviction stay slot-local scatters.

    With per-request decode policies the engine's slot slab is partitioned
    into per-policy *slot groups*; each group's ``SlotBatch`` is the
    group-local view of the slab (its ``group`` field records which group
    the rows belong to), stepped by that group's own compiled functions.
    """

    tokens: "jnp.ndarray"      # (S, buf) per-slot prompt+output buffer
    text_len: "jnp.ndarray"    # (S,) valid tokens in the buffer
    prompt_len: "jnp.ndarray"  # (S,) prompt portion of text_len
    proposals: "jnp.ndarray"   # (S, k) next-block proposals
    caches: Any                # per-layer cache pytree (batch dim = S)
    active: "jnp.ndarray"      # (S,) bool — slot holds a live request
    finished: "jnp.ndarray"    # (S,) bool — request hit EOS / budget
    generated: "jnp.ndarray"   # (S,) accepted tokens so far
    max_new: "jnp.ndarray"     # (S,) per-slot generation budget
    invocations: "jnp.ndarray" # (S,) model calls spent on this request
    policy_state: Any = ()     # per-slot DecodePolicy state (batch-leading
                               # leaves; reset on admit/evict)
    group: Any = ()            # (S,) int32 policy slot-group id: stamps
                               # every device-side state dump with the
                               # group that owns it (asserted in the
                               # equivalence tests), and is the routing
                               # key a future policy-batched step would
                               # switch on device-side


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static shapes of the serving engine (fixed at compile time)."""

    num_slots: int = 4          # concurrent requests in the device batch
    max_prompt_len: int = 32    # prompts are padded to this for admission
    max_new_cap: int = 64       # hard per-request generation budget
    page_pool_pages: int = 0    # paged backend: physical pages in the pool
                                # (incl. the trash page); 0 = auto worst
                                # case (1 + num_slots * pages_per_slot)
    prefill_slots: int = 0      # disaggregated prefill/decode: width of one
                                # prefill-worker batch (prompts prefilled
                                # per forward, handed to decode groups via
                                # the KV-handoff queue); 0 = unified engine
                                # (admission prefills inline, the
                                # historical path)
    handoff_cap: int = 0        # bound on requests staged for / parked in
                                # the KV-handoff queue (back-pressure once
                                # full); 0 = auto (max(2 * num_slots,
                                # prefill_slots))
    steps_per_sync: int = 1     # fused decode iterations per step()
                                # dispatch: >1 runs up to this many masked
                                # iterations in ONE device call (a bounded
                                # while_loop over the same traced step
                                # body, so tokens are identical by
                                # construction) that exits early the
                                # moment any row finishes — slot refill
                                # timing is unchanged, only arrival
                                # admission is delayed by at most
                                # steps_per_sync - 1 iterations.  Trades
                                # bounded admission staleness for
                                # static-batching dispatch economy; 1 =
                                # one iteration per sync (the historical
                                # path)

    def validate(self, dec=None, mesh=None) -> None:
        """Fail construction-time with a clear message instead of a
        downstream shape/trace error.

        dec  : optional DecodeConfig — ``max_new_cap`` must fit inside its
               ``max_new_tokens`` loop bound; its ``cache_backend`` /
               ``page_size`` gate the page-pool geometry checks.
        mesh : optional jax Mesh — the slot batch shards over the data
               axes, so ``num_slots`` must split evenly across them.
        """
        if self.num_slots <= 0:
            raise ValueError(
                f"EngineConfig.num_slots must be positive, got "
                f"{self.num_slots}")
        if self.max_prompt_len <= 0:
            raise ValueError(
                f"EngineConfig.max_prompt_len must be positive, got "
                f"{self.max_prompt_len}")
        if self.max_new_cap <= 0:
            raise ValueError(
                f"EngineConfig.max_new_cap must be positive, got "
                f"{self.max_new_cap}")
        if self.prefill_slots < 0:
            raise ValueError(
                f"EngineConfig.prefill_slots must be >= 0, got "
                f"{self.prefill_slots} (0 = unified engine)")
        if self.handoff_cap < 0:
            raise ValueError(
                f"EngineConfig.handoff_cap must be >= 0, got "
                f"{self.handoff_cap} (0 = auto)")
        if self.steps_per_sync < 1:
            raise ValueError(
                f"EngineConfig.steps_per_sync must be >= 1, got "
                f"{self.steps_per_sync}")
        if (self.prefill_slots > 0 and self.handoff_cap > 0
                and self.handoff_cap < self.prefill_slots):
            raise ValueError(
                f"EngineConfig.handoff_cap={self.handoff_cap} is smaller "
                f"than one prefill batch (prefill_slots="
                f"{self.prefill_slots}): the prefill worker could never "
                f"fill a batch — raise the cap or shrink the width")
        if dec is not None and self.max_new_cap > dec.max_new_tokens:
            raise ValueError(
                f"EngineConfig.max_new_cap={self.max_new_cap} exceeds "
                f"DecodeConfig.max_new_tokens={dec.max_new_tokens}: the "
                f"decode loop bound would truncate requests below their "
                f"advertised budget")
        if dec is not None and getattr(dec, "cache_backend", "dense") == "paged":
            ps = dec.page_size
            if ps <= 0 or ps % 8 != 0:
                raise ValueError(
                    f"DecodeConfig.page_size={ps} must be a positive "
                    f"multiple of 8: KV pages tile the TPU sublane dim, and "
                    f"a non-multiple fragments every page scatter/gather")
            if self.page_pool_pages:
                # lower bound on pages one max-size request maps (the true
                # span adds the model prefix and decode block slack, which
                # the session knows; validation uses what it can see)
                per_slot = -(-(self.max_prompt_len + self.max_new_cap) // ps)
                if self.page_pool_pages < 1 + per_slot:
                    raise ValueError(
                        f"EngineConfig.page_pool_pages={self.page_pool_pages}"
                        f" cannot admit even one request: a max-size request "
                        f"maps >= ceil((max_prompt_len + max_new_cap) / "
                        f"page_size) = ceil(({self.max_prompt_len} + "
                        f"{self.max_new_cap}) / {ps}) = {per_slot} pages, "
                        f"plus the reserved trash page 0.  Raise "
                        f"page_pool_pages to at least {1 + per_slot} (or to "
                        f"1 + num_slots * pages_per_slot = "
                        f"{1 + self.num_slots * per_slot} to rule out "
                        f"admission back-pressure entirely; 0 auto-sizes to "
                        f"the worst case)")
        if mesh is not None:
            from repro.sharding.policy import batch_axes, data_axis_size

            # batch_axes is the single source of truth for how the slot
            # batch shards (it already falls back from pod×data to data
            # alone) — reject only configurations it cannot shard at all,
            # which would silently replicate the whole slot batch.
            dsz = data_axis_size(mesh)
            if dsz > 1 and batch_axes(mesh, self.num_slots) is None:
                raise ValueError(
                    f"EngineConfig.num_slots={self.num_slots} is not "
                    f"divisible by the mesh data axes (data-axis product "
                    f"{dsz}, mesh axes {dict(mesh.shape)}): the slot "
                    f"batch cannot shard and would be replicated — pick "
                    f"num_slots as a multiple of the data axis size")


@dataclasses.dataclass
class Request:
    """One decode job submitted to the scheduler.

    ``arrival`` is an absolute ``time.monotonic()`` instant; ``None`` means
    "now" — the scheduler (or engine, for direct admission) stamps it, so
    latency = finish - arrival is always well-defined.

    ``policy`` names the registered decode policy this request wants
    (resolved through ``config.registry``); ``None`` means the engine's
    session default.  The engine serves a request from the slot group
    running its policy, so only policies the engine was configured with
    are admissible.

    ``src`` optionally carries source tokens for source-drafting policies
    (``input_copy``); ``None`` defaults to the prompt itself at admission.
    Drafts never change accepted tokens under exact acceptance, so ``src``
    only moves iteration counts.

    ``priority`` orders admission (higher = served first within a group);
    ``deadline`` is an absolute ``time.monotonic()`` instant by which the
    request should FINISH.  A queued request whose deadline is at risk may
    preempt a strictly-lower-priority slot in its group (the victim is
    evicted and requeued as a continuation — see ``serving.scheduler``).
    Both default to best-effort (priority 0, no deadline), which preserves
    the historical fcfs/sjf behavior exactly.
    """

    rid: int
    prompt: np.ndarray          # (P,) int32 token ids, P <= max_prompt_len
    max_new: int                # requested tokens, clamped to max_new_cap
    arrival: Optional[float] = None
    policy: Optional[str] = None  # registered policy name; None = default
    src: Optional[np.ndarray] = None  # source tokens for drafting policies
    priority: int = 0           # admission priority (higher wins)
    deadline: Optional[float] = None  # absolute finish deadline (monotonic)
    backpressured: int = 0      # times requeued by PagePoolExhausted

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.src is not None:
            self.src = np.asarray(self.src, np.int32).reshape(-1)


@dataclasses.dataclass
class FinishedRequest:
    """A retired request with its serving statistics."""

    rid: int
    prompt_len: int
    tokens: np.ndarray          # generated tokens only (no prompt)
    generated: int              # accepted tokens
    invocations: int            # model calls spent (prefill + iterations)
    mean_accepted: float        # k̂ for this request (generated / iterations)
    arrival: float
    admit_time: float
    finish_time: float
    policy: str = ""            # decode policy that served this request
    preempted: int = 0          # times this request was evicted + requeued

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival

    @property
    def queue_delay(self) -> float:
        return self.admit_time - self.arrival


@dataclasses.dataclass
class PreemptedRequest:
    """A mid-flight request evicted from its slot by the scheduler.

    ``tokens`` are the committed tokens of the evicted SEGMENT only (the
    continuation re-admits with ``prompt + tokens`` as its prompt, so the
    decode stream continues exactly where it stopped); ``streamed`` counts
    how many of them the engine's progress polling already emitted, so a
    streaming front end can forward the unstreamed remainder before the
    continuation produces new tokens.
    """

    req: "Request"              # the evicted request (original fields)
    tokens: np.ndarray          # committed tokens of this segment
    generated: int              # == len(tokens)
    invocations: int            # model calls spent on this segment
    streamed: int               # tokens of this segment already streamed


def percentile(values, q: float) -> Optional[float]:
    if not values:
        return None
    return float(np.percentile(np.asarray(values, np.float64), q))
