"""Request/response and configuration types for the continuous-batching
BPD serving engine.

A ``Request`` is one decode job (prompt + generation budget).  The engine
holds ``EngineConfig.num_slots`` requests in flight at once; finished slots
are evicted and refilled from the scheduler queue without recompiling
(static batch shape, per-slot active mask).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static shapes of the serving engine (fixed at compile time)."""

    num_slots: int = 4          # concurrent requests in the device batch
    max_prompt_len: int = 32    # prompts are padded to this for admission
    max_new_cap: int = 64       # hard per-request generation budget


@dataclasses.dataclass
class Request:
    """One decode job submitted to the scheduler.

    ``arrival`` is an absolute ``time.monotonic()`` instant; ``None`` means
    "now" — the scheduler (or engine, for direct admission) stamps it, so
    latency = finish - arrival is always well-defined.
    """

    rid: int
    prompt: np.ndarray          # (P,) int32 token ids, P <= max_prompt_len
    max_new: int                # requested tokens, clamped to max_new_cap
    arrival: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)


@dataclasses.dataclass
class FinishedRequest:
    """A retired request with its serving statistics."""

    rid: int
    prompt_len: int
    tokens: np.ndarray          # generated tokens only (no prompt)
    generated: int              # accepted tokens
    invocations: int            # model calls spent (prefill + iterations)
    mean_accepted: float        # k̂ for this request (generated / iterations)
    arrival: float
    admit_time: float
    finish_time: float

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival

    @property
    def queue_delay(self) -> float:
        return self.admit_time - self.arrival


def percentile(values, q: float) -> Optional[float]:
    if not values:
        return None
    return float(np.percentile(np.asarray(values, np.float64), q))
