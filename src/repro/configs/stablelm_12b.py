"""stablelm-12b [dense] — GQA with per-head QK norm.
[hf:stabilityai/stablelm-2-1_6b scaled per assignment]"""
from repro.config import ModelConfig, register

NAME = "stablelm-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab_size=100352,
        activation="silu",
        qk_norm=True,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=256,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
