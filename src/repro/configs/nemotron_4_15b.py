"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP, 256k vocabulary (the
biggest beneficiary of the logits-free fused-heads kernel).
[arXiv:2402.16819]"""
from repro.config import ModelConfig, register

NAME = "nemotron-4-15b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="dense",
        source="arXiv:2402.16819",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        activation="relu2",    # squared ReLU, non-gated
        norm_type="layernorm",
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
