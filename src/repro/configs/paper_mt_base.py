"""paper-mt-base — the paper's own setting: a transformer_base-shaped
encoder-decoder for machine translation (Vaswani et al. 2017 hyperparameters)
with the combined scoring/proposal head of §4/§6 on the decoder.
"""
from repro.config import ModelConfig, register

NAME = "paper-mt-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="seq2seq",
        source="Stern et al. 2018 §7.1 (transformer_base)",
        num_layers=6,
        num_encoder_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=32000,
        activation="relu",
        norm_type="layernorm",
        is_encoder_decoder=True,
        bpd_k=8,
        bpd_hidden=2048,  # paper §6: hidden size k × d_hidden with d_hidden = d_ff/k... uses d_ff scale
        max_seq_len=4096,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        num_encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=64,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
