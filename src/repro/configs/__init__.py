"""Assigned architecture configs (``--arch <id>``).  Importing this package
populates the registry."""
from repro.configs import (  # noqa: F401
    granite_3_8b,
    hubert_xlarge,
    hymba_1_5b,
    llava_next_34b,
    nemotron_4_15b,
    olmoe_1b_7b,
    paper_mt_base,
    qwen2_moe_a2_7b,
    rwkv6_1_6b,
    stablelm_12b,
    starcoder2_7b,
)

ASSIGNED = [
    "hymba-1.5b",
    "llava-next-34b",
    "qwen2-moe-a2.7b",
    "stablelm-12b",
    "rwkv6-1.6b",
    "starcoder2-7b",
    "hubert-xlarge",
    "nemotron-4-15b",
    "olmoe-1b-7b",
    "granite-3-8b",
]
