"""granite-3-8b [dense] — GQA, tied embeddings.
[hf:ibm-granite/granite-3.0-2b-base scaled per assignment]"""
from repro.config import ModelConfig, register

NAME = "granite-3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="dense",
        source="hf:ibm-granite/granite-3.0-2b-base",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49155,
        activation="silu",
        tie_embeddings=True,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=256,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
