"""llava-next-34b [vlm] — language backbone only; the SigLIP/ViT vision tower
and projector are stubbed per the brief: ``input_specs`` provides anyres
patch embeddings of the right shape.
[hf:llava-hf/llava-v1.6-mistral-7b-hf scaled per assignment]"""
from repro.config import ModelConfig, register

NAME = "llava-next-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        activation="silu",
        rope_theta=5_000_000.0,
        modality="vision_text",
        num_patch_tokens=2880,  # anyres: 4 tiles + base, 576 patches each
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=256,
        num_patch_tokens=16,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
