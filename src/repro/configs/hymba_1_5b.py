"""hymba-1.5b [hybrid] — parallel attention + mamba heads within each layer,
sliding-window attention with 3 global layers, 128 learnable meta tokens.
[arXiv:2411.13676]"""
from repro.config import ModelConfig, register

NAME = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="hybrid",
        source="arXiv:2411.13676",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        block_type="hymba",
        mlp_type="dense",
        activation="silu",
        sliding_window=1024,
        global_attn_layers=(0, 15, 31),
        ssm_state_dim=16,
        ssm_expand=2,
        num_meta_tokens=128,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=160,
        num_heads=5,
        num_kv_heads=5,
        head_dim=32,
        d_ff=384,
        vocab_size=128,
        sliding_window=32,
        global_attn_layers=(0,),
        num_meta_tokens=4,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
