"""starcoder2-7b [dense] — GQA + RoPE with the model's native 4096-token
sliding window (long_500k runs on the native window).  [arXiv:2402.19173]"""
from repro.config import ModelConfig, register

NAME = "starcoder2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="dense",
        source="arXiv:2402.19173",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        activation="gelu",     # non-gated c_fc/c_proj MLP
        sliding_window=4096,
        rope_theta=100_000.0,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=256,
        sliding_window=32,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
