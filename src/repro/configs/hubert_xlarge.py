"""hubert-xlarge [audio] — encoder-only masked prediction over a 504-entry
codebook.  The conv/mel frontend is stubbed per the brief: ``input_specs``
provides frame embeddings.  No autoregressive decode exists, so BPD is
inapplicable (DESIGN.md §5) and decode shapes are skipped.
[arXiv:2106.07447]"""
from repro.config import ModelConfig, register

NAME = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="audio",
        source="arXiv:2106.07447",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        activation="gelu",
        norm_type="layernorm",
        is_encoder_only=True,
        modality="audio",
        bpd_enabled=False,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=64,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
