"""olmoe-1b-7b [moe] — 64 routed experts top-8, QK-norm, no shared experts.
[arXiv:2409.02060]"""
from repro.config import ModelConfig, register

NAME = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="moe",
        source="arXiv:2409.02060",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,             # per-expert width
        vocab_size=50304,
        mlp_type="moe",
        activation="silu",
        qk_norm=True,
        num_experts=64,
        num_experts_per_tok=8,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=64,
        vocab_size=256,
        num_experts=4,
        num_experts_per_tok=2,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
