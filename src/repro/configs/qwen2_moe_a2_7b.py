"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts with a
sigmoid gate (shared width 4×1408 = 5632).  [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.config import ModelConfig, register

NAME = "qwen2-moe-a2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,             # per-expert width
        vocab_size=151936,
        mlp_type="moe",
        activation="silu",
        rope_theta=1_000_000.0,
        num_experts=60,
        expert_pad_multiple=16,   # 60 -> 64 lanes: shards over model=16
        num_experts_per_tok=4,
        num_shared_experts=4,
        shared_expert_d_ff=5632,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=96,
        vocab_size=256,
        num_experts=4,
        num_experts_per_tok=2,
        expert_pad_multiple=1,
        num_shared_experts=1,
        shared_expert_d_ff=96,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
