"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent per-channel decay,
O(1) recurrent state (native sub-quadratic long_500k).  [arXiv:2404.05892]"""
from repro.config import ModelConfig, register

NAME = "rwkv6-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME,
        family="ssm",
        source="arXiv:2404.05892",
        num_layers=24,
        d_model=2048,
        num_heads=0,           # attention-free
        num_kv_heads=0,
        d_ff=7168,
        vocab_size=65536,
        block_type="rwkv6",
        mlp_type="rwkv_channel_mix",
        rwkv_head_dim=64,
        bpd_k=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2,
        d_model=128,
        d_ff=256,
        vocab_size=256,
        rwkv_head_dim=32,
        bpd_k=4,
        max_seq_len=256,
    )


register(NAME, config, smoke_config)
