"""RWKV-6 ("Finch", arXiv:2404.05892) — attention-free block with
data-dependent per-channel decay.

Structure per block (faithful to the reference implementation, with the
low-rank data-dependent mixing of the five time-mix components):

  time-mix:   token-shift ddlerp -> r,k,v,g projections, decay
              w_t = exp(-exp(w0 + lora_w(x_w))); per-head state
              S_t = diag(w_t) S_{t-1} + k_t^T v_t;
              y_t = r_t · (S_{t-1} + diag(u) k_t^T v_t);  GroupNorm, gate g.
  channel-mix: token-shift lerp; k = relu(x_k W_k)^2; y = sigmoid(x_r W_r) ⊙ (k W_v)

The sequential ``wkv`` recurrence here is the pure-jnp oracle (lax.scan);
``repro.kernels.rwkv6_scan`` provides the TPU Pallas version that keeps the
(H, D, D) state resident in VMEM across the scan.

Decode-time API returns *per-step* states so blockwise parallel decoding can
roll the recurrent state back to the accepted prefix (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, group_norm_apply

LORA_MIX_RANK = 32
LORA_DECAY_RANK = 64
MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv_tm_init(key, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 12)
    p = {
        # token-shift interpolation anchors
        "mu_x": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((5, d), dtype),
        # data-dependent mix lora: tanh(xxx @ A) (5 heads) @ B
        "mix_A": jax.random.normal(ks[0], (d, 5 * LORA_MIX_RANK), dtype) * 1e-2,
        "mix_B": jax.random.normal(ks[1], (5, LORA_MIX_RANK, d), dtype) * 1e-2,
        # projections
        "wr": dense_init(ks[2], d, d, dtype=dtype)["w"],
        "wk": dense_init(ks[3], d, d, dtype=dtype)["w"],
        "wv": dense_init(ks[4], d, d, dtype=dtype)["w"],
        "wg": dense_init(ks[5], d, d, dtype=dtype)["w"],
        "wo": dense_init(ks[6], d, d, dtype=dtype)["w"],
        # decay: w0 + tanh(x_w @ dA) @ dB
        "w0": jnp.full((d,), -4.0, dtype),  # exp(-exp(-4)) ~ slow decay init
        "decay_A": jax.random.normal(ks[7], (d, LORA_DECAY_RANK), dtype) * 1e-2,
        "decay_B": jax.random.normal(ks[8], (LORA_DECAY_RANK, d), dtype) * 1e-2,
        # per-head bonus u ("time_faaaa")
        "u": jax.random.normal(ks[9], (h, hd), dtype) * 0.1,
        "ln_x": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift mixing -> (xw, xk, xv, xr, xg)."""
    sx = x_prev - x
    xxx = x + sx * p["mu_x"].astype(x.dtype)
    b, s, d = x.shape
    low = jnp.tanh(xxx @ p["mix_A"].astype(x.dtype))          # (B,S,5r)
    low = low.reshape(b, s, 5, LORA_MIX_RANK)
    delta = jnp.einsum("bsnr,nrd->bsnd", low, p["mix_B"].astype(x.dtype))
    mixed = []
    for i in range(5):
        mu_i = p["mu"][i].astype(x.dtype) + delta[:, :, i]
        mixed.append(x + sx * mu_i)
    return tuple(mixed)


def _wkv_step(uf):
    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,D) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        yt = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, yt

    return step


def _wkv_scan(r, k, v, w, u, state0, *, return_states: bool = False,
              chunk: int = 128):
    """Sequential wkv recurrence (pure-jnp oracle).

    r,k,v,w: (B,S,H,D); u: (H,D); state0: (B,H,D,D) f32.

    return_states=True (decode path, S == block_k, small): additionally
    returns the per-step states (B,S,H,D,D) so BPD can roll back to the
    accepted prefix.

    return_states=False (training): scan-of-chunks with jax.checkpoint so the
    backward pass stores only one (B,H,D,D) state per chunk boundary instead
    of one per timestep.
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    step = _wkv_step(uf)

    if return_states:
        def step_with_state(S, inp):
            S_new, yt = step(S, inp)
            return S_new, (yt, S_new)

        xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
        _, (ys, states) = jax.lax.scan(step_with_state, state0, xs)
        return ys.transpose(1, 0, 2, 3), states.transpose(1, 0, 2, 3, 4)

    b, s, h, d = rf.shape
    c = min(chunk, s)
    nchunks = (s + c - 1) // c
    pad = nchunks * c - s
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rf, kf, vf = zeros(rf), zeros(kf), zeros(vf)
        wf = jnp.pad(wf, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)

    def chunk_body(S, inp):
        # inp: (C, B, H, D) x4
        S_new, ys = jax.lax.scan(step, S, inp)
        return S_new, ys

    chunk_body = jax.checkpoint(chunk_body)
    xs = tuple(
        t.transpose(1, 0, 2, 3).reshape(nchunks, c, b, h, d)
        for t in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(chunk_body, state0, xs)
    ys = ys.reshape(nchunks * c, b, h, d)[:s]
    return ys.transpose(1, 0, 2, 3), final[None].transpose(1, 0, 2, 3, 4)


def rwkv_tm_apply(p, cfg: ModelConfig, x, *, x_prev=None, state0=None,
                  return_states: bool = False):
    """Time-mix forward.

    x       : (B, S, d)
    x_prev  : (B, d) last token of the preceding context (token shift), zeros
              at sequence start.
    state0  : (B, H, D, D) initial wkv state (zeros at sequence start).
    Returns (y, aux) where aux = {"x_last": (B,d), "state": final or
    per-step states if return_states}.
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    if state0 is None:
        state0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, shifted)

    r = (xr @ p["wr"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(b, s, h, hd)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))

    ww = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ p["decay_A"].astype(jnp.float32))
        @ p["decay_B"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(ww)).reshape(b, s, h, hd)

    y, states = _wkv_scan(r, k, v, w, p["u"], state0,
                          return_states=return_states)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = group_norm_apply(p["ln_x"], y, h)
    y = (y * g) @ p["wo"].astype(x.dtype)

    aux = {"x_last": x[:, -1, :],
           "state": states if return_states else states[:, -1]}
    return y, aux


# ---------------------------------------------------------------------------
# Channel mix
# ---------------------------------------------------------------------------


def rwkv_cm_init(key, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype),
        "mu_r": jnp.zeros((d,), dtype),
        "wk": dense_init(ks[0], d, ff, dtype=dtype)["w"],
        "wv": dense_init(ks[1], ff, d, dtype=dtype)["w"],
        "wr": dense_init(ks[2], d, d, dtype=dtype)["w"],
    }


def rwkv_cm_apply(p, cfg: ModelConfig, x, *, x_prev=None):
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x
    xk = x + sx * p["mu_k"].astype(x.dtype)
    xr = x + sx * p["mu_r"].astype(x.dtype)
    kk = jax.nn.relu(xk @ p["wk"].astype(x.dtype))
    kk = kk * kk
    vv = kk @ p["wv"].astype(x.dtype)
    rr = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype))
    return rr * vv, {"x_last": x[:, -1, :]}
