"""GQA attention with RoPE, sliding windows, and BPD-aware KV caching.

Three entry points:
  * ``attn_full``    — parallel forward over a whole sequence (training /
                       prefill / encoder).  Optionally returns post-RoPE K/V
                       so prefill can populate the cache.
  * ``attn_cached``  — scores a block of ``k`` fresh tokens against the KV
                       cache *and* each other (the paper's verify substep).
  * ``cross_attn``   — encoder-decoder cross attention (paper's MT setting).

Masking is computed from absolute positions so the blockwise-parallel-decode
rollback ("length decreases by up to k-1") needs no data movement.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, norm_apply, norm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, *, dtype=jnp.float32, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype=dtype)["w"].reshape(d, h, hd),
        "wk": dense_init(ks[1], d, kv * hd, dtype=dtype)["w"].reshape(d, kv, hd),
        "wv": dense_init(ks[2], d, kv * hd, dtype=dtype)["w"].reshape(d, kv, hd),
        "wo": dense_init(ks[3], h * hd, d, dtype=dtype)["w"].reshape(h, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, kind="rmsnorm", dtype=dtype)
        p["k_norm"] = norm_init(hd, kind="rmsnorm", dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def _project_qkv(p, cfg: ModelConfig, x, positions, *, rope: bool = True):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd); RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "q_norm" in p:
        q = norm_apply(p["q_norm"], q)
        k = norm_apply(p["k_norm"], k)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _out_proj(p, ctx):
    """ctx: (B, S, H, hd) -> (B, S, d)."""
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(ctx.dtype))


# ---------------------------------------------------------------------------
# Core scored attention (GQA without materializing repeated KV)
# ---------------------------------------------------------------------------


def _gqa_attend(q, k, v, mask, *, head_dim: int):
    """q: (B,Sq,H,hd)  k/v: (B,Sk,KV,hd)  mask: broadcastable to (B,Sq,Sk).

    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(head_dim))
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
    return ctx.reshape(b, sq, h, hd)


def make_causal_mask(q_pos, kv_pos, *, window: int = 0, num_meta: int = 0,
                     bidirectional: bool = False):
    """q_pos: (..., Sq), kv_pos: (..., Sk) absolute positions ->
    (..., Sq, Sk) bool.  Leading dims broadcast (per-row decode positions)."""
    q = q_pos[..., :, None]
    s = kv_pos[..., None, :]
    valid = s >= 0
    if bidirectional:
        m = valid & (q >= -1)  # broadcast q into the shape
    else:
        m = valid & (s <= q)
        if window:
            m = m & ((q - s < window) | (s < num_meta))
    return m


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill / encoder)
# ---------------------------------------------------------------------------


def attn_full(p, cfg: ModelConfig, x, *, layer_idx: int = 0, positions=None,
              bidirectional: bool = False, return_kv: bool = False,
              kv_chunk: int = 0):
    """Parallel attention over the full sequence.

    kv_chunk > 0 switches to a memory-bounded chunked (flash-style) softmax —
    used for long-context prefill where the (Sq, Sk) score matrix would not
    fit; this is also the jnp oracle for the Pallas block-attention kernel.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions, rope=not bidirectional)
    window = 0 if (bidirectional or layer_idx in cfg.global_attn_layers) else cfg.sliding_window
    if kv_chunk:
        ctx = _chunked_attend(q, k, v, positions, positions,
                              window=window, num_meta=cfg.num_meta_tokens,
                              bidirectional=bidirectional,
                              head_dim=cfg.resolved_head_dim, chunk=kv_chunk)
    else:
        mask = make_causal_mask(positions, positions, window=window,
                                num_meta=cfg.num_meta_tokens,
                                bidirectional=bidirectional)[None]
        ctx = _gqa_attend(q, k, v, mask, head_dim=cfg.resolved_head_dim)
    y = _out_proj(p, ctx)
    if return_kv:
        return y, (k, v)
    return y


def _chunked_attend(q, k, v, q_pos, kv_pos, *, window, num_meta, bidirectional,
                    head_dim, chunk):
    """Online-softmax attention, scanning KV in chunks of ``chunk``."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    q_pos = jnp.broadcast_to(q_pos, (b, sq))
    kv_pos = jnp.broadcast_to(kv_pos, (b, sk))
    qg = (q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
          / jnp.sqrt(jnp.float32(head_dim)))
    nchunks = (sk + chunk - 1) // chunk
    pad = nchunks * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pp = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = kp.reshape(b, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = pp.reshape(b, nchunks, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry  # (B,KV,G,Sq), (B,KV,G,Sq), (B,KV,G,Sq,hd)
        kb, vb, pb = inp
        scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, kb.astype(jnp.float32))
        mask = make_causal_mask(q_pos, pb, window=window, num_meta=num_meta,
                                bidirectional=bidirectional)  # (B, Sq, chunk)
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqs,bshk->bhgqk", pexp, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, kvh, g, sq), jnp.float32),
        jnp.zeros((b, kvh, g, sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pc))
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]
    ctx = ctx.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return ctx.astype(q.dtype)


# ---------------------------------------------------------------------------
# Cache plumbing
# ---------------------------------------------------------------------------


def _slot_for(pos, buf_len: int, num_reserved: int):
    """Ring-buffer slot assignment with reserved leading (meta-token) slots."""
    ring = buf_len - num_reserved
    wrapped = num_reserved + jnp.remainder(pos - num_reserved, ring)
    return jnp.where(pos < num_reserved, pos, wrapped).astype(jnp.int32)


def _reserved_slots(cfg: ModelConfig, layer_idx: int, buf_len: int) -> int:
    window = 0 if layer_idx in cfg.global_attn_layers else cfg.sliding_window
    return cfg.num_meta_tokens if window else 0


def _paged_cache_write(cache: Dict, k, v, positions) -> Dict:
    """Scatter K/V through the block table into the page pool.

    Paged layers are always full-attention (windowed layers stay dense), so
    the slot assignment is the identity: position p lives in logical page
    ``p // page_size``, offset ``p % page_size``, and the block table maps
    logical to physical pages per row.  Rows whose table entry is 0 (trash
    page — evicted slots, unmapped tail pages) write harmlessly into page 0;
    its contents are never visible because the corresponding ``pos`` lanes
    mask out of every attention.
    """
    kp, vp, tbl = cache["kp"], cache["vp"], cache["tbl"]
    num_pages, ps, kvh, hd = kp.shape
    b = tbl.shape[0]
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :],
                                     (b, positions.shape[0]))
    positions = positions.astype(jnp.int32)
    s = positions.shape[1]
    phys = tbl[jnp.arange(b)[:, None], positions // ps] * ps + positions % ps
    new = dict(cache)
    new["kp"] = kp.reshape(num_pages * ps, kvh, hd).at[phys.reshape(-1)].set(
        k.reshape(b * s, kvh, hd).astype(kp.dtype)).reshape(kp.shape)
    new["vp"] = vp.reshape(num_pages * ps, kvh, hd).at[phys.reshape(-1)].set(
        v.reshape(b * s, kvh, hd).astype(vp.dtype)).reshape(vp.shape)
    new["pos"] = jax.vmap(lambda buf, slot, val: buf.at[slot].set(val))(
        cache["pos"], positions, positions)
    return new


def cache_kv_view(cache: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The (B, L, KV, hd) K/V arrays attention scores against — a direct
    reference for dense layers, a page gather for paged layers (the jnp
    path; ``kernels/paged_attention.py`` streams pages instead on TPU)."""
    if "kp" in cache:
        kp, vp, tbl = cache["kp"], cache["vp"], cache["tbl"]
        _, ps, kvh, hd = kp.shape
        b, P = tbl.shape
        return (kp[tbl].reshape(b, P * ps, kvh, hd),
                vp[tbl].reshape(b, P * ps, kvh, hd))
    return cache["k"], cache["v"]


def cache_write(cache: Dict, cfg: ModelConfig, layer_idx: int, k, v, positions) -> Dict:
    """Scatter post-RoPE K/V for ``positions`` into the ring buffer (dense)
    or through the block table (paged).

    positions: (S,) shared across rows (prefill) or (B, S) per-row (decode).
    """
    if "kp" in cache:
        return _paged_cache_write(cache, k, v, positions)
    buf_len = cache["k"].shape[1]
    b = cache["k"].shape[0]
    nres = _reserved_slots(cfg, layer_idx, buf_len)

    if positions.ndim == 1:
        s = positions.shape[0]
        if s > buf_len:
            # prefill longer than the window: keep the reserved (meta) head
            # plus the last (buf_len - nres) positions — everything else
            # would be overwritten anyway, and slicing keeps scatter indices
            # unique.
            keep = buf_len - nres
            if nres:
                cache = cache_write(cache, cfg, layer_idx, k[:, :nres],
                                    v[:, :nres], positions[:nres])
            k, v, positions = k[:, -keep:], v[:, -keep:], positions[-keep:]
        slots = _slot_for(positions, buf_len, nres)
        new = dict(cache)
        new["k"] = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
        new["v"] = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
        new["pos"] = cache["pos"].at[:, slots].set(
            jnp.broadcast_to(positions.astype(jnp.int32), (b, positions.shape[0])))
        return new

    # per-row decode write: positions (B, S)
    slots = _slot_for(positions, buf_len, nres)                    # (B, S)

    def row_write(buf, slot, val):
        return buf.at[slot].set(val)

    new = dict(cache)
    new["k"] = jax.vmap(row_write)(cache["k"], slots, k.astype(cache["k"].dtype))
    new["v"] = jax.vmap(row_write)(cache["v"], slots, v.astype(cache["v"].dtype))
    new["pos"] = jax.vmap(row_write)(cache["pos"], slots,
                                     positions.astype(jnp.int32))
    return new


def attn_cached(p, cfg: ModelConfig, x_block, cache: Dict, length, *,
                layer_idx: int = 0, kv_chunk: int = 0,
                tree=None) -> Tuple[jnp.ndarray, Dict]:
    """Verify-substep attention: ``k`` fresh tokens vs the cache and each other.

    x_block : (B, k, d) tokens at absolute positions length .. length+k-1
    length  : (B,) or () int32 — number of *accepted* tokens per row.  Cache
              entries with pos >= length+k are stale speculative writes from
              rows that advanced differently and are masked out; entries in
              [length, length+k) are overwritten by this call's own write.
    tree    : optional ``kernels.tree_mask.TreeTopology`` — the block is a
              draft *tree* of ``k`` nodes instead of a chain.  Node n still
              writes its KV at storage position ``length + n`` (so the
              cache layout, slot math, and rollback masking are unchanged),
              but RoPE runs at the node's *logical* position
              ``length + depth[n]`` and the intra-block mask columns are
              overridden with the static ancestor matrix, so each node
              attends exactly to its root-to-node chain plus the committed
              cache.  After acceptance ``tree_commit_attn`` compacts the
              chosen root-to-leaf path back into chain slots.
    """
    b, kblk, _ = x_block.shape
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    positions = length[:, None] + jnp.arange(kblk, dtype=jnp.int32)[None, :]
    if tree is None:
        rope_pos = positions
    else:
        if kv_chunk:
            raise ValueError(
                "tree verification is incompatible with kv_chunk (chunked "
                "attention has no per-column mask override); use the dense "
                "mask path for tree-drafted decode")
        if tree.num_nodes != kblk:
            raise ValueError(
                f"tree topology has {tree.num_nodes} nodes but the block "
                f"has {kblk} slots")
        depth = jnp.asarray(tree.depths)
        rope_pos = length[:, None] + depth[None, :]
    q, k, v = _project_qkv(p, cfg, x_block, rope_pos)
    cache = cache_write(cache, cfg, layer_idx, k, v, positions)
    window = 0 if layer_idx in cfg.global_attn_layers else cfg.sliding_window
    kv_pos = cache["pos"]                                          # (B, L)
    kv_pos = jnp.where(kv_pos < (length + kblk)[:, None], kv_pos, -1)
    ck, cv = cache_kv_view(cache)
    if kv_chunk:
        ctx = _chunked_attend(q, ck, cv, positions, kv_pos,
                              window=window, num_meta=cfg.num_meta_tokens,
                              bidirectional=False,
                              head_dim=cfg.resolved_head_dim, chunk=kv_chunk)
    else:
        mask = make_causal_mask(rope_pos, kv_pos, window=window,
                                num_meta=cfg.num_meta_tokens)       # (B, k, L)
        if tree is not None:
            # this block's entries sit at KV-view columns == their storage
            # slots; override those columns with ancestor ∧ window masking
            # computed on the nodes' logical positions
            intra = (jnp.asarray(tree.anc_matrix)[None]
                     & make_causal_mask(rope_pos, rope_pos, window=window,
                                        num_meta=cfg.num_meta_tokens))
            if "kp" in cache:
                cols = positions           # paged view column == position
            else:
                buf_len = cache["k"].shape[1]
                nres = _reserved_slots(cfg, layer_idx, buf_len)
                cols = _slot_for(positions, buf_len, nres)
            mask = jax.vmap(lambda m, s, iv: m.at[:, s].set(iv))(
                mask, cols, intra)
        ctx = _gqa_attend(q, ck, cv, mask,
                          head_dim=cfg.resolved_head_dim)
    return _out_proj(p, ctx), cache


def tree_commit_attn(cache: Dict, cfg: ModelConfig, layer_idx: int,
                     path_nodes, khat, length, block_k: int) -> Dict:
    """Compact an accepted root-to-leaf tree path into chain slots.

    After a tree verify forward, the KV for the token committed at position
    ``length + j`` lives at storage position ``length + path_nodes[:, j]``
    (the path's node at depth j — its RoPE position is already correct,
    since depth[path_nodes[:, j]] == j).  This gathers those entries and
    rewrites the leading ``khat`` chain slots so subsequent iterations see
    an ordinary committed chain; slots at j >= k̂ keep their speculative
    entries, which the next block overwrites exactly like chain decode.

    path_nodes : (B, k) int32 — node id at depth j (< 0 beyond the path)
    khat       : (B,) int32 accepted tokens; 0 = frozen row (no writes)
    length     : (B,) or () int32 pre-accept lengths (the block's base)
    """
    b = path_nodes.shape[0]
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    j = jnp.arange(block_k, dtype=jnp.int32)[None, :]
    src_pos = length[:, None] + jnp.clip(path_nodes, 0, block_k - 1)
    dst_pos = length[:, None] + j
    keep = (j < khat[:, None]) & (jnp.clip(path_nodes, 0, block_k - 1) != j)
    new = dict(cache)
    if "kp" in cache:
        kp, vp, tbl = cache["kp"], cache["vp"], cache["tbl"]
        num_pages, ps, kvh, hd = kp.shape
        rows = jnp.arange(b)[:, None]
        phys_src = tbl[rows, src_pos // ps] * ps + src_pos % ps
        phys_dst = tbl[rows, dst_pos // ps] * ps + dst_pos % ps
        kf = kp.reshape(num_pages * ps, kvh, hd)
        vf = vp.reshape(num_pages * ps, kvh, hd)
        m = keep.reshape(-1)[:, None, None]
        kvals = jnp.where(m, kf[phys_src.reshape(-1)], kf[phys_dst.reshape(-1)])
        vvals = jnp.where(m, vf[phys_src.reshape(-1)], vf[phys_dst.reshape(-1)])
        new["kp"] = kf.at[phys_dst.reshape(-1)].set(kvals).reshape(kp.shape)
        new["vp"] = vf.at[phys_dst.reshape(-1)].set(vvals).reshape(vp.shape)
        return new
    buf_len = cache["k"].shape[1]
    nres = _reserved_slots(cfg, layer_idx, buf_len)
    sslot = _slot_for(src_pos, buf_len, nres)
    dslot = _slot_for(dst_pos, buf_len, nres)

    def row(buf, ss, ds, m):
        vals = jnp.where(m[:, None, None], buf[ss], buf[ds])
        return buf.at[ds].set(vals)

    new["k"] = jax.vmap(row)(cache["k"], sslot, dslot, keep)
    new["v"] = jax.vmap(row)(cache["v"], sslot, dslot, keep)
    return new


# ---------------------------------------------------------------------------
# Cross attention (paper's encoder-decoder MT setting)
# ---------------------------------------------------------------------------


def cross_attn_init(key, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict:
    return attn_init(key, cfg, dtype=dtype, cross=True)


def cross_attn_apply(p, cfg: ModelConfig, x, enc_kv, enc_mask=None):
    """x: (B, Sq, d); enc_kv: (k, v) each (B, Se, KV, hd) precomputed."""
    b, sq, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "q_norm" in p:
        q = norm_apply(p["q_norm"], q)
    k, v = enc_kv
    se = k.shape[1]
    if enc_mask is None:
        mask = jnp.ones((1, sq, se), bool)
    else:
        mask = enc_mask[:, None, :]
    ctx = _gqa_attend(q, k, v, mask, head_dim=cfg.resolved_head_dim)
    return _out_proj(p, ctx)


def cross_kv(p, cfg: ModelConfig, enc_out):
    """Precompute encoder K/V once per sequence (no RoPE across modalities)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if "k_norm" in p:
        k = norm_apply(p["k_norm"], k)
    return k, v
