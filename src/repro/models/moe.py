"""Mixture-of-Experts MLP with capacity-bounded top-k routing.

Dispatch uses a sort-based rank computation plus scatter/gather (MaxText /
MegaBlocks style) rather than the classic one-hot einsum: the einsum
formulation is O(T·E·C) memory, which at train_4k scale (1M tokens, 60
experts) is petabytes; the scatter formulation is O(T·K·d).  Under ``pjit``
with experts sharded over the ``model`` mesh axis GSPMD lowers the
scatter/gather across the expert dim to all-to-all-style collectives.

Covers both assigned MoE architectures:
  * qwen2-moe-a2.7b: 60 routed experts top-4 + 4 shared experts (sigmoid gate)
  * olmoe-1b-7b:     64 routed experts top-8, no shared experts
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import GATED_ACTIVATIONS, activation, dense_apply, dense_init
from repro.sharding.policy import maybe_shard_expert


def moe_init(key, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ep = cfg.padded_num_experts      # expert weights padded so E shards
    ks = jax.random.split(key, 6)
    std = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": dense_init(ks[0], d, e, dtype=dtype),
        "w1": jax.random.normal(ks[1], (ep, d, ff), dtype) * std,
        "w2": jax.random.normal(ks[2], (ep, ff, d), dtype) * (1.0 / jnp.sqrt(ff)),
    }
    if cfg.activation in GATED_ACTIVATIONS:
        p["w3"] = jax.random.normal(ks[3], (ep, d, ff), dtype) * std
    if cfg.num_shared_experts:
        sff = cfg.shared_expert_d_ff or cfg.num_shared_experts * ff
        p["shared"] = {
            "w1": dense_init(ks[4], d, sff, dtype=dtype),
            "w3": dense_init(ks[5], d, sff, dtype=dtype),
            "w2": dense_init(jax.random.fold_in(key, 7), sff, d, dtype=dtype),
            "gate": dense_init(jax.random.fold_in(key, 8), d, 1, dtype=dtype),
        }
    return p


def _expert_ffn(p, x, act: str):
    """x: (B, E, C, d) -> (B, E, C, d), per-expert weights."""
    h = jnp.einsum("becd,edf->becf", x, p["w1"].astype(x.dtype))
    if "w3" in p:
        h = activation("silu" if act == "geglu" else act, h) * jnp.einsum(
            "becd,edf->becf", x, p["w3"].astype(x.dtype))
    else:
        h = activation(act, h)
    return jnp.einsum("becf,efd->becd", h, p["w2"].astype(x.dtype))


def _assignment_ranks(expert_ids_flat: jnp.ndarray) -> jnp.ndarray:
    """rank[a] = #{a' < a : expert[a'] == expert[a]} without O(A·E) one-hots.

    Sort assignments by expert (stable), compute position-within-segment via a
    cummax of segment starts, scatter back to assignment order.
    """
    a = expert_ids_flat.shape[0]
    order = jnp.argsort(expert_ids_flat, stable=True)
    sorted_e = expert_ids_flat[order]
    idx = jnp.arange(a, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jnp.where(is_start, idx, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    rank_sorted = idx - seg_start
    return jnp.zeros((a,), jnp.int32).at[order].set(rank_sorted)


def moe_apply(p, cfg: ModelConfig, x, *, full_capacity: bool = False
              ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (y, metrics).

    GShard-style GROUPED dispatch: each batch row is a dispatch group with
    its own capacity, so the expert buffer is (B, Ep, Cg, d) — batch-sharded
    over `data`, expert-sharded over `model` — and the data→expert
    redistribution lowers to an all-to-all on those two dims instead of
    replicating the token array per expert shard (measured: 2.56 TB → GB-
    scale collectives at prefill_32k; EXPERIMENTS.md §Perf #3).

    full_capacity=True sets capacity so no token can ever be dropped — used
    on the decode path, where dropping would break the paper's greedy-
    equivalence guarantee for blockwise parallel decoding.
    """
    b, s, d = x.shape
    e, topk = cfg.num_experts, cfg.num_experts_per_tok
    ep = cfg.padded_num_experts

    logits = dense_apply(p["router"], x.astype(jnp.float32))         # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, topk)               # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)            # renorm

    if full_capacity:
        capacity = s  # a row's expert gets at most one slot per token
    else:
        capacity = int(max(1, cfg.capacity_factor * topk * s / e))
    capacity = min(capacity, s)

    def dispatch_row(xr, er):
        """xr: (S, d); er: (S, K) -> per-group expert buffer + slots."""
        rank = _assignment_ranks(er.reshape(s * topk)).reshape(s, topk)
        keep = rank < capacity
        # destination in the (Ep*Cg) buffer; capacity overflow -> dump row
        slot = jnp.where(keep, er * capacity + rank, ep * capacity)
        xin = jnp.zeros((ep * capacity + 1, d), xr.dtype)
        xin = xin.at[slot.reshape(-1)].add(
            jnp.broadcast_to(xr[:, None, :], (s, topk, d)).reshape(-1, d))
        return xin[: ep * capacity].reshape(ep, capacity, d), slot, keep

    xin, slot, keep = jax.vmap(dispatch_row)(x, expert_ids)  # (B, Ep, Cg, d)
    xin = maybe_shard_expert(xin)

    xout = _expert_ffn(p, xin, cfg.activation)                # (B, Ep, Cg, d)
    xout = maybe_shard_expert(xout)

    def gather_row(xo, sl, gv, kp):
        flat = jnp.concatenate(
            [xo.reshape(ep * capacity, d), jnp.zeros((1, d), xo.dtype)], 0)
        g = jnp.take(flat, sl.reshape(-1), axis=0).reshape(s, topk, d)
        w = (gv * kp.astype(gv.dtype)).astype(xo.dtype)
        return jnp.einsum("skd,sk->sd", g, w)

    y = jax.vmap(gather_row)(xout, slot, gate_vals, keep)     # (B, S, d)

    if "shared" in p:
        sp = p["shared"]
        h = activation("silu", dense_apply(sp["w1"], x)) * dense_apply(sp["w3"], x)
        shared_out = dense_apply(sp["w2"], h)
        g = jax.nn.sigmoid(dense_apply(sp["gate"], x).astype(jnp.float32)
                           ).astype(x.dtype)
        y = y + g * shared_out

    # Switch-Transformer load-balance loss + router z-loss
    t = b * s
    density = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (t * topk)
    density_proxy = jnp.mean(probs.reshape(t, -1), axis=0)
    aux_loss = e * jnp.sum(density * density_proxy)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(keep) / (t * topk)

    metrics = {
        "moe_aux_loss": aux_loss.astype(jnp.float32),
        "moe_z_loss": z_loss.astype(jnp.float32),
        "moe_dropped_frac": dropped.astype(jnp.float32),
    }
    return y, metrics
