# Model substrate: layers, attention, MoE, RWKV6, Mamba, Hymba blocks,
# decoder-only CausalLM (model.py), encoder-decoder (seq2seq.py).
# Submodules are imported directly (repro.models.model, ...) to keep import
# graphs acyclic; nothing is re-exported here.
