"""Decode-time caches.

All caches are plain dict pytrees so they thread through ``jax.lax.while_loop``
and ``pjit`` unchanged.

KV cache layout (per attention layer):
    k, v : (batch, buf_len, kv_heads, head_dim)   post-RoPE keys
    pos  : (buf_len,) int32                       absolute position held by slot
                                                  (-1 = never written)

The *model-level* current length (number of accepted tokens) lives outside the
per-layer dicts (one scalar for the whole model).  Slot assignment is
``slot = position % buf_len``; masking is computed from absolute positions, so
blockwise-parallel-decoding rollback is simply "decrease the length": stale
slots have ``pos >= length`` and are masked out until overwritten.

For full attention, ``buf_len`` covers the whole context (seq_len + block
slack).  For sliding-window attention, ``buf_len = window + block_k`` — the
``+ block_k`` slack guarantees that speculative writes can never clobber a
slot that is still inside the window after a rollback (see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def attn_cache_init(batch: int, buf_len: int, kv_heads: int, head_dim: int, dtype) -> Dict:
    return {
        "k": jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        # per-row absolute positions: rows advance at different rates under
        # blockwise parallel decoding (per-row accepted block sizes)
        "pos": jnp.full((batch, buf_len), -1, jnp.int32),
    }


def mamba_cache_init(batch: int, d_inner: int, state_dim: int, conv_width: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, state_dim), jnp.float32),
    }


def rwkv_cache_init(batch: int, d_model: int, num_heads: int, head_dim: int, dtype) -> Dict:
    return {
        "shift_tm": jnp.zeros((batch, d_model), dtype),   # time-mix token shift
        "shift_cm": jnp.zeros((batch, d_model), dtype),   # channel-mix token shift
        "state": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
    }


def reset_rows(cache: Dict, mask: jnp.ndarray) -> Dict:
    """Invalidate the cache rows selected by ``mask`` ((B,) bool).

    This is the slot-recycling primitive for continuous-batching serving:
    an evicted request's KV slots get ``pos = -1`` (never-written, masked out
    of every attention) and its recurrent states return to zero, so the row
    can host a freshly admitted request.  K/V values themselves are left in
    place — with ``pos = -1`` they are unreachable, and the admit prefill
    overwrites the whole row anyway.
    """
    out = dict(cache)
    if "attn" in cache:
        a = dict(cache["attn"])
        a["pos"] = jnp.where(mask[:, None], -1, a["pos"])
        out["attn"] = a
    for key in ("tm", "mamba"):
        if key in cache:
            out[key] = {
                k: jnp.where(mask.reshape((-1,) + (1,) * (v.ndim - 1)),
                             jnp.zeros_like(v), v)
                for k, v in cache[key].items()
            }
    return out


def scatter_row(cache: Dict, row_cache: Dict, slot, *, constraint=None) -> Dict:
    """Write a batch-1 cache (``row_cache``) into row ``slot`` of ``cache``.

    Used by the serving engine to prefill an admitted request into a freed
    slot while the other slots keep decoding.  Leaf structures must match
    (same layers / buffer lengths); ``slot`` may be a traced int32 scalar.

    ``constraint`` — optional pytree of shardings (NamedSharding /
    PartitionSpec) mirroring ``cache``.  Under a mesh the slot-index write
    is a *global* scatter into a batch-sharded buffer; pinning the result
    keeps GSPMD lowering it as a masked local write on the owning data
    shard instead of replicating the whole KV buffer around the scatter.
    """
    out = jax.tree_util.tree_map(
        lambda full, row: jax.lax.dynamic_update_index_in_dim(
            full, row[0].astype(full.dtype), slot, 0),
        cache, row_cache)
    if constraint is not None:
        out = jax.tree_util.tree_map(jax.lax.with_sharding_constraint,
                                     out, constraint)
    return out


def attn_buf_len(cfg: ModelConfig, layer_idx: int, context_len: int, block_k: int) -> int:
    """Static KV buffer size for one attention layer.

    Rounded up to a multiple of 256 so the buffer's *length* dim can shard
    over the model axis (flash-decoding-style sequence sharding — used when
    kv_heads doesn't divide the axis).  Extra slots hold pos = -1 and are
    masked out, so padding is semantically free."""
    window = cfg.sliding_window
    if window and layer_idx not in cfg.global_attn_layers:
        # meta tokens (hymba) are global: give them dedicated leading slots by
        # folding them into the window budget.
        n = min(context_len + block_k, window + cfg.num_meta_tokens + block_k)
    else:
        n = context_len + block_k
    return ((n + 255) // 256) * 256
