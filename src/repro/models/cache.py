"""Decode-time caches: dense slabs and paged pools behind one backend API.

All caches are plain dict pytrees so they thread through ``jax.lax.while_loop``
and ``pjit`` unchanged.

Dense KV layout (per attention layer, ``cache_backend="dense"``):
    k, v : (batch, buf_len, kv_heads, head_dim)   post-RoPE keys
    pos  : (batch, buf_len) int32                 absolute position held by
                                                  slot (-1 = never written)

Paged KV layout (per full-attention layer, ``cache_backend="paged"``):
    kp, vp : (num_pages, page_size, kv_heads, head_dim)  shared page pool
    tbl    : (batch, P) int32       per-row block table: logical page i of
                                    row b lives in physical page tbl[b, i].
                                    Physical page 0 is a permanent trash
                                    page — unmapped entries point at it, so
                                    stray writes land somewhere harmless.
    pos    : (batch, P * page_size) int32   absolute positions, as dense

The *model-level* current length (number of accepted tokens) lives outside
the per-layer dicts (one scalar for the whole model).  Masking is computed
from absolute positions, so blockwise-parallel-decoding rollback is simply
"decrease the length": stale slots have ``pos >= length`` and are masked out
until overwritten.  This invariant is backend-independent — under paging a
rollback reclaims stale *speculative* writes by the same position masking
(the pages stay mapped; no copies, no host round-trip), and whole pages are
only returned to the pool on request eviction (``serving/pages.py``).

For full attention, ``buf_len`` covers the whole context (seq_len + block
slack).  For sliding-window attention, ``buf_len = window + block_k`` — the
``+ block_k`` slack guarantees that speculative writes can never clobber a
slot that is still inside the window after a rollback (see DESIGN.md §4).
Window layers keep the dense ring-buffer layout even under the paged
backend: their buffers are already bounded by the window, so paging buys
nothing and would break the ring-wrap slot assignment.

Backend selection: construct a backend with ``get_backend(dec)`` (reads
``DecodeConfig.cache_backend`` / ``page_size``) and pass it down through
``model.init_caches(..., backend=)``.  The legacy free functions
(``attn_cache_init`` etc.) remain the dense building blocks; new call sites
should go through :class:`KVCacheBackend`.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def attn_cache_init(batch: int, buf_len: int, kv_heads: int, head_dim: int, dtype) -> Dict:
    return {
        "k": jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        # per-row absolute positions: rows advance at different rates under
        # blockwise parallel decoding (per-row accepted block sizes)
        "pos": jnp.full((batch, buf_len), -1, jnp.int32),
    }


def paged_attn_cache_init(batch: int, pages_per_row: int, page_size: int,
                          num_pages: int, kv_heads: int, head_dim: int,
                          dtype, *, identity_tbl: bool = False) -> Dict:
    """Paged pool + block table for one full-attention layer.

    ``identity_tbl`` maps row b's logical page i to physical page
    ``1 + b * P + i`` — a fixed, allocator-free layout for run-to-completion
    decode paths.  Serving starts all-trash (``tbl = 0``) and maps pages at
    admission via ``serving.pages.PageAllocator``.
    """
    if identity_tbl:
        tbl = (1 + jnp.arange(batch * pages_per_row, dtype=jnp.int32)
               ).reshape(batch, pages_per_row)
    else:
        tbl = jnp.zeros((batch, pages_per_row), jnp.int32)
    return {
        "kp": jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        "vp": jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        "tbl": tbl,
        "pos": jnp.full((batch, pages_per_row * page_size), -1, jnp.int32),
    }


def mamba_cache_init(batch: int, d_inner: int, state_dim: int, conv_width: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, state_dim), jnp.float32),
    }


def rwkv_cache_init(batch: int, d_model: int, num_heads: int, head_dim: int, dtype) -> Dict:
    return {
        "shift_tm": jnp.zeros((batch, d_model), dtype),   # time-mix token shift
        "shift_cm": jnp.zeros((batch, d_model), dtype),   # channel-mix token shift
        "state": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
    }


def reset_rows(cache: Dict, mask: jnp.ndarray) -> Dict:
    """Invalidate the cache rows selected by ``mask`` ((B,) bool).

    This is the slot-recycling primitive for continuous-batching serving:
    an evicted request's KV slots get ``pos = -1`` (never-written, masked out
    of every attention) and its recurrent states return to zero, so the row
    can host a freshly admitted request.  K/V values themselves are left in
    place — with ``pos = -1`` they are unreachable, and the admit prefill
    overwrites the whole row anyway.  Paged rows additionally drop their
    block table to the trash page (``tbl = 0``) so any in-flight speculative
    write from the retiring step cannot touch pages the host allocator has
    already handed to another slot.
    """
    out = dict(cache)
    if "attn" in cache:
        a = dict(cache["attn"])
        a["pos"] = jnp.where(mask[:, None], -1, a["pos"])
        if "tbl" in a:
            a["tbl"] = jnp.where(mask[:, None], 0, a["tbl"])
        out["attn"] = a
    for key in ("tm", "mamba"):
        if key in cache:
            out[key] = {
                k: jnp.where(mask.reshape((-1,) + (1,) * (v.ndim - 1)),
                             jnp.zeros_like(v), v)
                for k, v in cache[key].items()
            }
    return out


def scatter_row(cache: Dict, row_cache: Dict, slot, *, constraint=None) -> Dict:
    """Write a batch-1 cache (``row_cache``) into row ``slot`` of ``cache``.

    Used by the serving engine to prefill an admitted request into a freed
    slot while the other slots keep decoding.  Leaf structures must match
    (same layers / buffer lengths); ``slot`` may be a traced int32 scalar.

    ``constraint`` — optional pytree of shardings (NamedSharding /
    PartitionSpec) mirroring ``cache``.  Under a mesh the slot-index write
    is a *global* scatter into a batch-sharded buffer; pinning the result
    keeps GSPMD lowering it as a masked local write on the owning data
    shard instead of replicating the whole KV buffer around the scatter.
    """
    out = jax.tree_util.tree_map(
        lambda full, row: jax.lax.dynamic_update_index_in_dim(
            full, row[0].astype(full.dtype), slot, 0),
        cache, row_cache)
    if constraint is not None:
        out = jax.tree_util.tree_map(jax.lax.with_sharding_constraint,
                                     out, constraint)
    return out


def scatter_row_paged(cache: Dict, row_cache: Dict, slot, tbl_row, write_mask,
                      *, constraint=None) -> Dict:
    """Paged admission: install a prefilled batch-1 row into the page pool.

    ``row_cache`` is a *dense* batch-1 layer cache whose attention buffer is
    exactly ``P * page_size`` long (``PagedBackend.row_init``), so logical
    page i of the row is ``row_k[0, i*ps:(i+1)*ps]``.  ``tbl_row`` ((P,)
    int32) is the host allocator's physical mapping for this slot and
    ``write_mask`` ((P,) bool) selects which pages to actually write: False
    entries are copy-on-write prefix hits (their bytes already live in the
    pool from an earlier identical prompt) or unmapped tail pages.  Masked
    pages are redirected to the trash page 0 instead of gathered-and-
    rewritten, so a CoW-shared page is never touched by admission.

    Non-attention cache parts (recurrent states) scatter densely as usual.
    """
    a = cache["attn"]
    r = row_cache["attn"]
    num_pages, ps, kvh, hd = a["kp"].shape
    P = a["tbl"].shape[1]
    tbl_row = jnp.asarray(tbl_row, jnp.int32)
    write_mask = jnp.asarray(write_mask, bool)
    # masked (shared / unmapped) pages write to the trash page, not the pool
    dst = jnp.where(write_mask, tbl_row, 0)
    row_k = r["k"][0].reshape(P, ps, kvh, hd)
    row_v = r["v"][0].reshape(P, ps, kvh, hd)
    new_attn = dict(a)
    new_attn["kp"] = a["kp"].at[dst].set(row_k.astype(a["kp"].dtype))
    new_attn["vp"] = a["vp"].at[dst].set(row_v.astype(a["vp"].dtype))
    new_attn["tbl"] = jax.lax.dynamic_update_index_in_dim(
        a["tbl"], tbl_row, slot, 0)
    new_attn["pos"] = jax.lax.dynamic_update_index_in_dim(
        a["pos"], r["pos"][0].astype(jnp.int32), slot, 0)

    out = dict(cache)
    out["attn"] = new_attn
    for key in cache:
        if key != "attn":
            out[key] = jax.tree_util.tree_map(
                lambda full, row: jax.lax.dynamic_update_index_in_dim(
                    full, row[0].astype(full.dtype), slot, 0),
                cache[key], row_cache[key])
    if constraint is not None:
        out = jax.tree_util.tree_map(jax.lax.with_sharding_constraint,
                                     out, constraint)
    return out


def attn_buf_len(cfg: ModelConfig, layer_idx: int, context_len: int, block_k: int) -> int:
    """Static KV buffer size for one attention layer.

    Rounded up to a multiple of 256 so the buffer's *length* dim can shard
    over the model axis (flash-decoding-style sequence sharding — used when
    kv_heads doesn't divide the axis).  Extra slots hold pos = -1 and are
    masked out, so padding is semantically free."""
    window = cfg.sliding_window
    if window and layer_idx not in cfg.global_attn_layers:
        # meta tokens (hymba) are global: give them dedicated leading slots by
        # folding them into the window budget.
        n = min(context_len + block_k, window + cfg.num_meta_tokens + block_k)
    else:
        n = context_len + block_k
    return ((n + 255) // 256) * 256


def is_paged(layer_cache: Dict) -> bool:
    """True when a per-layer cache dict carries a paged attention part."""
    return "attn" in layer_cache and "kp" in layer_cache["attn"]


def _is_window_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    return bool(cfg.sliding_window) and layer_idx not in cfg.global_attn_layers


# ---------------------------------------------------------------------------
# KVCacheBackend — the one construction/maintenance surface for decode caches
# ---------------------------------------------------------------------------


class KVCacheBackend:
    """Protocol for decode-cache backends.

    A backend owns the *layout* of the per-layer attention caches and every
    whole-model lifecycle operation the decode and serving paths need:

      init(cfg, batch, context_len, block_k, dtype=None)  -> caches
      row_init(cfg, context_len, block_k, dtype=None)     -> batch-1 caches
                    (dense layout, sized so the row scatters into ``init``'s
                    buffers — the admission prefill workspace)
      reset_rows(caches, mask)                            -> caches
      scatter_or_alloc(caches, row_caches, slot, ...)     -> caches
      specs(cfg, caches, mesh, batch_size)                -> PartitionSpecs
      memory_bytes(cfg, batch, context_len, block_k)      -> int

    plus the per-layer hook ``layer_attn_init`` that
    ``blocks.block_cache_init`` dispatches through.  Select one with
    :func:`get_backend`; ``DecodeConfig.cache_backend`` names it.
    """

    name = "abstract"

    # -- per-layer layout hook (called by blocks.block_cache_init) ----------

    def layer_attn_init(self, cfg: ModelConfig, layer_idx: int, batch: int,
                        context_len: int, block_k: int, dtype) -> Dict:
        raise NotImplementedError

    # -- whole-model lifecycle ----------------------------------------------

    def init(self, cfg: ModelConfig, batch: int, context_len: int,
             block_k: int, dtype=None):
        from repro.models import model as model_lib  # cache <- blocks <- model

        return model_lib.init_caches(cfg, batch, context_len, block_k, dtype,
                                     backend=self)

    def row_init(self, cfg: ModelConfig, context_len: int, block_k: int,
                 dtype=None, *, batch: int = 1):
        """Admission-prefill workspace: ``batch`` rows in the dense row
        layout (batch > 1 = a prefill worker's whole packet at once; each
        row is still scattered into a slot individually)."""
        from repro.models import model as model_lib

        return model_lib.init_caches(cfg, batch, context_len, block_k, dtype,
                                     backend=DenseBackend())

    def reset_rows(self, caches, mask):
        return tuple(reset_rows(c, mask) for c in caches)

    def scatter_or_alloc(self, caches, row_caches, slot, *, tbl_row=None,
                         write_mask=None, constraint=None):
        """Install a prefilled batch-1 row: dense rows scatter, paged rows
        additionally bind the allocator's page mapping (``tbl_row`` /
        ``write_mask``, shared across layers — identical tokens at identical
        positions produce one page-id space for the whole model)."""
        if constraint is None:
            constraint = (None,) * len(caches)
        out = []
        for c, rc, cn in zip(caches, row_caches, constraint):
            if is_paged(c):
                out.append(scatter_row_paged(c, rc, slot, tbl_row, write_mask,
                                             constraint=cn))
            else:
                out.append(scatter_row(c, rc, slot, constraint=cn))
        return tuple(out)

    def specs(self, cfg: ModelConfig, caches, mesh, batch_size: int):
        from repro.sharding import policy as shard_policy

        return shard_policy.cache_specs(cfg, caches, mesh, batch_size)

    def memory_bytes(self, cfg: ModelConfig, batch: int, context_len: int,
                     block_k: int, dtype=None) -> int:
        """HBM footprint of ``init``'s buffers (no allocation happens)."""
        shapes = jax.eval_shape(
            lambda: self.init(cfg, batch, context_len, block_k, dtype))
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree_util.tree_leaves(shapes))


class DenseBackend(KVCacheBackend):
    """The original layout: one padded ``buf_len`` KV row per batch slot."""

    name = "dense"

    def layer_attn_init(self, cfg: ModelConfig, layer_idx: int, batch: int,
                        context_len: int, block_k: int, dtype) -> Dict:
        buf = attn_buf_len(cfg, layer_idx, context_len, block_k)
        return attn_cache_init(batch, buf, cfg.num_kv_heads,
                               cfg.resolved_head_dim, dtype)


class _PagedRowBackend(DenseBackend):
    """Dense batch-1 rows whose full-attention buffers are exactly
    ``P * page_size`` long, so the admission prefill's output reshapes
    page-aligned into the pool (see ``scatter_row_paged``)."""

    name = "paged_row"

    def __init__(self, page_size: int):
        self.page_size = page_size

    def layer_attn_init(self, cfg, layer_idx, batch, context_len, block_k,
                        dtype):
        if _is_window_layer(cfg, layer_idx):
            return super().layer_attn_init(cfg, layer_idx, batch, context_len,
                                           block_k, dtype)
        P = pages_per_row(context_len, block_k, self.page_size)
        return attn_cache_init(batch, P * self.page_size, cfg.num_kv_heads,
                               cfg.resolved_head_dim, dtype)


def pages_per_row(context_len: int, block_k: int, page_size: int) -> int:
    """Block-table width P: pages to address ``context_len + block_k``
    positions (the same span a dense buffer covers, minus the 256-padding)."""
    return -(-(context_len + block_k) // page_size)


class PagedBackend(KVCacheBackend):
    """Paged pool layout for full-attention layers (windowed layers stay
    dense — their ring buffers are already window-bounded).

    ``num_pages = 0`` (the default) auto-sizes the pool to the identity
    worst case ``1 + batch * P`` and lays the block tables out identity —
    run-to-completion decode needs no allocator.  Serving passes an explicit
    pool size (``EngineConfig.page_pool_pages``) with ``managed=True``:
    tables start all-trash and ``serving.pages.PageAllocator`` maps pages at
    admission (with copy-on-write prefix sharing).
    """

    name = "paged"

    def __init__(self, page_size: int = 16, num_pages: int = 0,
                 managed: bool = False):
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.managed = bool(managed)

    def layer_attn_init(self, cfg: ModelConfig, layer_idx: int, batch: int,
                        context_len: int, block_k: int, dtype) -> Dict:
        if _is_window_layer(cfg, layer_idx):
            buf = attn_buf_len(cfg, layer_idx, context_len, block_k)
            return attn_cache_init(batch, buf, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, dtype)
        P = pages_per_row(context_len, block_k, self.page_size)
        pool = self.num_pages or (1 + batch * P)
        return paged_attn_cache_init(batch, P, self.page_size, pool,
                                     cfg.num_kv_heads, cfg.resolved_head_dim,
                                     dtype, identity_tbl=not self.managed)

    def row_init(self, cfg: ModelConfig, context_len: int, block_k: int,
                 dtype=None, *, batch: int = 1):
        from repro.models import model as model_lib

        return model_lib.init_caches(
            cfg, batch, context_len, block_k, dtype,
            backend=_PagedRowBackend(self.page_size))


def get_backend(dec=None, *, num_pages: int = 0,
                managed: bool = False) -> KVCacheBackend:
    """The blessed backend constructor: reads ``DecodeConfig.cache_backend``
    (+ ``page_size``); serving passes its pool size and ``managed=True``."""
    name = getattr(dec, "cache_backend", "dense") if dec is not None else "dense"
    if name in ("", "dense"):
        return DenseBackend()
    if name == "paged":
        return PagedBackend(getattr(dec, "page_size", 16),
                            num_pages=num_pages, managed=managed)
    raise ValueError(
        f"unknown cache_backend {name!r}: expected 'dense' or 'paged'")
