"""Decode-time caches.

All caches are plain dict pytrees so they thread through ``jax.lax.while_loop``
and ``pjit`` unchanged.

KV cache layout (per attention layer):
    k, v : (batch, buf_len, kv_heads, head_dim)   post-RoPE keys
    pos  : (buf_len,) int32                       absolute position held by slot
                                                  (-1 = never written)

The *model-level* current length (number of accepted tokens) lives outside the
per-layer dicts (one scalar for the whole model).  Slot assignment is
``slot = position % buf_len``; masking is computed from absolute positions, so
blockwise-parallel-decoding rollback is simply "decrease the length": stale
slots have ``pos >= length`` and are masked out until overwritten.

For full attention, ``buf_len`` covers the whole context (seq_len + block
slack).  For sliding-window attention, ``buf_len = window + block_k`` — the
``+ block_k`` slack guarantees that speculative writes can never clobber a
slot that is still inside the window after a rollback (see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.config import ModelConfig


def attn_cache_init(batch: int, buf_len: int, kv_heads: int, head_dim: int, dtype) -> Dict:
    return {
        "k": jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        # per-row absolute positions: rows advance at different rates under
        # blockwise parallel decoding (per-row accepted block sizes)
        "pos": jnp.full((batch, buf_len), -1, jnp.int32),
    }


def mamba_cache_init(batch: int, d_inner: int, state_dim: int, conv_width: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, state_dim), jnp.float32),
    }


def rwkv_cache_init(batch: int, d_model: int, num_heads: int, head_dim: int, dtype) -> Dict:
    return {
        "shift_tm": jnp.zeros((batch, d_model), dtype),   # time-mix token shift
        "shift_cm": jnp.zeros((batch, d_model), dtype),   # channel-mix token shift
        "state": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
    }


def attn_buf_len(cfg: ModelConfig, layer_idx: int, context_len: int, block_k: int) -> int:
    """Static KV buffer size for one attention layer.

    Rounded up to a multiple of 256 so the buffer's *length* dim can shard
    over the model axis (flash-decoding-style sequence sharding — used when
    kv_heads doesn't divide the axis).  Extra slots hold pos = -1 and are
    masked out, so padding is semantically free."""
    window = cfg.sliding_window
    if window and layer_idx not in cfg.global_attn_layers:
        # meta tokens (hymba) are global: give them dedicated leading slots by
        # folding them into the window budget.
        n = min(context_len + block_k, window + cfg.num_meta_tokens + block_k)
    else:
        n = context_len + block_k
    return ((n + 255) // 256) * 256
