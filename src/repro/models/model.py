"""Model assembly: decoder-only ``CausalLM`` (all assigned text archs, the
VLM backbone, and the RWKV/Hymba families) and the encoder-only stack
(hubert).  The encoder-decoder MT model from the paper lives in seq2seq.py.

All functions are pure; parameters/caches are dict pytrees.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.heads import heads_apply, heads_init
from repro.models import cache as cache_lib
from repro.models.blocks import (
    block_cached,
    block_cache_init,
    block_full,
    block_init,
    commit_cache,
)
from repro.models.layers import (
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    norm_apply,
    norm_init,
    unembed_apply,
)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.params_dtype
    ks = jax.random.split(key, cfg.num_layers + 5)
    p: Dict = {
        "embed": embed_init(ks[0], cfg.padded_vocab_size, cfg.d_model,
                            dtype=dtype),
        "blocks": [block_init(ks[1 + i], cfg, i, dtype=dtype)
                   for i in range(cfg.num_layers)],
        "final_norm": norm_init(cfg.d_model, kind=cfg.norm_type, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[cfg.num_layers + 1], cfg.d_model,
                                  cfg.padded_vocab_size, dtype=dtype)
    if cfg.bpd_enabled:
        p["bpd_heads"] = heads_init(ks[cfg.num_layers + 2], cfg, dtype=dtype)
    if cfg.num_meta_tokens:
        p["meta_tokens"] = jax.random.normal(
            ks[cfg.num_layers + 3], (cfg.num_meta_tokens, cfg.d_model),
            dtype) * 0.02
    if cfg.is_encoder_only:
        p["pos_embed"] = jax.random.normal(
            ks[cfg.num_layers + 4], (cfg.max_seq_len, cfg.d_model), dtype) * 0.02
        p["mask_embed"] = jax.random.normal(
            jax.random.fold_in(key, 99), (cfg.d_model,), dtype) * 0.02
    return p


# ---------------------------------------------------------------------------
# Input embedding (text / vision_text / audio)
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    """batch keys by modality:
       text        : tokens (B, S) int32
       vision_text : patch_embeds (B, P, d) float + tokens (B, S-P-meta)
       audio       : frame_embeds (B, S, d) float [+ mask (B, S) bool]
    Meta tokens (hymba) are prepended here.
    """
    dtype = cfg.compute_dtype
    if cfg.modality == "audio":
        h = batch["frame_embeds"].astype(dtype)
        if "mask" in batch:  # masked-prediction corruption (hubert training)
            m = batch["mask"][..., None]
            h = jnp.where(m, params["mask_embed"].astype(dtype), h)
        s = h.shape[1]
        h = h + params["pos_embed"][:s].astype(dtype)
        return h
    parts = []
    if cfg.num_meta_tokens:
        b = (batch["tokens"] if "tokens" in batch else batch["patch_embeds"]).shape[0]
        meta = jnp.broadcast_to(params["meta_tokens"].astype(dtype),
                                (b, cfg.num_meta_tokens, cfg.d_model))
        parts.append(meta)
    if cfg.modality == "vision_text" and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"].astype(dtype))
    parts.append(embed_apply(params["embed"], batch["tokens"]).astype(dtype))
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def prefix_len(cfg: ModelConfig, batch: Dict) -> int:
    """Number of non-text positions preceding the text tokens."""
    n = cfg.num_meta_tokens
    if cfg.modality == "vision_text" and "patch_embeds" in batch:
        n += batch["patch_embeds"].shape[1]
    return n


# ---------------------------------------------------------------------------
# Backbone forwards
# ---------------------------------------------------------------------------


def forward_hidden(params, cfg: ModelConfig, h, *, positions=None,
                   bidirectional: bool = False, caches=None, kv_chunk: int = 0,
                   moe_full_capacity: bool = False):
    """Whole-sequence forward. h: (B,S,d) embeddings.

    Returns (hidden, metrics, caches) — caches populated if given (prefill).
    """
    metrics: Dict = {}
    new_caches = list(caches) if caches is not None else None
    use_remat = cfg.remat and caches is None   # training forward only

    def run_block(i, bp, h, c):
        return block_full(bp, cfg, i, h, positions=positions,
                          bidirectional=bidirectional, cache=c,
                          kv_chunk=kv_chunk,
                          moe_full_capacity=moe_full_capacity)

    for i, bp in enumerate(params["blocks"]):
        c = caches[i] if caches is not None else None
        if use_remat:
            h, m, c_out = jax.checkpoint(
                lambda bp_, h_, i_=i: run_block(i_, bp_, h_, None))(bp, h)
        else:
            h, m, c_out = run_block(i, bp, h, c)
        for k, v in m.items():
            metrics[k] = metrics.get(k, 0.0) + v / cfg.num_layers
        if caches is not None:
            new_caches[i] = c_out
    h = norm_apply(params["final_norm"], h, kind=cfg.norm_type)
    return h, metrics, (tuple(new_caches) if new_caches is not None else None)


def decode_block_step(params, cfg: ModelConfig, h, caches, length, *,
                      kv_chunk: int = 0, tree=None):
    """BPD verify-substep backbone: k fresh embeddings vs the caches.

    Returns (hidden_block, staged_caches). staged caches carry stacked
    per-step recurrent states; call ``commit_caches`` with k̂ to resolve.
    ``tree`` switches the block to tree verification (see
    ``models.attention.attn_cached``).
    """
    new_caches = []
    for i, bp in enumerate(params["blocks"]):
        h, c_out = block_cached(bp, cfg, i, h, caches[i], length,
                                kv_chunk=kv_chunk, tree=tree)
        new_caches.append(c_out)
    h = norm_apply(params["final_norm"], h, kind=cfg.norm_type)
    return h, tuple(new_caches)


def commit_caches(cfg: ModelConfig, caches, khat):
    return tuple(commit_cache(cfg, c, khat) for c in caches)


def commit_tree_path(cfg: ModelConfig, caches, path_nodes, khat, length,
                     block_k: int):
    """Compact the accepted root-to-leaf path into chain slots per layer
    after a tree verify forward (see ``attention.tree_commit_attn``)."""
    from repro.models.attention import tree_commit_attn

    out = []
    for i, c in enumerate(caches):
        nc = dict(c)
        if "attn" in c:
            nc["attn"] = tree_commit_attn(c["attn"], cfg, i, path_nodes,
                                          khat, length, block_k)
        out.append(nc)
    return tuple(out)


def init_caches(cfg: ModelConfig, batch: int, context_len: int, block_k: int,
                dtype=None, *, backend=None):
    """``backend`` (a ``cache.KVCacheBackend``) selects the attention cache
    layout — dense slabs (default) or the paged pool; recurrent caches are
    layout-independent."""
    dtype = dtype or cfg.compute_dtype
    return tuple(block_cache_init(cfg, i, batch, context_len, block_k, dtype,
                                  backend=backend)
                 for i in range(cfg.num_layers))


def reset_cache_rows(caches, mask):
    """Invalidate rows ``mask`` ((B,) bool) across every layer's cache —
    slot eviction for the continuous-batching serving engine."""
    return tuple(cache_lib.reset_rows(c, mask) for c in caches)


def scatter_cache_row(caches, row_caches, slot, *, constraint=None,
                      tbl_row=None, write_mask=None):
    """Insert a batch-1 cache pytree into row ``slot`` of a batched cache —
    prefill-into-freed-slot for the continuous-batching serving engine.
    ``constraint`` optionally pins per-layer shardings (see cache.scatter_row)
    so admission stays a shard-local write on a mesh.  For paged layers
    ``tbl_row`` / ``write_mask`` carry the host allocator's page mapping
    (one mapping serves every layer — see cache.scatter_row_paged)."""
    if constraint is None:
        constraint = (None,) * len(caches)
    out = []
    for c, rc, cn in zip(caches, row_caches, constraint):
        if cache_lib.is_paged(c):
            out.append(cache_lib.scatter_row_paged(
                c, rc, slot, tbl_row, write_mask, constraint=cn))
        else:
            out.append(cache_lib.scatter_row(c, rc, slot, constraint=cn))
    return tuple(out)


# ---------------------------------------------------------------------------
# Output projections
# ---------------------------------------------------------------------------


def project_vocab(params, cfg: ModelConfig, h) -> jnp.ndarray:
    """(..., d) -> (..., padded_vocab) logits; pad lanes masked to -inf so
    argmax / softmax never select them (see ModelConfig.padded_vocab_size)."""
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], h)
    else:
        logits = dense_apply(params["lm_head"], h)
    if cfg.padded_vocab_size != cfg.vocab_size:
        lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(lane < cfg.vocab_size, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return logits


def all_head_logits(params, cfg: ModelConfig, hidden) -> jnp.ndarray:
    """hidden: (..., d) -> (..., k, V) logits of p_1..p_k (paper Fig. 3)."""
    if not cfg.bpd_enabled or "bpd_heads" not in params:
        # headless model: p_1 only (greedy-decodable via block_k=1)
        return project_vocab(params, cfg, hidden)[..., None, :]
    outs = heads_apply(params["bpd_heads"], cfg, hidden,
                       identity_p1=cfg.bpd_identity_p1)
    return project_vocab(params, cfg, outs)


def base_logits(params, cfg: ModelConfig, hidden) -> jnp.ndarray:
    """p_1 logits only."""
    if cfg.bpd_enabled and not cfg.bpd_identity_p1:
        from repro.core.heads import head_apply_single
        hidden = head_apply_single(params["bpd_heads"], cfg, hidden, 0,
                                   identity_p1=False)
    return project_vocab(params, cfg, hidden)
