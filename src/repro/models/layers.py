"""Shared building blocks: initializers, norms, activations, RoPE, MLPs."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

# ---------------------------------------------------------------------------
# Initializers (pure functions of a PRNG key; params are plain dict pytrees)
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, *, dtype=jnp.float32, scale: float = 1.0,
               bias: bool = False):
    std = scale / math.sqrt(in_dim)
    p = {"w": jax.random.normal(key, (in_dim, out_dim), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(dim: int, *, kind: str = "rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def norm_apply(p, x, *, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def group_norm_apply(p, x, num_groups: int, *, eps: float = 1e-5):
    """GroupNorm over the channel dim (used by RWKV6 per-head ln_x)."""
    *lead, c = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, num_groups, c // num_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, c)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name}")


GATED_ACTIVATIONS = ("silu", "geglu")  # use w1/w3 gated form


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense feed-forward; MoE lives in moe.py)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, *, d_ff: Optional[int] = None, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], cfg.d_model, d_ff, dtype=dtype)}
    if cfg.activation in GATED_ACTIVATIONS:
        p["w3"] = dense_init(ks[1], cfg.d_model, d_ff, dtype=dtype)
    p["w2"] = dense_init(ks[2], d_ff, cfg.d_model, dtype=dtype)
    return p


def mlp_apply(p, x, *, act: str):
    h = dense_apply(p["w1"], x)
    if "w3" in p:
        h = activation("silu" if act == "geglu" else act, h) * dense_apply(p["w3"], x)
    else:
        h = activation(act, h)
    return dense_apply(p["w2"], h)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, dim: int, *, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * 0.02}


def embed_apply(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed_apply(p, x):
    """Tied unembedding: x @ table^T."""
    return x @ p["table"].T.astype(x.dtype)
