"""Encoder-decoder transformer — the paper's machine-translation setting.

Encoder: bidirectional attention blocks (learned positional embeddings).
Decoder: causal blocks with cross attention; BPD heads sit on the decoder
output exactly as in the decoder-only case.  The cross-attention K/V are
computed once per source sentence ("encode") and threaded through decode.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.heads import heads_init
from repro.models.attention import cross_kv
from repro.models.blocks import block_cached, block_cache_init, block_full, block_init
from repro.models.layers import (
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    norm_apply,
    norm_init,
    unembed_apply,
)
from repro.models import model as model_lib


def init(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.params_dtype
    ne, nd = cfg.num_encoder_layers, cfg.num_layers
    ks = jax.random.split(key, ne + nd + 6)
    p: Dict = {
        "src_embed": embed_init(ks[0], cfg.padded_vocab_size, cfg.d_model,
                                dtype=dtype),
        "embed": embed_init(ks[1], cfg.padded_vocab_size, cfg.d_model,
                            dtype=dtype),
        "enc_pos": jax.random.normal(ks[2], (cfg.max_seq_len, cfg.d_model),
                                     dtype) * 0.02,
        "enc_blocks": [block_init(ks[3 + i], cfg, i, dtype=dtype)
                       for i in range(ne)],
        "enc_norm": norm_init(cfg.d_model, kind=cfg.norm_type, dtype=dtype),
        "blocks": [block_init(ks[3 + ne + i], cfg, i, dtype=dtype,
                              cross_attention=True) for i in range(nd)],
        "final_norm": norm_init(cfg.d_model, kind=cfg.norm_type, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[3 + ne + nd], cfg.d_model,
                                  cfg.padded_vocab_size, dtype=dtype)
    if cfg.bpd_enabled:
        p["bpd_heads"] = heads_init(ks[4 + ne + nd], cfg, dtype=dtype)
    return p


def encode(params, cfg: ModelConfig, src_tokens, src_mask=None):
    """src_tokens: (B, Se) -> per-decoder-layer cross K/V + mask."""
    dtype = cfg.compute_dtype
    h = embed_apply(params["src_embed"], src_tokens).astype(dtype)
    h = h + params["enc_pos"][: h.shape[1]].astype(dtype)
    for i, bp in enumerate(params["enc_blocks"]):
        h, _, _ = block_full(bp, cfg, i, h, bidirectional=True)
    h = norm_apply(params["enc_norm"], h, kind=cfg.norm_type)
    enc_kvs = tuple(cross_kv(bp["cross"], cfg, h) for bp in params["blocks"])
    return enc_kvs, src_mask


def forward_hidden(params, cfg: ModelConfig, tgt_tokens, enc_kvs, *,
                   enc_mask=None, caches=None):
    """Teacher-forced decoder forward (training / prefill)."""
    dtype = cfg.compute_dtype
    h = embed_apply(params["embed"], tgt_tokens).astype(dtype)
    new_caches = list(caches) if caches is not None else None
    for i, bp in enumerate(params["blocks"]):
        c = caches[i] if caches is not None else None
        h, _, c_out = block_full(bp, cfg, i, h, enc_kv=enc_kvs[i],
                                 enc_mask=enc_mask, cache=c)
        if caches is not None:
            new_caches[i] = c_out
    h = norm_apply(params["final_norm"], h, kind=cfg.norm_type)
    return h, (tuple(new_caches) if new_caches is not None else None)


def decode_block_step(params, cfg: ModelConfig, h, caches, length, enc_kvs,
                      enc_mask=None, tree=None):
    new_caches = []
    for i, bp in enumerate(params["blocks"]):
        h, c_out = block_cached(bp, cfg, i, h, caches[i], length,
                                enc_kv=enc_kvs[i], enc_mask=enc_mask,
                                tree=tree)
        new_caches.append(c_out)
    h = norm_apply(params["final_norm"], h, kind=cfg.norm_type)
    return h, tuple(new_caches)


def init_caches(cfg: ModelConfig, batch: int, context_len: int, block_k: int,
                dtype=None):
    dtype = dtype or cfg.compute_dtype
    return tuple(block_cache_init(cfg, i, batch, context_len, block_k, dtype)
                 for i in range(cfg.num_layers))


# Output projections are identical to the decoder-only model (lazy
# delegation: model_lib may still be mid-import when this module loads).


def project_vocab(params, cfg, h):
    return model_lib.project_vocab(params, cfg, h)


def all_head_logits(params, cfg, hidden):
    return model_lib.all_head_logits(params, cfg, hidden)


def base_logits(params, cfg, hidden):
    return model_lib.base_logits(params, cfg, hidden)
