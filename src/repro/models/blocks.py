"""Per-layer block composition.

One ``Block`` covers every assigned family via ``cfg.block_type`` ×
``cfg.mlp_type``:

  attn  + dense     : stablelm / starcoder2 / nemotron / granite / llava /
                      hubert (bidirectional) / the paper's MT transformer
  attn  + moe       : qwen2-moe, olmoe
  rwkv6 + channel   : rwkv6 ("Finch")
  hymba + dense     : hymba (parallel attention + mamba heads, fused)

Two execution modes:
  * full   — whole-sequence parallel forward (training / prefill / encoder);
             optionally populates the decode caches.
  * cached — a block of ``k`` fresh tokens against the caches (the BPD
             verify substep).  Recurrent components return *per-step* states
             stacked along a leading axis so the decode loop can roll back to
             the accepted prefix; ``commit_cache`` selects the accepted step.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import cache as cache_lib
from repro.models.attention import (
    attn_cached,
    attn_full,
    attn_init,
    cache_write,
    cross_attn_apply,
    cross_attn_init,
    cross_kv,
)
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init
from repro.models.mamba import mamba_apply, mamba_init
from repro.models.moe import moe_apply, moe_init
from repro.models.rwkv6 import (
    rwkv_cm_apply,
    rwkv_cm_init,
    rwkv_tm_apply,
    rwkv_tm_init,
)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, layer_idx: int, *, dtype=jnp.float32,
               cross_attention: bool = False) -> Dict:
    ks = jax.random.split(key, 8)
    p: Dict = {"ln1": norm_init(cfg.d_model, kind=cfg.norm_type, dtype=dtype)}

    if cfg.block_type == "attn":
        p["attn"] = attn_init(ks[0], cfg, dtype=dtype)
    elif cfg.block_type == "rwkv6":
        p["tm"] = rwkv_tm_init(ks[0], cfg, dtype=dtype)
    elif cfg.block_type == "hymba":
        p["attn"] = attn_init(ks[0], cfg, dtype=dtype)
        p["mamba"] = mamba_init(ks[1], cfg, dtype=dtype)
        p["fuse_ln_attn"] = norm_init(cfg.d_model, kind="rmsnorm", dtype=dtype)
        p["fuse_ln_ssm"] = norm_init(cfg.d_model, kind="rmsnorm", dtype=dtype)
        p["beta_attn"] = jnp.ones((cfg.d_model,), dtype)
        p["beta_ssm"] = jnp.ones((cfg.d_model,), dtype)
    else:
        raise ValueError(cfg.block_type)

    if cross_attention:
        p["ln_cross"] = norm_init(cfg.d_model, kind=cfg.norm_type, dtype=dtype)
        p["cross"] = cross_attn_init(ks[2], cfg, dtype=dtype)

    p["ln2"] = norm_init(cfg.d_model, kind=cfg.norm_type, dtype=dtype)
    if cfg.mlp_type == "dense":
        p["mlp"] = mlp_init(ks[3], cfg, dtype=dtype)
    elif cfg.mlp_type == "moe":
        p["moe"] = moe_init(ks[3], cfg, dtype=dtype)
    elif cfg.mlp_type == "rwkv_channel_mix":
        p["cm"] = rwkv_cm_init(ks[3], cfg, dtype=dtype)
    else:
        raise ValueError(cfg.mlp_type)
    return p


def block_cache_init(cfg: ModelConfig, layer_idx: int, batch: int,
                     context_len: int, block_k: int, dtype,
                     backend: Optional[cache_lib.KVCacheBackend] = None) -> Dict:
    """Static cache buffers for one layer (decode path).  ``backend``
    (a ``cache.KVCacheBackend``) owns the attention-cache layout; None
    means the dense default."""
    c: Dict = {}
    if cfg.block_type in ("attn", "hymba"):
        be = backend if backend is not None else cache_lib.DenseBackend()
        c["attn"] = be.layer_attn_init(cfg, layer_idx, batch, context_len,
                                       block_k, dtype)
    if cfg.block_type == "rwkv6":
        h = cfg.d_model // cfg.rwkv_head_dim
        c["tm"] = cache_lib.rwkv_cache_init(batch, cfg.d_model, h,
                                            cfg.rwkv_head_dim, dtype)
    if cfg.block_type == "hymba":
        c["mamba"] = cache_lib.mamba_cache_init(
            batch, cfg.ssm_expand * cfg.d_model, cfg.ssm_state_dim,
            cfg.ssm_conv_width, dtype)
    return c


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill / encoder)
# ---------------------------------------------------------------------------


def block_full(p, cfg: ModelConfig, layer_idx: int, x, *, positions=None,
               bidirectional: bool = False, enc_kv=None, enc_mask=None,
               cache: Optional[Dict] = None, kv_chunk: int = 0,
               moe_full_capacity: bool = False
               ) -> Tuple[jnp.ndarray, Dict, Optional[Dict]]:
    """Returns (y, metrics, cache_out). cache_out is populated when a cache
    dict is passed in (prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    metrics: Dict = {}
    cache_out = dict(cache) if cache is not None else None

    h = norm_apply(p["ln1"], x, kind=cfg.norm_type)
    if cfg.block_type == "attn":
        if cache is not None:
            y, (kk, vv) = attn_full(p["attn"], cfg, h, layer_idx=layer_idx,
                                    positions=positions,
                                    bidirectional=bidirectional,
                                    return_kv=True, kv_chunk=kv_chunk)
            cache_out["attn"] = cache_write(cache["attn"], cfg, layer_idx,
                                            kk, vv, positions)
        else:
            y = attn_full(p["attn"], cfg, h, layer_idx=layer_idx,
                          positions=positions, bidirectional=bidirectional,
                          kv_chunk=kv_chunk)
    elif cfg.block_type == "rwkv6":
        y, aux = rwkv_tm_apply(p["tm"], cfg, h)
        if cache is not None:
            cache_out["tm"] = {
                "shift_tm": aux["x_last"],
                "shift_cm": cache["tm"]["shift_cm"],  # filled below
                "state": aux["state"],
            }
    elif cfg.block_type == "hymba":
        ya, (kk, vv) = attn_full(p["attn"], cfg, h, layer_idx=layer_idx,
                                 positions=positions, return_kv=True,
                                 kv_chunk=kv_chunk)
        ym, maux = mamba_apply(p["mamba"], cfg, h)
        ya = norm_apply(p["fuse_ln_attn"], ya) * p["beta_attn"].astype(x.dtype)
        ym = norm_apply(p["fuse_ln_ssm"], ym) * p["beta_ssm"].astype(x.dtype)
        y = 0.5 * (ya + ym)
        if cache is not None:
            cache_out["attn"] = cache_write(cache["attn"], cfg, layer_idx,
                                            kk, vv, positions)
            cache_out["mamba"] = {"conv": maux["conv"], "h": maux["ssm"]}
    x = x + y

    if enc_kv is not None:
        h = norm_apply(p["ln_cross"], x, kind=cfg.norm_type)
        x = x + cross_attn_apply(p["cross"], cfg, h, enc_kv, enc_mask)

    h = norm_apply(p["ln2"], x, kind=cfg.norm_type)
    if cfg.mlp_type == "dense":
        y = mlp_apply(p["mlp"], h, act=cfg.activation)
    elif cfg.mlp_type == "moe":
        y, metrics = moe_apply(p["moe"], cfg, h, full_capacity=moe_full_capacity)
    else:  # rwkv channel mix
        y, cm_aux = rwkv_cm_apply(p["cm"], cfg, h)
        if cache_out is not None:
            cache_out["tm"] = dict(cache_out["tm"], shift_cm=cm_aux["x_last"])
    x = x + y
    return x, metrics, cache_out


# ---------------------------------------------------------------------------
# Cached block forward (BPD verify substep: k fresh tokens)
# ---------------------------------------------------------------------------


def block_cached(p, cfg: ModelConfig, layer_idx: int, x, cache: Dict, length,
                 *, enc_kv=None, enc_mask=None, kv_chunk: int = 0, tree=None
                 ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, k, d) fresh tokens at positions length..length+k-1.

    Returns (y, new_cache).  Recurrent state entries in new_cache are stacked
    per-step (leading axis k) — ``commit_cache`` resolves them once k̂ is
    known.  Attention cache entries need no rollback (masking by position).

    ``tree`` (a ``kernels.tree_mask.TreeTopology``) switches the block to
    tree verification — attention-family layers only: recurrent states are
    conditioned on the whole previous chain step-by-step, so a branching
    block has no single per-step state to roll back to.
    """
    b, kblk, _ = x.shape
    new_cache = dict(cache)

    if tree is not None and cfg.block_type != "attn":
        raise NotImplementedError(
            f"tree verification requires pure attention blocks "
            f"(block_type='attn'); {cfg.block_type!r} carries chain-"
            f"conditioned per-step recurrent state")

    h = norm_apply(p["ln1"], x, kind=cfg.norm_type)
    if cfg.block_type == "attn":
        y, new_cache["attn"] = attn_cached(p["attn"], cfg, h, cache["attn"],
                                           length, layer_idx=layer_idx,
                                           kv_chunk=kv_chunk, tree=tree)
    elif cfg.block_type == "rwkv6":
        y, aux = rwkv_tm_apply(p["tm"], cfg, h,
                               x_prev=cache["tm"]["shift_tm"],
                               state0=cache["tm"]["state"],
                               return_states=True)
        # stacked per-step: shift = the h inputs themselves, state = aux
        new_cache["tm"] = {
            "shift_tm_steps": h,                       # (B,k,d)
            "state_steps": aux["state"],               # (B,k,H,D,D)
            "shift_tm": cache["tm"]["shift_tm"],
            "shift_cm": cache["tm"]["shift_cm"],
            "state": cache["tm"]["state"],
        }
    elif cfg.block_type == "hymba":
        ya, new_cache["attn"] = attn_cached(p["attn"], cfg, h, cache["attn"],
                                            length, layer_idx=layer_idx,
                                            kv_chunk=kv_chunk)
        ym, maux = mamba_apply(p["mamba"], cfg, h,
                               conv_state=cache["mamba"]["conv"],
                               h0=cache["mamba"]["h"], return_states=True)
        ya = norm_apply(p["fuse_ln_attn"], ya) * p["beta_attn"].astype(x.dtype)
        ym = norm_apply(p["fuse_ln_ssm"], ym) * p["beta_ssm"].astype(x.dtype)
        y = 0.5 * (ya + ym)
        new_cache["mamba"] = {
            "conv_steps": maux["conv"],                # (B,k,W-1,di)
            "h_steps": maux["ssm"],                    # (B,k,di,N)
            "conv": cache["mamba"]["conv"],
            "h": cache["mamba"]["h"],
        }
    x = x + y

    if enc_kv is not None:
        h = norm_apply(p["ln_cross"], x, kind=cfg.norm_type)
        x = x + cross_attn_apply(p["cross"], cfg, h, enc_kv, enc_mask)

    h = norm_apply(p["ln2"], x, kind=cfg.norm_type)
    if cfg.mlp_type == "dense":
        y = mlp_apply(p["mlp"], h, act=cfg.activation)
    elif cfg.mlp_type == "moe":
        y, _ = moe_apply(p["moe"], cfg, h, full_capacity=True)
    else:
        y, _ = rwkv_cm_apply(p["cm"], cfg, h,
                             x_prev=cache["tm"]["shift_cm"])
        new_cache["tm"]["shift_cm_steps"] = h          # (B,k,d)
    x = x + y
    return x, new_cache


def commit_cache(cfg: ModelConfig, cache: Dict, khat) -> Dict:
    """Resolve stacked per-step recurrent states to the accepted prefix.

    khat: (B,) or () int32 in [0, k] — number of accepted tokens per row this
    iteration (0 = row already finished: keep the pre-iteration state).
    Attention caches are untouched (absolute-position masking handles
    rollback); recurrent states select step khat-1.
    """
    out = dict(cache)
    khat = jnp.asarray(khat, jnp.int32)

    def pick(steps, old):  # steps: (B, k, ...) old: (B, ...) -> (B, ...)
        b = steps.shape[0]
        kh = jnp.broadcast_to(khat, (b,))
        idx = jnp.maximum(kh - 1, 0).reshape((b,) + (1,) * (steps.ndim - 1))
        picked = jnp.take_along_axis(steps, idx, axis=1).squeeze(1)
        keep_old = (kh == 0).reshape((b,) + (1,) * (old.ndim - 1))
        return jnp.where(keep_old, old, picked.astype(old.dtype))

    if "tm" in cache:
        tm = cache["tm"]
        out["tm"] = {
            "shift_tm": pick(tm["shift_tm_steps"], tm["shift_tm"])
            if "shift_tm_steps" in tm else tm["shift_tm"],
            "shift_cm": pick(tm["shift_cm_steps"], tm["shift_cm"])
            if "shift_cm_steps" in tm else tm["shift_cm"],
            "state": pick(tm["state_steps"], tm["state"])
            if "state_steps" in tm else tm["state"],
        }
    if "mamba" in cache:
        mb = cache["mamba"]
        out["mamba"] = {
            "conv": pick(mb["conv_steps"], mb["conv"])
            if "conv_steps" in mb else mb["conv"],
            "h": pick(mb["h_steps"], mb["h"]) if "h_steps" in mb else mb["h"],
        }
    return out
