"""Mamba-1 selective SSM (used by the Hymba hybrid block's SSM heads).

h_t = exp(Δ_t A) ⊙ h_{t-1} + (Δ_t B_t) x_t ;  y_t = C_t h_t + D x_t
with data-dependent Δ, B, C.  Causal depthwise conv front-end as in the
original architecture.

Decode API mirrors rwkv6: per-step states are returned for the BPD rollback.
Training uses a chunked, remat'ed scan (same trick as rwkv6) to bound the
backward-pass state storage.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init

DT_RANK_DIV = 16  # dt_rank = ceil(d_model / 16), mamba default


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, (cfg.d_model + DT_RANK_DIV - 1) // DT_RANK_DIV)


def mamba_init(key, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dtype),  # x and gate z
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * n, dtype=dtype),  # Δ_low, B, C
        "dt_proj": {
            "w": jax.random.normal(ks[3], (dtr, di), dtype) * (dtr ** -0.5),
            "b": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
                jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                           jnp.log(1e-3), jnp.log(1e-1))))).astype(dtype),
        },
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[5], di, d, dtype=dtype),
    }


def _causal_conv(p, x, conv_state):
    """x: (B,S,di); conv_state: (B,W-1,di) trailing inputs of the prefix."""
    w = p["conv_w"].astype(x.dtype)  # (W, di)
    width = w.shape[0]
    xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B, W-1+S, di)
    # depthwise causal conv via stacked shifts (W is tiny, typically 4)
    s = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xx[:, i:i + s, :] * w[i]
    out = out + p["conv_b"].astype(x.dtype)
    new_state = xx[:, -(width - 1):, :] if width > 1 else xx[:, :0, :]
    return jax.nn.silu(out), new_state


def _ssm_scan(u, dt, B, C, A, D, h0, *, return_states: bool, chunk: int = 128):
    """u: (B,S,di); dt: (B,S,di); B,C: (B,S,N); A: (di,N); h0: (B,di,N) f32."""
    uf, dtf, Bf, Cf = (t.astype(jnp.float32) for t in (u, dt, B, C))
    Af = A.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * Af)                    # (B,S,di,N)
    dBu = dtf[..., None] * Bf[:, :, None, :] * uf[..., None]

    def step(h, inp):
        dA_t, dBu_t, C_t = inp                           # (B,di,N),(B,di,N),(B,N)
        h_new = dA_t * h + dBu_t
        y_t = jnp.einsum("bdn,bn->bd", h_new, C_t)
        return h_new, (y_t, h_new) if return_states else y_t

    xs = (dA.transpose(1, 0, 2, 3), dBu.transpose(1, 0, 2, 3),
          Cf.transpose(1, 0, 2))

    if return_states:
        h_last, (ys, hs) = jax.lax.scan(step, h0, xs)
        ys = ys.transpose(1, 0, 2)
        states = hs.transpose(1, 0, 2, 3)                # (B,S,di,N)
    else:
        b, s, di = u.shape
        n = A.shape[1]
        c = min(chunk, s)
        nchunks = (s + c - 1) // c
        pad = nchunks * c - s
        if pad:
            xs = tuple(jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1)) for t in xs)

        def chunk_body(h, inp):
            return jax.lax.scan(step, h, inp)

        chunk_body = jax.checkpoint(chunk_body)
        xs = tuple(t.reshape(nchunks, c, *t.shape[1:]) for t in xs)
        h_last, ys = jax.lax.scan(chunk_body, h0, xs)
        ys = ys.reshape(nchunks * c, b, di)[:s].transpose(1, 0, 2)
        states = h_last[:, None]                         # (B,1,di,N)

    y = ys + uf * D.astype(jnp.float32)
    return y, states


def mamba_apply(p, cfg: ModelConfig, x, *, conv_state=None, h0=None,
                return_states: bool = False):
    """x: (B,S,d) -> (y, aux) with aux = {conv_states, ssm_states}.

    When return_states=True both conv and ssm states are per-step (S small on
    the decode path); otherwise only the final states are returned.
    """
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    width = cfg.ssm_conv_width
    dtr = _dt_rank(cfg)
    if conv_state is None:
        conv_state = jnp.zeros((b, width - 1, di), x.dtype)
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    xz = x @ p["in_proj"]["w"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u, new_conv = _causal_conv(p, u, conv_state)

    proj = u @ p["x_proj"]["w"].astype(x.dtype)          # (B,S,dtr+2N)
    dt_low, Bm, Cm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        dt_low @ p["dt_proj"]["w"].astype(x.dtype)
        + p["dt_proj"]["b"].astype(x.dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, states = _ssm_scan(u, dt, Bm, Cm, A, p["D"], h0,
                          return_states=return_states)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = y @ p["out_proj"]["w"].astype(x.dtype)

    if return_states:
        # per-step conv states: trailing (width-1) inputs before each step end
        xx = jnp.concatenate([conv_state.astype(x.dtype),
                              (x @ p["in_proj"]["w"].astype(x.dtype))[..., :di]],
                             axis=1)
        conv_states = jnp.stack(
            [jax.lax.dynamic_slice_in_dim(xx, t + 1, width - 1, axis=1)
             for t in range(s)], axis=1)                 # (B,S,W-1,di)
        aux = {"conv": conv_states, "ssm": states}
    else:
        aux = {"conv": new_conv, "ssm": states[:, -1]}
    return y, aux
