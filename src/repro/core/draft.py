"""Draft-model speculative drafting: a small causal LM proposes the block.

The paper predicts the k block tokens with prediction heads bolted onto the
verifier; the stronger form in the BPD-drafts follow-up (arXiv:2404.09221)
and Aggressive Decoding (arXiv:2205.10350) replaces the heads with an
*independent small draft model* that proposes the block autoregressively —
cheap, because it is tiny — while the big model verifies the whole block in
one invocation.  Exact acceptance keeps this lossless: slot 0 of every
draft is pinned to the verifier's own greedy token, so the decoded tokens
equal greedy decoding for ANY draft model; draft quality moves iteration
counts only.

``DraftModelDrafter`` is a ``core.policy.Drafter`` backed by an auxiliary
``core.bundle.ModelBundle`` (bound at session construction via
``DecodePolicy.bind``).  Its loop-carried state is the draft model's own
KV cache, living inside ``BPDState.policy_state`` / ``SlotBatch.
policy_state`` like any other per-row policy state: it shards over the
data axes (``sharding.policy.state_specs`` applies the draft model's own
``cache_specs`` when given ``draft_cfg`` — the session reads it off the
bound drafter), freezes with finished rows, and is reset/scattered by
the serving engine on admit/evict.

Cache discipline (why one catch-up token is always enough): the draft
chain written at iteration t covers positions L..L+k-2 (slot 0 = the
verified token at L, then the chain), and the verifier commits exactly
that chain prefix — so after accepting k̂ tokens the draft cache already
holds the committed stream except, when k̂ = k, the single position
L+k-1.  Each draft therefore re-feeds ``prev_token`` (the committed token
at ``text_len - 1``) before extending; attention-cache staleness beyond
``text_len`` is handled by the same absolute-position masking that powers
BPD rollback (models/cache.py).  That argument is KV-only, hence the
attention-family restriction on the draft config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import policy as policy_lib

I32 = jnp.int32

DRAFT_BUNDLE = "draft"  # the session bundle name this drafter reads


@dataclasses.dataclass(frozen=True)
class DraftModelDrafter(policy_lib.Drafter):
    """Propose ``block_k`` tokens with a small causal draft LM.

    Unbound (``cfg is None``) until ``DecodePolicy.bind`` attaches the
    session's ``bundles["draft"]``; the params themselves arrive traced,
    per call, via ``DraftInputs.aux["draft"]``.
    """

    cfg: Optional[ModelConfig] = None      # the DRAFT model's config
    kv_chunk: int = 0
    backend_factory: Optional[Callable] = None
    bundle: str = DRAFT_BUNDLE
    # Suffix carry-over: fold the catch-up token into the first extension
    # as one width-2 draft forward, cutting the sequential draft-model
    # calls per iteration from block_k to block_k - 1 (token-identical —
    # the position text_len-1 rewrite is value-identical and absolute-
    # position masking hides the stale text_len entry from it).
    carry_over: bool = True

    # -- binding --------------------------------------------------------------

    def bind(self, bundles: Dict, cfg) -> "DraftModelDrafter":
        b = (bundles or {}).get(self.bundle)
        if b is None:
            raise ValueError(
                f"the 'draft_model' policy runs a second model: pass "
                f"bundles={{{self.bundle!r}: ModelBundle(draft_params, "
                f"draft_cfg)}} to the DecodeSession / decode entry point "
                f"(got bundles={sorted(bundles or {})})")
        d = b.cfg
        if d.block_type != "attn":
            raise NotImplementedError(
                f"draft model {d.name!r} has block_type={d.block_type!r}: "
                f"the draft cache rolls back rejected speculation by "
                f"absolute-position masking, which only KV caches support "
                f"— recurrent draft states would keep rejected tokens")
        if d.is_encoder_decoder or d.is_encoder_only:
            raise ValueError(
                f"draft model {d.name!r} must be decoder-only: it drafts "
                f"the output token stream autoregressively")
        if d.num_meta_tokens or d.modality != "text":
            raise NotImplementedError(
                f"draft model {d.name!r} must be a plain text LM (no meta "
                f"tokens / modality prefixes): draft positions are output-"
                f"stream positions")
        if cfg is not None and d.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft model vocab_size={d.vocab_size} != primary model "
                f"vocab_size={cfg.vocab_size}: proposals are token ids in "
                f"the primary vocabulary")
        return dataclasses.replace(self, cfg=d, kv_chunk=b.kv_chunk,
                                   backend_factory=b.backend_factory)

    def _require_bound(self):
        if self.cfg is None:
            raise ValueError(
                "DraftModelDrafter is unbound — resolve the 'draft_model' "
                "policy through a DecodeSession (or call DecodePolicy.bind) "
                "with a 'draft' ModelBundle before decoding")

    def _backend(self):
        from repro.core.decode import causal_lm_backend

        if self.backend_factory is not None:
            return self.backend_factory(self.cfg, self.kv_chunk)
        return causal_lm_backend(self.cfg, kv_chunk=self.kv_chunk)

    # -- state ----------------------------------------------------------------

    def init_state(self, cfg, dec, batch, b, aux=()) -> Any:
        """Draft KV cache for ``b`` rows, prefilled on the prompt when the
        caller can supply both the prompt tokens and the draft params.

        Shape contract: the cache geometry depends only on (prompt length,
        dec, block_k), never on whether ``aux`` was available — so the
        engine's paramless init/evict builders produce states congruent
        with the admission path's prefilled rows.
        """
        self._require_bound()
        from repro.models import model as model_lib

        block_k = dec.block_k or cfg.bpd_k
        tokens = batch.get("tokens") if isinstance(batch, dict) else None
        # seq2seq / promptless paths: the draft stream starts at BOS (pos 0)
        prompt_len = 1 if tokens is None else tokens.shape[1]
        context = prompt_len + dec.max_new_tokens + block_k
        caches = model_lib.init_caches(self.cfg, b, context, 1)
        params = aux[self.bundle] if aux and self.bundle in aux else None
        if params is not None and tokens is not None:
            from repro.models.layers import embed_apply

            h = embed_apply(params["embed"], jnp.asarray(tokens, I32))
            h = h.astype(self.cfg.compute_dtype)
            positions = jnp.arange(h.shape[1], dtype=I32)
            _, _, caches = model_lib.forward_hidden(
                params, self.cfg, h, positions=positions, caches=caches,
                kv_chunk=self.kv_chunk, moe_full_capacity=True)
        return {"caches": caches}

    # -- drafting -------------------------------------------------------------

    def draft(self, inputs: policy_lib.DraftInputs, state: Any):
        self._require_bound()
        if not (inputs.aux and self.bundle in inputs.aux):
            raise ValueError(
                f"DraftModelDrafter needs its params in DraftInputs.aux"
                f"[{self.bundle!r}] — this decode path was not built with "
                f"the session's auxiliary bundles threaded through")
        params = inputs.aux[self.bundle]
        be = self._backend()
        b, k = inputs.old_proposals.shape
        ones = jnp.ones((b,), I32)
        caches = state["caches"]

        def step(tok, caches, pos):
            """One draft-model token: feed ``tok`` at per-row ``pos``."""
            h = be.embed_tokens(params, tok[:, None])
            hidden, staged = be.decode_block(params, h, caches, pos)
            caches = be.commit(staged, ones)
            logits = be.head_logits(params, hidden)    # (B, 1, K', V)
            return jnp.argmax(logits[:, 0, 0, :], axis=-1).astype(I32), caches

        head_argmax = jnp.argmax(inputs.logits, axis=-1)        # (B, k, K)
        verified = policy_lib._gather_slot(head_argmax, inputs.slot)[:, 0]
        verified = verified.astype(I32)
        prev = jnp.asarray(inputs.prev_token, I32)
        pos0 = jnp.maximum(inputs.text_len - 1, 0)

        props = [verified]
        if self.carry_over and k > 1:
            # carry-over: the catch-up token (committed at text_len - 1)
            # and the verified slot-0 token ride one width-2 forward at
            # positions [text_len-1, text_len] — the rewrite at text_len-1
            # is value-identical, and the query there cannot see the stale
            # speculative entry at text_len (absolute-position masking),
            # while the verified-token query reads the fresh write.  One
            # sequential draft call replaces two.
            h = be.embed_tokens(params, jnp.stack([prev, verified], axis=1))
            hidden, staged = be.decode_block(params, h, caches, pos0)
            caches = be.commit(staged, ones)
            logits = be.head_logits(params, hidden)    # (B, 2, K', V)
            tok = jnp.argmax(logits[:, 1, 0, :], axis=-1).astype(I32)
            props.append(tok)
            start = 2
        else:
            # catch-up: re-feed the committed token at text_len - 1 so the
            # cache covers the full verified stream (see module docstring);
            # its prediction is discarded — slot 0 is the verifier's token
            _, caches = step(prev, caches, pos0)
            tok = verified
            start = 1
        for i in range(start, k):
            tok, caches = step(tok, caches, inputs.text_len - 1 + i)
            props.append(tok)
        return jnp.stack(props, axis=1), {"caches": caches}

    def draft_steps_per_iter(self, block_k: int) -> int:
        """Sequential draft-model forwards issued per BPD iteration."""
        if self.carry_over and block_k > 1:
            return block_k - 1
        return block_k


policy_lib.register_policy("draft_model", lambda dec: policy_lib.DecodePolicy(
    DraftModelDrafter(),
    policy_lib._maybe_fused(policy_lib.ExactAcceptor(), dec),
    policy_lib._schedule_for(dec), name="draft_model"))
