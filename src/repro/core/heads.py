"""Combined scoring-and-proposal heads (paper §4, §6, Fig. 3).

A single feedforward layer with hidden size k·d_hidden and output size
k·d_model is inserted after the decoder output; a residual connection feeds
the decoder output into each of the k outputs; the original vocabulary
projection is applied identically to each output, yielding the logits of
p_1 .. p_k.

Per the paper's footnote 1, transforming p_1 through a learned head makes
the combined model's greedy output differ slightly from the base model's;
using the identity for p_1 (``identity_p1=True``, our default) keeps p_1
exactly the base model.  Either way, blockwise parallel decoding with exact
verification reproduces greedy decoding *of p_1* — the paper's guarantee.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def heads_init(key, cfg: ModelConfig, *, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    k = cfg.bpd_k
    dh = cfg.resolved_bpd_hidden
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, k, dh), dtype) * (d ** -0.5),
        "b1": jnp.zeros((k, dh), dtype),
        "w2": jax.random.normal(k2, (k, dh, d), dtype) * (dh ** -0.5) * 0.1,
        "b2": jnp.zeros((k, d), dtype),
    }


def heads_apply(p, cfg: ModelConfig, hidden, *, identity_p1: bool = True
                ) -> jnp.ndarray:
    """hidden: (..., d) -> (..., k, d) per-head decoder outputs."""
    h = jnp.einsum("...d,dkh->...kh", hidden, p["w1"].astype(hidden.dtype))
    h = jax.nn.relu(h + p["b1"].astype(hidden.dtype))
    out = jnp.einsum("...kh,khd->...kd", h, p["w2"].astype(hidden.dtype))
    out = out + p["b2"].astype(hidden.dtype) + hidden[..., None, :]
    if identity_p1:
        out = out.at[..., 0, :].set(hidden)
    return out


def head_apply_single(p, cfg: ModelConfig, hidden, head_idx: int, *,
                      identity_p1: bool = True) -> jnp.ndarray:
    """Only head ``head_idx`` (static int) — used by the paper's §6 training
    scheme (one random sub-loss per minibatch) to avoid materializing all k
    logit tensors."""
    if identity_p1 and head_idx == 0:
        return hidden
    w1 = p["w1"][:, head_idx].astype(hidden.dtype)
    b1 = p["b1"][head_idx].astype(hidden.dtype)
    w2 = p["w2"][head_idx].astype(hidden.dtype)
    b2 = p["b2"][head_idx].astype(hidden.dtype)
    h = jax.nn.relu(hidden @ w1 + b1)
    return h @ w2 + b2 + hidden


def head_apply_dynamic(p, cfg: ModelConfig, hidden, head_idx, *,
                       identity_p1: bool = True,
                       detach_residual: bool = False) -> jnp.ndarray:
    """Like head_apply_single but with a traced head index (training picks a
    random head per step inside jit).  identity_p1 is applied with a
    jnp.where on head_idx == 0.

    detach_residual stops the gradient through the ``+ hidden`` residual of
    the future heads (values unchanged).  Rationale: the residual feeds
    ``hidden`` straight into the shared vocab projection under a FUTURE-token
    loss, so its gradient coherently drags proj(hidden) — which IS p_1 —
    toward predicting t+i; at small scale this collapses p_1 within a few
    hundred steps (measured in EXPERIMENTS.md §Paper-claims).  Detaching it
    routes head gradients into the trunk only through the per-head FFN."""
    w1 = jnp.take(p["w1"], head_idx, axis=1).astype(hidden.dtype)   # (d, dh)
    b1 = jnp.take(p["b1"], head_idx, axis=0).astype(hidden.dtype)
    w2 = jnp.take(p["w2"], head_idx, axis=0).astype(hidden.dtype)   # (dh, d)
    b2 = jnp.take(p["b2"], head_idx, axis=0).astype(hidden.dtype)
    h = jax.nn.relu(hidden @ w1 + b1)
    res = jax.lax.stop_gradient(hidden) if detach_residual else hidden
    out = h @ w2 + b2 + res
    if identity_p1:
        out = jnp.where(head_idx == 0, hidden, out)
    return out
