"""Verification criteria (paper §3 exact match, §5.1 top-k, §5.2 distance,
§5.3 minimum block size).

Index convention for one BPD iteration (0-based within the block):
  * ``proposals[:, i]`` is the token proposed for absolute position j+1+i.
  * The verify forward feeds the k proposals; its p_1 output at block slot
    i covers context ŷ_{≤ j+1+i}, i.e. it is the greedy distribution for
    position j+2+i.
  * proposals[:, 0] was p_1's own argmax from the previous iteration — it is
    accepted unconditionally (paper: k̂ ≥ 1).
  * proposals[:, i] for i ≥ 1 is checked against the p_1 output at slot i-1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import DecodeConfig


def position_accepts(proposals: jnp.ndarray, p1_logits: jnp.ndarray,
                     dec: DecodeConfig) -> jnp.ndarray:
    """Per-position acceptance decisions (before the prefix AND).

    proposals : (B, k) int32
    p1_logits : (B, k, V) — p_1 logits at block slots 0..k-1
    returns   : (B, k) bool; column 0 is always True.
    """
    b, k = proposals.shape
    # slot i-1 verifies proposal i
    ver_logits = p1_logits[:, : k - 1, :]                      # (B, k-1, V)
    cand = proposals[:, 1:]                                    # (B, k-1)

    if dec.criterion == "exact":
        greedy = jnp.argmax(ver_logits, axis=-1)
        ok = cand == greedy
    elif dec.criterion == "topk":
        _, top_ids = jax.lax.top_k(ver_logits, dec.top_k)      # (B, k-1, topk)
        ok = jnp.any(top_ids == cand[..., None], axis=-1)
    elif dec.criterion == "distance":
        greedy = jnp.argmax(ver_logits, axis=-1)
        ok = jnp.abs(cand - greedy) <= dec.epsilon
    else:
        raise ValueError(dec.criterion)

    first = jnp.ones((b, 1), bool)
    return jnp.concatenate([first, ok], axis=1)


def accepted_block_size(accepts: jnp.ndarray, dec: DecodeConfig,
                        remaining: jnp.ndarray) -> jnp.ndarray:
    """k̂ per row: longest accepted prefix, with §5.3 minimum block size,
    clamped to the tokens still allowed (``remaining``, (B,) int32).

    accepts: (B, k) bool -> (B,) int32 in [1, k] (before remaining clamp).
    """
    prefix = jnp.cumprod(accepts.astype(jnp.int32), axis=1)
    khat = jnp.sum(prefix, axis=1)
    if dec.min_block > 1:
        k = accepts.shape[1]
        khat = jnp.maximum(khat, min(dec.min_block, k))
    return jnp.maximum(jnp.minimum(khat, remaining), 1)
