"""Verification criteria — REMOVED legacy entry points.

The paper's acceptance criteria (§3 exact match, §5.1 top-k, §5.2
distance, §5.3 minimum block size) live in ``core.policy`` as first-class
``Acceptor`` / ``BlockSchedule`` objects, composed into a ``DecodePolicy``
and resolved through ``repro.config.get_policy`` — the one blessed path.
The criterion-string wrappers that used to live here (``position_accepts``
/ ``accepted_block_size``) warned for a release cycle and are now hard
errors: every internal call site is on ``DecodePolicy``, and keeping two
entry points alive meant every acceptance change had to be proven twice.

Migration (the error message repeats it):

    from repro.config import get_policy
    policy = get_policy(dec)                       # or get_policy(dec, name)
    accepts = policy.acceptor.accepts(proposals, p1_logits)
    khat, state = policy.schedule.block_size(accepts, remaining, state)

Index convention for one BPD iteration (0-based within the block) — still
the contract of ``Acceptor.accepts``:
  * ``proposals[:, i]`` is the token proposed for absolute position j+1+i.
  * The verify forward feeds the k proposals; its p_1 output at block slot
    i covers context ŷ_{≤ j+1+i}, i.e. it is the greedy distribution for
    position j+2+i.
  * proposals[:, 0] was p_1's own argmax from the previous iteration — it is
    accepted unconditionally (paper: k̂ ≥ 1).
  * proposals[:, i] for i ≥ 1 is checked against the p_1 output at slot i-1.
"""
from __future__ import annotations


def _removed(name: str, call: str) -> ValueError:
    return ValueError(
        f"repro.core.verify.{name} was removed: the criterion-string API "
        f"is gone.  Resolve a DecodePolicy via repro.config.get_policy(dec)"
        f" and call {call} instead.")


def position_accepts(*_args, **_kwargs):
    """REMOVED — use ``get_policy(dec).acceptor.accepts(proposals,
    p1_logits)``."""
    raise _removed("position_accepts",
                   "policy.acceptor.accepts(proposals, p1_logits)")


def accepted_block_size(*_args, **_kwargs):
    """REMOVED — use ``get_policy(dec).schedule.block_size(accepts,
    remaining, state)``."""
    raise _removed("accepted_block_size",
                   "policy.schedule.block_size(accepts, remaining, state)")
