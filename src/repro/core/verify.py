"""Verification criteria (paper §3 exact match, §5.1 top-k, §5.2 distance,
§5.3 minimum block size) — legacy functional entry points, DEPRECATED.

The implementations live in ``core.policy`` as first-class ``Acceptor`` /
``BlockSchedule`` objects; these wrappers keep the original
criterion-string API (and the seed tests) working by resolving
``dec.criterion`` through the policy registry.  New code should construct
a ``DecodePolicy`` via ``repro.config.get_policy(dec)`` (see its docstring
for the blessed path) and call ``policy.acceptor.accepts(...)`` /
``policy.schedule.block_size(...)`` directly — both wrappers below emit a
``DeprecationWarning``.

Index convention for one BPD iteration (0-based within the block):
  * ``proposals[:, i]`` is the token proposed for absolute position j+1+i.
  * The verify forward feeds the k proposals; its p_1 output at block slot
    i covers context ŷ_{≤ j+1+i}, i.e. it is the greedy distribution for
    position j+2+i.
  * proposals[:, 0] was p_1's own argmax from the previous iteration — it is
    accepted unconditionally (paper: k̂ ≥ 1).
  * proposals[:, i] for i ≥ 1 is checked against the p_1 output at slot i-1.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.config import DecodeConfig
from repro.core.policy import StaticSchedule, resolve_policy

# Each shim warns once per process: decode loops call these per iteration,
# and a warning per call drowns the signal that should prompt migration.
_WARNED: set = set()


def _warn_once(name: str, message: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


def position_accepts(proposals: jnp.ndarray, p1_logits: jnp.ndarray,
                     dec: DecodeConfig) -> jnp.ndarray:
    """Per-position acceptance decisions (before the prefix AND).

    .. deprecated:: use ``get_policy(dec).acceptor.accepts(proposals,
       p1_logits)`` — the criterion-string shim will be removed.

    proposals : (B, k) int32
    p1_logits : (B, k, V) — p_1 logits at block slots 0..k-1
    returns   : (B, k) bool; column 0 is always True.
    """
    _warn_once(
        "position_accepts",
        "repro.core.verify.position_accepts is deprecated; resolve a "
        "DecodePolicy (repro.config.get_policy) and call "
        "policy.acceptor.accepts(proposals, p1_logits)")
    return resolve_policy(dec).acceptor.accepts(proposals, p1_logits)


def accepted_block_size(accepts: jnp.ndarray, dec: DecodeConfig,
                        remaining: jnp.ndarray) -> jnp.ndarray:
    """k̂ per row: longest accepted prefix, with §5.3 minimum block size,
    clamped to the tokens still allowed (``remaining``, (B,) int32).

    .. deprecated:: use ``get_policy(dec).schedule.block_size(accepts,
       remaining, state)`` — the criterion-string shim will be removed.

    accepts: (B, k) bool -> (B,) int32 in [1, k] (before remaining clamp).
    """
    _warn_once(
        "accepted_block_size",
        "repro.core.verify.accepted_block_size is deprecated; resolve a "
        "DecodePolicy (repro.config.get_policy) and call "
        "policy.schedule.block_size(accepts, remaining, state)")
    khat, _ = StaticSchedule(min_block=dec.min_block).block_size(
        accepts, remaining, ())
    return khat
