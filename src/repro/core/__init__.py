"""The paper's contribution: blockwise parallel decoding.

Import order matters: ``heads`` must load before ``decode`` (models.model
imports repro.core.heads while repro.core is still initializing).
"""
from repro.core import heads as heads  # noqa: F401  (must be first)
from repro.core.heads import (
    head_apply_dynamic,
    head_apply_single,
    heads_apply,
    heads_init,
)
from repro.core.policy import (
    AdaptiveSchedule,
    Acceptor,
    BlockSchedule,
    DecodePolicy,
    DistanceAcceptor,
    Drafter,
    DraftInputs,
    ExactAcceptor,
    HeadsDrafter,
    InputCopyDrafter,
    PolicyState,
    StaticSchedule,
    TopKAcceptor,
    TopKTreeDrafter,
    list_policies,
    register_policy,
    resolve_policy,
)
from repro.core.bundle import ModelBundle
from repro.core.draft import DraftModelDrafter
# removed criterion-string entry points: importable, raise with migration
from repro.core.verify import accepted_block_size, position_accepts
from repro.core.decode import (
    Backend,
    BPDState,
    bpd_decode,
    bpd_iteration,
    bpd_prefill_causal_lm,
    causal_lm_backend,
    greedy_decode,
    seq2seq_backend,
)
from repro.core.train import (
    lm_loss,
    loss_fn_for,
    masked_prediction_loss,
    seq2seq_loss,
    softmax_xent,
)

__all__ = [
    "Acceptor",
    "AdaptiveSchedule",
    "Backend",
    "BPDState",
    "BlockSchedule",
    "DecodePolicy",
    "DistanceAcceptor",
    "Drafter",
    "DraftInputs",
    "DraftModelDrafter",
    "ModelBundle",
    "ExactAcceptor",
    "HeadsDrafter",
    "InputCopyDrafter",
    "PolicyState",
    "StaticSchedule",
    "TopKAcceptor",
    "TopKTreeDrafter",
    "accepted_block_size",
    "list_policies",
    "register_policy",
    "resolve_policy",
    "bpd_decode",
    "bpd_iteration",
    "bpd_prefill_causal_lm",
    "causal_lm_backend",
    "greedy_decode",
    "head_apply_dynamic",
    "head_apply_single",
    "heads_apply",
    "heads_init",
    "lm_loss",
    "loss_fn_for",
    "masked_prediction_loss",
    "position_accepts",
    "seq2seq_backend",
    "seq2seq_loss",
    "softmax_xent",
]
