"""Sequence-level knowledge distillation (paper §6.2).

The paper distills with beam-4 decodes from a same-architecture teacher; in
this offline container we distill with greedy teacher decodes — the effect
the paper relies on ("greater predictability due to consistent mode breaking
from the teacher") is produced by any deterministic teacher decode.  The
deviation is recorded in DESIGN.md §9.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.core.decode import greedy_decode


def distill_lm_batches(teacher_params, cfg: ModelConfig, batches: Iterable[Dict],
                       *, prompt_len: int, max_new: int) -> List[Dict]:
    """Replace the continuation of each batch's token stream with the
    teacher's greedy continuation of its prompt prefix.

    Input batches: {"tokens": (B, S)}.  Output: same structure, where
    tokens[:, prompt_len:] come from the teacher.
    """
    dec = DecodeConfig(max_new_tokens=max_new, block_k=1, eos_id=-1)
    fn = jax.jit(lambda b: greedy_decode(teacher_params, cfg, dec, b))
    out = []
    for batch in batches:
        prompts = batch["tokens"][:, :prompt_len]
        toks, _ = fn({"tokens": prompts})
        s = batch["tokens"].shape[1]
        new = np.asarray(toks[:, :s])
        out.append(dict(batch, tokens=jnp.asarray(new)))
    return out
