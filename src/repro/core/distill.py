"""Sequence-level knowledge distillation (paper §6.2).

The paper distills with beam-4 decodes from a same-architecture teacher; in
this offline container we distill with greedy teacher decodes — the effect
the paper relies on ("greater predictability due to consistent mode breaking
from the teacher") is produced by any deterministic teacher decode.  The
deviation is recorded in DESIGN.md §9.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig, ModelConfig
from repro.core.decode import greedy_decode, greedy_decode_seq2seq


def distill_lm_batches(teacher_params, cfg: ModelConfig, batches: Iterable[Dict],
                       *, prompt_len: int, max_new: int) -> List[Dict]:
    """Replace the continuation of each batch's token stream with the
    teacher's greedy continuation of its prompt prefix.

    Input batches: {"tokens": (B, S)}.  Output: same structure, where
    tokens[:, prompt_len:] come from the teacher.
    """
    dec = DecodeConfig(max_new_tokens=max_new, block_k=1, eos_id=-1)
    fn = jax.jit(lambda b: greedy_decode(teacher_params, cfg, dec, b))
    out = []
    for batch in batches:
        s = batch["tokens"].shape[1]
        if prompt_len >= s:
            raise ValueError(
                f"distill_lm_batches: prompt_len={prompt_len} leaves no "
                f"positions to distill in a width-{s} batch")
        if prompt_len + max_new < s:
            # the decode buffer only covers prompt_len + max_new positions;
            # slicing toks[:, :s] past that would return zero-initialized
            # buffer padding as "teacher tokens" and silently poison the
            # distillation targets
            raise ValueError(
                f"distill_lm_batches: prompt_len + max_new = "
                f"{prompt_len + max_new} < batch width {s} — the teacher "
                f"decode cannot fill the stream; raise max_new to at least "
                f"{s - prompt_len}")
        prompts = batch["tokens"][:, :prompt_len]
        toks, _ = fn({"tokens": prompts})
        new = np.asarray(toks[:, :s])
        out.append(dict(batch, tokens=jnp.asarray(new)))
    return out


def distill_seq2seq_to_causal_batches(teacher_params, cfg: ModelConfig,
                                      src_batches: Iterable[np.ndarray], *,
                                      max_new: int, bos_id: int = 0
                                      ) -> List[Dict]:
    """Draft-student training data from a seq2seq teacher (paper §6.2 reuse).

    Greedy teacher decodes of each ``(B, Ss)`` source batch become
    BOS-prefixed *causal LM* token streams — the training set for a small
    decoder-only draft model (``core.draft.DraftModelDrafter``).  The draft
    model never sees the source; it learns the teacher's output
    distribution directly, which is exactly the "consistent mode breaking"
    property the paper credits distillation with — and the reason a tiny
    student can propose blocks the big model then verifies losslessly.

    Output batches: {"tokens": (B, 1 + max_new)} with ``tokens[:, 0] ==
    bos_id``, matching the decoder stream the drafter replays at decode
    time (BOS at position 0).
    """
    dec = DecodeConfig(max_new_tokens=max_new, block_k=1, eos_id=-1)
    fn = jax.jit(
        lambda b: greedy_decode_seq2seq(teacher_params, cfg, dec, b)[0])
    out = []
    for src in src_batches:
        toks = np.asarray(fn({"src": jnp.asarray(src)}))[:, :max_new]
        bos = np.full((toks.shape[0], 1), bos_id, np.int32)
        stream = np.concatenate([bos, toks.astype(np.int32)], axis=1)
        out.append({"tokens": jnp.asarray(stream)})
    return out
