"""Blockwise parallel decoding (paper §3–§5) and the greedy baseline.

The combined scoring/proposal formulation (§4) is used throughout: one model
invocation per iteration serves simultaneously as the verification of the
current block and the prediction of the next block, so decoding an output of
length m costs (m / mean-k̂) + 1 invocations instead of m.

The loop is a ``jax.lax.while_loop`` with fully static shapes; per-row
accepted block sizes k̂ let every batch row advance at its own rate.

Model-agnostic: a ``Backend`` bundles the embed / decode-block / head-logits
functions, with adapters for the decoder-only CausalLM and the paper's
encoder-decoder MT model.

Placement: every run-to-completion entry point (``bpd_decode``,
``greedy_decode``, ``bpd_decode_seq2seq``) is a thin wrapper over
``repro.serving.session.DecodeSession`` — the one sharding-aware driver
shared with the continuous-batching engine.  With no ``mesh``/``session``
argument the wrappers are trace-transparent (identical to the historical
eager paths, safe under an outer ``jax.jit``); with a mesh they run jitted
with explicit in/out shardings from ``repro.sharding.policy``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import DecodeConfig, ModelConfig
from repro.core import policy as policy_lib
from repro.core.policy import DecodePolicy, DraftInputs, PolicyState
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models import seq2seq as seq2seq_lib
from repro.models.layers import embed_apply


class Backend(NamedTuple):
    """Model functions the BPD engine needs."""

    embed_tokens: Callable          # (params, tokens (B,S)) -> (B,S,d)
    decode_block: Callable          # (params, h, caches, length) -> (hidden, staged_caches)
    commit: Callable                # (caches, khat) -> caches
    head_logits: Callable           # (params, hidden) -> (..., k, V)


def causal_lm_backend(cfg: ModelConfig, *, kv_chunk: int = 0) -> Backend:
    return Backend(
        embed_tokens=lambda p, t: embed_apply(p["embed"], t).astype(cfg.compute_dtype),
        decode_block=lambda p, h, c, ln, tree=None: model_lib.decode_block_step(
            p, cfg, h, c, ln, kv_chunk=kv_chunk, tree=tree),
        commit=lambda c, kh: model_lib.commit_caches(cfg, c, kh),
        head_logits=lambda p, h: model_lib.all_head_logits(p, cfg, h),
    )


def seq2seq_backend(cfg: ModelConfig, enc_kvs, enc_mask=None) -> Backend:
    return Backend(
        embed_tokens=lambda p, t: embed_apply(p["embed"], t).astype(cfg.compute_dtype),
        decode_block=lambda p, h, c, ln, tree=None: seq2seq_lib.decode_block_step(
            p, cfg, h, c, ln, enc_kvs, enc_mask, tree=tree),
        commit=lambda c, kh: model_lib.commit_caches(cfg, c, kh),
        head_logits=lambda p, h: seq2seq_lib.all_head_logits(p, cfg, h),
    )


# ---------------------------------------------------------------------------
# One BPD iteration (predict+verify merged — paper §4, Fig. 2)
# ---------------------------------------------------------------------------


class BPDState(NamedTuple):
    tokens: jnp.ndarray        # (B, buf) generated+prompt token buffer
    text_len: jnp.ndarray      # (B,) tokens valid in the buffer
    proposals: jnp.ndarray     # (B, k) next block proposals
    caches: Any                # per-layer cache pytree
    finished: jnp.ndarray      # (B,) bool
    iters: jnp.ndarray         # () int32 — model invocations in the loop
    generated: jnp.ndarray     # (B,) int32 — accepted tokens so far
    policy_state: PolicyState = PolicyState()  # loop-carried drafter/schedule


def _freeze_rows(frozen, old_tree, new_tree):
    """Keep the old policy-state rows where ``frozen`` is True.  Policy
    state leaves are batch-leading (B, ...) arrays by contract."""
    def leaf(old, new):
        mask = frozen.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(mask, old, new)

    return jax.tree_util.tree_map(leaf, old_tree, new_tree)


def bpd_iteration(params, cfg: ModelConfig, dec: DecodeConfig,
                  backend: Backend, state: BPDState, *,
                  prefix_offset: int, max_new, active=None,
                  policy: Optional[DecodePolicy] = None,
                  aux_params=None) -> BPDState:
    """One combined predict/verify/accept step.

    max_new : int or (B,) int32 — per-row generation budget (the serving
              engine gives every slot its own request budget).
    active  : optional (B,) bool — rows with ``active == False`` are slots
              holding no request (continuous batching): they accept nothing,
              write nothing, and keep their state frozen exactly like
              finished rows.
    policy  : decode policy (drafter × acceptor × block schedule); None
              resolves ``dec.policy`` / the legacy ``dec.criterion`` alias.
    aux_params : optional {bundle name: params} of the session's auxiliary
              ``ModelBundle``s, exposed to the drafter via
              ``DraftInputs.aux`` (e.g. the draft model's parameters for
              the ``draft_model`` policy).
    """
    pol = policy_lib.resolve_policy(dec, policy)
    block_k = dec.block_k or cfg.bpd_k
    b = state.proposals.shape[0]
    pos_len = state.text_len + prefix_offset
    topo = pol.drafter.tree_topology(block_k)
    if topo is not None and getattr(pol.schedule, "min_block", 1) > 1:
        raise NotImplementedError(
            "tree verification with min_block > 1 would commit tokens "
            "beyond the accepted root-to-leaf path")

    # ---- parallel scoring of the k proposals (verify ∧ next-predict) ------
    h = backend.embed_tokens(params, state.proposals)
    if topo is None:
        hidden, staged = backend.decode_block(params, h, state.caches,
                                              pos_len)
    else:
        hidden, staged = backend.decode_block(params, h, state.caches,
                                              pos_len, tree=topo)
    logits = backend.head_logits(params, hidden)            # (B, k, K, V)
    logits = logits[:, :, :block_k, :]
    p1_logits = logits[:, :, 0, :]

    # ---- verify ------------------------------------------------------------
    if topo is None:
        accepts = pol.acceptor.accepts(state.proposals, p1_logits)
        commit_tokens = state.proposals
        path_nodes = None
    else:
        # Tree verify: node n is checked by p_1 at its PARENT node (each
        # node's logits are ancestor-chain-conditioned thanks to the tree
        # mask).  Permuting the logits by parent turns the tree accept into
        # the ordinary chain accept — including the fused-kernel path; the
        # trailing permutation slot only feeds the always-true column 0.
        perm = tuple(topo.parents[1:]) + (0,)
        acc_nodes = pol.acceptor.accepts(state.proposals,
                                         p1_logits[:, perm, :])   # (B, N)
        reach = [acc_nodes[:, 0]]                  # root: always accepted
        for n in range(1, block_k):
            reach.append(acc_nodes[:, n] & reach[topo.parents[n]])
        reach = jnp.stack(reach, axis=1)                          # (B, N)
        depth = jnp.asarray(topo.depths)
        path_len = jnp.max(jnp.where(reach, depth[None, :] + 1, 0), axis=1)
        # deepest reached node; argmax tie-break = lowest node id
        chosen = jnp.argmax(jnp.where(reach, depth[None, :], -1), axis=1)
        path_nodes = jnp.asarray(topo.path_matrix)[chosen]        # (B, D+1)
        if path_nodes.shape[1] < block_k:
            path_nodes = jnp.pad(
                path_nodes, ((0, 0), (0, block_k - path_nodes.shape[1])),
                constant_values=-1)
        commit_tokens = jnp.take_along_axis(
            state.proposals, jnp.clip(path_nodes, 0, block_k - 1), axis=1)
        accepts = (jnp.arange(block_k, dtype=jnp.int32)[None, :]
                   < path_len[:, None])            # chain-shaped for schedule
    remaining = jnp.maximum(max_new - state.generated, 1)
    khat, sched_state = pol.schedule.block_size(
        accepts, remaining, state.policy_state.schedule)    # (B,) in [1, k]
    frozen = state.finished if active is None else (state.finished | ~active)
    khat = jnp.where(frozen, 0, khat)

    # ---- EOS handling -------------------------------------------------------
    if dec.eos_id >= 0:
        pos_in_block = jnp.arange(block_k, dtype=jnp.int32)[None, :]
        iseos = (commit_tokens == dec.eos_id) & (pos_in_block < khat[:, None])
        has_eos = jnp.any(iseos, axis=1)
        first_eos = jnp.argmax(iseos, axis=1)
        khat = jnp.where(has_eos, first_eos + 1, khat)
    else:
        has_eos = jnp.zeros((b,), bool)

    # ---- accept -------------------------------------------------------------
    widx = state.text_len[:, None] + jnp.arange(block_k, dtype=jnp.int32)[None, :]
    wmask = jnp.arange(block_k, dtype=jnp.int32)[None, :] < khat[:, None]

    def row_write(buf, idx, vals, m):
        old = buf[idx]
        return buf.at[idx].set(jnp.where(m, vals, old))

    tokens = jax.vmap(row_write)(state.tokens, widx, commit_tokens, wmask)
    caches = backend.commit(staged, khat)
    if topo is not None:
        # move the accepted path's KV into chain slots so later iterations
        # see an ordinary committed chain
        caches = model_lib.commit_tree_path(cfg, caches, path_nodes, khat,
                                            pos_len, block_k)
    generated = state.generated + khat
    finished = state.finished | has_eos | (generated >= max_new)

    # ---- next-block proposals (drafted from this same invocation) ----------
    # the committed token at the new text_len - 1 (the last accepted slot;
    # model-backed drafters re-feed it to keep their own cache in sync)
    prev_token = jnp.take_along_axis(
        commit_tokens, jnp.maximum(khat - 1, 0)[:, None], axis=1)[:, 0]
    if topo is None:
        slot = jnp.maximum(khat - 1, 0)
    else:
        # the accepted slot is the path's node at depth k̂-1 (root for k̂=0)
        slot = jnp.take_along_axis(
            path_nodes, jnp.maximum(khat - 1, 0)[:, None], axis=1)[:, 0]
        slot = jnp.maximum(slot, 0)
    draft_in = DraftInputs(
        logits=logits, khat=khat, slot=slot,
        text_len=state.text_len + khat, old_proposals=commit_tokens,
        prev_token=prev_token, aux=aux_params or {})
    proposals, draft_state = pol.drafter.draft(
        draft_in, state.policy_state.drafter)
    proposals = jnp.where(frozen[:, None], state.proposals, proposals)
    policy_state = PolicyState(
        drafter=_freeze_rows(frozen, state.policy_state.drafter, draft_state),
        schedule=_freeze_rows(frozen, state.policy_state.schedule,
                              sched_state))

    return BPDState(
        tokens=tokens,
        text_len=state.text_len + khat,
        proposals=proposals,
        caches=caches,
        finished=finished,
        iters=state.iters + 1,
        generated=generated,
        policy_state=policy_state,
    )


def initial_draft(pol: DecodePolicy, head_logits: jnp.ndarray,
                  text_len: jnp.ndarray, block_k: int, state, *,
                  prev_token=None, aux_params=None):
    """Draft the FIRST block from a prefill's head logits.

    ``head_logits`` is (B, K, V) at the last context position — presented to
    the drafter as a single pseudo block slot (slot 0, k̂ = 1), so the same
    ``draft`` method covers prefill and loop iterations.  For
    ``HeadsDrafter`` this reduces exactly to the historical
    ``argmax(head_logits)``; source-drafting policies get to draft from
    their own state immediately instead of spending one iteration on weak
    head proposals.

    ``prev_token`` is the (B,) committed token at ``text_len - 1`` (the
    last prompt token; BOS for seq2seq) and ``aux_params`` the auxiliary
    bundle params — both only consumed by model-backed drafters.
    """
    b = head_logits.shape[0]
    if prev_token is None:
        prev_token = jnp.zeros((b,), jnp.int32)
    din = DraftInputs(
        logits=head_logits[:, None, :block_k, :],
        khat=jnp.ones((b,), jnp.int32),
        slot=jnp.zeros((b,), jnp.int32),
        text_len=jnp.broadcast_to(jnp.asarray(text_len, jnp.int32), (b,)),
        old_proposals=jnp.zeros((b, block_k), jnp.int32),
        prev_token=jnp.asarray(prev_token, jnp.int32),
        aux=aux_params or {})
    proposals, new_state = pol.drafter.draft(din, state)
    return proposals.astype(jnp.int32), new_state


# ---------------------------------------------------------------------------
# Shared run-to-completion machinery (driven by serving.session.DecodeSession)
# ---------------------------------------------------------------------------


def decode_stats(final) -> Dict:
    """Decode statistics shared by every run-to-completion entry point.

    ``final`` is any loop-final state with ``iters`` / ``generated`` /
    ``text_len`` fields (``BPDState`` or ``GreedyState``).
    ``mean_accepted`` is the paper's headline k̂ metric; ``invocations``
    counts model calls (prefill + loop iterations).
    """
    b = final.generated.shape[0]
    return {
        "iterations": final.iters,
        "generated": final.generated,
        "mean_accepted": jnp.sum(final.generated)
        / jnp.maximum(final.iters, 1) / b,
        "invocations": final.iters + 1,
        "text_len": final.text_len,
    }


def bpd_prefill_causal_lm(params, cfg: ModelConfig, dec: DecodeConfig,
                          batch: Dict, *, max_new: int, kv_chunk: int = 0,
                          policy: Optional[DecodePolicy] = None,
                          aux_params=None):
    """Prefill the caches from the prompt and produce the first proposals."""
    pol = policy_lib.resolve_policy(dec, policy)
    block_k = dec.block_k or cfg.bpd_k
    prompt = batch["tokens"]
    b, prompt_len = prompt.shape
    prefix = model_lib.prefix_len(cfg, batch)
    context_len = prefix + prompt_len + max_new
    # dec.cache_backend selects the KV layout; run-to-completion decode
    # uses the identity-mapped (allocator-free) paged pool
    caches = model_lib.init_caches(cfg, b, context_len, block_k,
                                   backend=cache_lib.get_backend(dec))

    h = model_lib.embed_inputs(params, cfg, batch)          # (B, prefix+P, d)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    hidden, _, caches = model_lib.forward_hidden(
        params, cfg, h, positions=positions, caches=caches, kv_chunk=kv_chunk,
        moe_full_capacity=True)
    last = hidden[:, -1, :]                                 # context = full prompt
    logits = model_lib.all_head_logits(params, cfg, last)   # (B, K, V)
    ps = pol.init_state(cfg, dec, batch, b, aux=aux_params or {})
    proposals, dstate = initial_draft(pol, logits, prompt_len, block_k,
                                      ps.drafter,
                                      prev_token=prompt[:, -1],
                                      aux_params=aux_params)

    buf = prompt_len + max_new + block_k
    tokens = jnp.zeros((b, buf), jnp.int32)
    tokens = tokens.at[:, :prompt_len].set(prompt)
    state = BPDState(
        tokens=tokens,
        text_len=jnp.full((b,), prompt_len, jnp.int32),
        proposals=proposals,
        caches=caches,
        finished=jnp.zeros((b,), bool),
        iters=jnp.zeros((), jnp.int32),
        generated=jnp.zeros((b,), jnp.int32),
        policy_state=ps._replace(drafter=dstate),
    )
    return state, prefix


def _bpd_decode_impl(params, cfg: ModelConfig, dec: DecodeConfig, batch: Dict,
                     row_budget=None, *, backend: Optional[Backend] = None,
                     kv_chunk: int = 0,
                     constrain: Optional[Callable] = None,
                     policy: Optional[DecodePolicy] = None,
                     aux_params=None) -> Tuple[jnp.ndarray, Dict]:
    """Prefill + while_loop for the decoder-only model.

    ``constrain`` (set by a mesh-backed ``DecodeSession``) applies sharding
    constraints to the loop-carried state so GSPMD keeps it partitioned
    through the whole loop.  ``aux_params`` are the auxiliary bundle params
    (loop-invariant, closed over by the body like the primary params).
    """
    max_new = dec.max_new_tokens
    pol = policy_lib.resolve_policy(dec, policy)
    state, prefix = bpd_prefill_causal_lm(params, cfg, dec, batch,
                                          max_new=max_new, kv_chunk=kv_chunk,
                                          policy=pol, aux_params=aux_params)
    if constrain is not None:
        state = constrain(state)
    be = backend or causal_lm_backend(cfg, kv_chunk=kv_chunk)
    budget = max_new if row_budget is None else row_budget

    def cond(s: BPDState):
        return (~jnp.all(s.finished)) & (s.iters < max_new)

    def body(s: BPDState):
        return bpd_iteration(params, cfg, dec, be, s,
                             prefix_offset=prefix, max_new=budget, policy=pol,
                             aux_params=aux_params)

    final = jax.lax.while_loop(cond, body, state)
    return final.tokens, decode_stats(final)


def _session_for(params, cfg, dec, *, mesh=None, session=None, kv_chunk=0,
                 backend=None, policy=None, bundles=None):
    """Resolve the DecodeSession a wrapper should run through.

    When ``session`` is given it takes precedence — its (possibly
    mesh-placed) params are used, so the ``params`` argument is ignored by
    design; cfg/dec/policy however must MATCH the session's, or the caller
    would silently decode under a different geometry/criterion than
    requested.  Otherwise a lightweight local session is built — with
    mesh=None that is trace-transparent and allocation-free.
    """
    if session is not None:
        if session.cfg is not cfg and session.cfg != cfg:
            raise ValueError(
                f"session was built for model config "
                f"{session.cfg.name!r}, called with {cfg.name!r}: build "
                f"one DecodeSession per model")
        if session.dec != dec:
            raise ValueError(
                f"session was built with {session.dec}, called with "
                f"{dec}: a session's decode config is fixed at "
                f"construction — build a new session (or call its "
                f"methods directly)")
        if bundles is not None:
            raise ValueError(
                "bundles are fixed at DecodeSession construction — build "
                "the session with bundles= instead of passing them to the "
                "decode wrapper")
        if policy is not None and \
                policy_lib.resolve_policy(dec, policy).bind(
                    session.bundles, cfg) != session.policy:
            raise ValueError(
                f"session was built with policy "
                f"{session.policy.name!r}, called with {policy!r}: a "
                f"session's decode policy is fixed at construction — "
                f"build a new session")
        return session
    from repro.serving.session import DecodeSession

    return DecodeSession(params, cfg, dec, mesh=mesh, kv_chunk=kv_chunk,
                         backend=backend, policy=policy, bundles=bundles)


def bpd_decode(params, cfg: ModelConfig, dec: DecodeConfig, batch: Dict, *,
               backend: Optional[Backend] = None, kv_chunk: int = 0,
               max_new_rows: Optional[jnp.ndarray] = None,
               mesh=None, session=None, policy=None, bundles=None
               ) -> Tuple[jnp.ndarray, Dict]:
    """Full blockwise parallel decode for the decoder-only model.

    Returns (tokens (B, buf), stats).  stats["mean_accepted"] is the paper's
    headline metric; stats["invocations"] counts model calls (prefill + loop).

    max_new_rows: optional (B,) int32 per-row budgets ≤ dec.max_new_tokens —
    rows stop at their own budget (static-batch serving baseline), while the
    buffers stay sized by dec.max_new_tokens.

    policy: a registered policy name or ``DecodePolicy`` object overriding
    ``dec.policy`` / the legacy ``dec.criterion`` alias for this decode.

    mesh / session: run through a sharding-aware ``DecodeSession`` — params
    placed with ``param_shardings``, the loop jitted with explicit in/out
    shardings.  Default (both None) is the single-device eager path.
    ``mesh=`` is one-shot: it builds (and discards) a fresh session per
    call, re-placing params and recompiling — callers decoding more than
    once should build a ``DecodeSession`` and pass ``session=`` so the
    placement and per-geometry jit cache persist across calls.

    bundles: optional {name: core.bundle.ModelBundle} of auxiliary models
    (e.g. ``{"draft": ModelBundle(draft_params, draft_cfg)}`` for the
    ``draft_model`` policy); fixed at session construction.
    """
    sess = _session_for(params, cfg, dec, mesh=mesh, session=session,
                        kv_chunk=kv_chunk, backend=backend, policy=policy,
                        bundles=bundles)
    return sess.decode(batch, max_new_rows=max_new_rows)


# ---------------------------------------------------------------------------
# Seq2seq decode (the paper's MT experiments): encode once, BPD the decoder.
# ---------------------------------------------------------------------------


def _bpd_decode_seq2seq_impl(params, cfg: ModelConfig, dec: DecodeConfig,
                             batch: Dict,
                             constrain: Optional[Callable] = None,
                             policy: Optional[DecodePolicy] = None,
                             aux_params=None) -> Tuple[jnp.ndarray, Dict]:
    """batch: {"src": (B, Ss)}.  Decoder stream: BOS (token 0) + output."""
    max_new = dec.max_new_tokens
    pol = policy_lib.resolve_policy(dec, policy)
    block_k = dec.block_k or cfg.bpd_k
    src = batch["src"]
    b = src.shape[0]
    enc_kvs, enc_mask = seq2seq_lib.encode(params, cfg, src)
    be = seq2seq_backend(cfg, enc_kvs, enc_mask)

    context_len = 1 + max_new
    caches = seq2seq_lib.init_caches(cfg, b, context_len, block_k)
    bos = jnp.zeros((b, 1), jnp.int32)
    hidden, caches = seq2seq_lib.forward_hidden(params, cfg, bos, enc_kvs,
                                                enc_mask=enc_mask,
                                                caches=caches)
    logits = seq2seq_lib.all_head_logits(params, cfg, hidden[:, -1, :])
    ps = pol.init_state(cfg, dec, batch, b, aux=aux_params or {})
    # the committed token at text_len - 1 is BOS (decoder position 0)
    proposals, dstate = initial_draft(pol, logits, 1, block_k, ps.drafter,
                                      prev_token=bos[:, 0],
                                      aux_params=aux_params)

    buf = 1 + max_new + block_k
    tokens = jnp.zeros((b, buf), jnp.int32)
    state = BPDState(
        tokens=tokens,
        text_len=jnp.ones((b,), jnp.int32),  # BOS occupies position 0
        proposals=proposals,
        caches=caches,
        finished=jnp.zeros((b,), bool),
        iters=jnp.zeros((), jnp.int32),
        generated=jnp.zeros((b,), jnp.int32),
        policy_state=ps._replace(drafter=dstate),
    )
    if constrain is not None:
        state = constrain(state)

    def cond(s: BPDState):
        return (~jnp.all(s.finished)) & (s.iters < max_new)

    def body(s: BPDState):
        return bpd_iteration(params, cfg, dec, be, s, prefix_offset=0,
                             max_new=max_new, policy=pol,
                             aux_params=aux_params)

    final = jax.lax.while_loop(cond, body, state)
    return final.tokens[:, 1:], decode_stats(final)  # strip BOS


def bpd_decode_seq2seq(params, cfg: ModelConfig, dec: DecodeConfig,
                       batch: Dict, *, mesh=None, session=None, policy=None,
                       bundles=None) -> Tuple[jnp.ndarray, Dict]:
    """batch: {"src": (B, Ss)}.  Decoder stream: BOS (token 0) + output.

    ``policy`` / ``bundles`` — see ``bpd_decode``; the seq2seq path
    additionally supports source-drafting policies (``input_copy``), whose
    drafter state is initialized from ``batch["src"]``, and the
    ``draft_model`` policy, whose small causal draft LM runs over the
    decoder token stream.
    """
    sess = _session_for(params, cfg, dec, mesh=mesh, session=session,
                        policy=policy, bundles=bundles)
    return sess.decode_seq2seq(batch)


def greedy_decode_seq2seq(params, cfg: ModelConfig, dec: DecodeConfig,
                          batch: Dict, *, mesh=None, session=None
                          ) -> Tuple[jnp.ndarray, Dict]:
    """Greedy baseline via BPD machinery with block size 1 (p_1 only)."""
    if session is not None:
        if (session.dec.block_k or session.cfg.bpd_k) != 1:
            raise ValueError(
                f"greedy_decode_seq2seq needs a session built with "
                f"block_k=1, got block_k="
                f"{session.dec.block_k or session.cfg.bpd_k}: reusing a "
                f"BPD session would report blockwise iteration stats as "
                f"the greedy baseline")
        return session.decode_seq2seq(batch)
    return bpd_decode_seq2seq(params, cfg, dec.replace(block_k=1), batch,
                              mesh=mesh)


# ---------------------------------------------------------------------------
# Greedy baseline (paper §2) — identical machinery with block size 1,
# scoring only p_1 (no head overhead), for fair wall-clock comparisons.
# ---------------------------------------------------------------------------


class GreedyState(NamedTuple):
    tokens: jnp.ndarray        # (B, buf) prompt+output token buffer
    text_len: jnp.ndarray      # (B,) tokens valid in the buffer
    tok: jnp.ndarray           # (B,) next token to commit
    caches: Any                # per-layer cache pytree
    finished: jnp.ndarray      # (B,) bool
    iters: jnp.ndarray         # () int32 — decode steps taken
    generated: jnp.ndarray     # (B,) int32 — committed tokens so far


def _greedy_decode_impl(params, cfg: ModelConfig, dec: DecodeConfig,
                        batch: Dict, *, kv_chunk: int = 0,
                        constrain: Optional[Callable] = None
                        ) -> Tuple[jnp.ndarray, Dict]:
    max_new = dec.max_new_tokens
    prompt = batch["tokens"]
    b, prompt_len = prompt.shape
    prefix = model_lib.prefix_len(cfg, batch)
    context_len = prefix + prompt_len + max_new
    caches = model_lib.init_caches(cfg, b, context_len, 1,
                                   backend=cache_lib.get_backend(dec))

    h = model_lib.embed_inputs(params, cfg, batch)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    hidden, _, caches = model_lib.forward_hidden(
        params, cfg, h, positions=positions, caches=caches, kv_chunk=kv_chunk,
        moe_full_capacity=True)
    logits = model_lib.base_logits(params, cfg, hidden[:, -1, :])
    next_tok = jnp.argmax(logits, axis=-1)                   # (B,)

    buf = prompt_len + max_new + 1
    tokens = jnp.zeros((b, buf), jnp.int32).at[:, :prompt_len].set(prompt)
    state = GreedyState(
        tokens=tokens,
        text_len=jnp.full((b,), prompt_len, jnp.int32),
        tok=next_tok.astype(jnp.int32),
        caches=caches,
        finished=jnp.zeros((b,), bool),
        iters=jnp.zeros((), jnp.int32),
        generated=jnp.zeros((b,), jnp.int32),
    )
    if constrain is not None:
        state = constrain(state)

    def cond(s: GreedyState):
        return (~jnp.all(s.finished)) & (s.iters < max_new)

    def body(s: GreedyState):
        adv = (~s.finished).astype(jnp.int32)
        tokens = jax.vmap(lambda bu, i, v, m: bu.at[i].set(
            jnp.where(m, v, bu[i])))(s.tokens, s.text_len, s.tok, ~s.finished)
        h = embed_apply(params["embed"], s.tok[:, None]).astype(cfg.compute_dtype)
        hidden, staged = model_lib.decode_block_step(
            params, cfg, h, s.caches, s.text_len + prefix, kv_chunk=kv_chunk)
        caches = model_lib.commit_caches(cfg, staged, adv)
        logits = model_lib.base_logits(params, cfg, hidden[:, 0, :])
        new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        text_len = s.text_len + adv
        finished = s.finished
        if dec.eos_id >= 0:
            finished = finished | (s.tok == dec.eos_id)
        finished = finished | (text_len - prompt_len >= max_new)
        tok = jnp.where(finished, s.tok, new_tok)
        return GreedyState(tokens=tokens, text_len=text_len, tok=tok,
                           caches=caches, finished=finished,
                           iters=s.iters + 1, generated=s.generated + adv)

    final = jax.lax.while_loop(cond, body, state)
    return final.tokens, decode_stats(final)


def greedy_decode(params, cfg: ModelConfig, dec: DecodeConfig, batch: Dict, *,
                  kv_chunk: int = 0, mesh=None, session=None
                  ) -> Tuple[jnp.ndarray, Dict]:
    sess = _session_for(params, cfg, dec, mesh=mesh, session=session,
                        kv_chunk=kv_chunk)
    return sess.greedy(batch)
