"""Training for the combined scoring/proposal model (paper §6).

Key paper mechanics reproduced:

* **Random sub-loss selection** — the mean of the k head cross-entropies is
  too memory-hungry at training time, so one head is sampled uniformly per
  minibatch, giving an unbiased estimate of the full loss.  (``head_loss =
  "mean"`` is also provided for small models / ablations.)
* **Frozen vs fine-tuned base (§6.1)** — with ``freeze_base=True`` the trunk
  hidden states are stop-gradient'ed and the optimizer masks every parameter
  outside ``bpd_heads``, so the original model's quality is exactly retained.
  Head 0 is the identity (p_1 = base model), so frozen training samples the
  head index from {1..k-1}.
* Aux losses: MoE load-balance + router-z (weighted per config), logit
  z-loss, optional label smoothing.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.core.heads import head_apply_dynamic
from repro.models import model as model_lib
from repro.models import seq2seq as seq2seq_lib


def softmax_xent(logits, targets, *, mask=None, label_smoothing=0.0,
                 z_loss=0.0):
    """logits (..., V), targets (...,) int32; returns (loss, metrics)."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    logp_t = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0] - logz
    nll = -logp_t
    if label_smoothing:
        smooth = -(jnp.mean(logits, axis=-1) - logz)
        nll = (1 - label_smoothing) * nll + label_smoothing * smooth
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if mask is None:
        mask = jnp.ones(nll.shape, jnp.float32)
    mask = jnp.broadcast_to(mask.astype(jnp.float32), nll.shape)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    return loss, {"nll": loss, "accuracy": acc}


def _head_logits_for(params, cfg: ModelConfig, hidden, head_idx,
                     freeze_base: bool, detach_residual: bool = False):
    """Logits of one (traced-index) head over the trunk hidden states."""
    if freeze_base:
        hidden = jax.lax.stop_gradient(hidden)
    if not cfg.bpd_enabled:          # plain LM pre-training (no heads yet)
        return model_lib.project_vocab(params, cfg, hidden)
    h = head_apply_dynamic(params["bpd_heads"], cfg, hidden, head_idx,
                           identity_p1=cfg.bpd_identity_p1,
                           detach_residual=detach_residual)
    return model_lib.project_vocab(params, cfg, h)


def _sample_head(key, cfg: ModelConfig, tc: TrainConfig):
    k = cfg.bpd_k
    if tc.head_loss == "mean" or not cfg.bpd_enabled:
        return None
    lo = 1 if (tc.freeze_base and cfg.bpd_identity_p1) else 0
    return jax.random.randint(key, (), lo, k)


# ---------------------------------------------------------------------------
# Decoder-only LM loss
# ---------------------------------------------------------------------------


def lm_loss(params, cfg: ModelConfig, tc: TrainConfig, batch: Dict, key
            ) -> Tuple[jnp.ndarray, Dict]:
    """batch: tokens (B, S) [+ patch_embeds / frame_embeds per modality].

    Head i (0-based) predicts position t+1+i from the hidden state at t.
    """
    tokens = batch["tokens"]
    h = model_lib.embed_inputs(params, cfg, batch)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    hidden, moe_metrics, _ = model_lib.forward_hidden(params, cfg, h,
                                                      positions=positions)
    prefix = model_lib.prefix_len(cfg, batch)
    hidden = hidden[:, prefix:, :]                      # text positions only
    b, s, _ = hidden.shape

    if cfg.bpd_enabled and tc.head_loss == "random":
        head_idx = _sample_head(key, cfg, tc)
        logits = _head_logits_for(params, cfg, hidden, head_idx,
                                  tc.freeze_base, tc.detach_head_residual)
        # targets for head i at position t: tokens[t+1+i]
        offs = head_idx + 1
        tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + offs
        tpos_c = jnp.minimum(tpos, s - 1)
        targets = jnp.take_along_axis(tokens, tpos_c, axis=1)
        mask = (tpos < s).astype(jnp.float32)
        loss, m = softmax_xent(logits, targets, mask=mask,
                               label_smoothing=tc.label_smoothing,
                               z_loss=tc.z_loss)
        m["head_idx"] = head_idx.astype(jnp.float32)
    else:
        # mean over heads (small-model / oracle mode) or plain LM (no BPD)
        nheads = cfg.bpd_k if cfg.bpd_enabled else 1
        total, m = 0.0, {}
        for i in range(nheads):
            logits = _head_logits_for(params, cfg, hidden, jnp.asarray(i),
                                      tc.freeze_base,
                                      tc.detach_head_residual)
            tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + (i + 1)
            tpos_c = jnp.minimum(tpos, s - 1)
            targets = jnp.take_along_axis(tokens, tpos_c, axis=1)
            mask = (tpos < s).astype(jnp.float32)
            li, mi = softmax_xent(logits, targets, mask=mask,
                                  label_smoothing=tc.label_smoothing,
                                  z_loss=tc.z_loss)
            total = total + li / nheads
            if i == 0:
                m = mi
        loss = total

    for name, val in moe_metrics.items():
        m[name] = val
        if name == "moe_aux_loss":
            loss = loss + cfg.router_aux_coef * val
        if name == "moe_z_loss":
            loss = loss + cfg.router_z_coef * val
    m["loss"] = loss
    return loss, m


# ---------------------------------------------------------------------------
# Encoder-only masked prediction (hubert)
# ---------------------------------------------------------------------------


def masked_prediction_loss(params, cfg: ModelConfig, tc: TrainConfig,
                           batch: Dict, key) -> Tuple[jnp.ndarray, Dict]:
    """batch: frame_embeds (B,S,d), mask (B,S) bool, targets (B,S) int32."""
    h = model_lib.embed_inputs(params, cfg, batch)      # applies mask_embed
    hidden, _, _ = model_lib.forward_hidden(params, cfg, h, bidirectional=True)
    logits = model_lib.project_vocab(params, cfg, hidden)
    loss, m = softmax_xent(logits, batch["targets"],
                           mask=batch["mask"].astype(jnp.float32),
                           z_loss=tc.z_loss)
    m["loss"] = loss
    return loss, m


# ---------------------------------------------------------------------------
# Seq2seq (paper MT) loss
# ---------------------------------------------------------------------------


def seq2seq_loss(params, cfg: ModelConfig, tc: TrainConfig, batch: Dict, key
                 ) -> Tuple[jnp.ndarray, Dict]:
    """batch: src (B,Ss), tgt (B,St); teacher forcing with BOS-shifted tgt."""
    src, tgt = batch["src"], batch["tgt"]
    enc_kvs, _ = seq2seq_lib.encode(params, cfg, src)
    bos = jnp.zeros((tgt.shape[0], 1), tgt.dtype)
    dec_in = jnp.concatenate([bos, tgt[:, :-1]], axis=1)
    hidden, _ = seq2seq_lib.forward_hidden(params, cfg, dec_in, enc_kvs)
    b, s, _ = hidden.shape

    if cfg.bpd_enabled and tc.head_loss == "random":
        head_idx = _sample_head(key, cfg, tc)
        logits = _head_logits_for(params, cfg, hidden, head_idx,
                                  tc.freeze_base, tc.detach_head_residual)
        offs = head_idx  # dec_in position t sees tgt[<t]; head i predicts tgt[t+i]
        tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + offs
        tpos_c = jnp.minimum(tpos, s - 1)
        targets = jnp.take_along_axis(tgt, tpos_c, axis=1)
        mask = (tpos < s).astype(jnp.float32)
        if "tgt_mask" in batch:
            mask = mask * jnp.take_along_axis(
                batch["tgt_mask"].astype(jnp.float32), tpos_c, axis=1)
        loss, m = softmax_xent(logits, targets, mask=mask,
                               label_smoothing=tc.label_smoothing,
                               z_loss=tc.z_loss)
        m["head_idx"] = head_idx.astype(jnp.float32)
    else:
        nheads = cfg.bpd_k if cfg.bpd_enabled else 1
        total, m = 0.0, {}
        for i in range(nheads):
            logits = _head_logits_for(params, cfg, hidden, jnp.asarray(i),
                                      tc.freeze_base,
                                      tc.detach_head_residual)
            tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + i
            tpos_c = jnp.minimum(tpos, s - 1)
            targets = jnp.take_along_axis(tgt, tpos_c, axis=1)
            mask = (tpos < s).astype(jnp.float32)
            if "tgt_mask" in batch:
                mask = mask * jnp.take_along_axis(
                    batch["tgt_mask"].astype(jnp.float32), tpos_c, axis=1)
            li, mi = softmax_xent(logits, targets, mask=mask,
                                  label_smoothing=tc.label_smoothing,
                                  z_loss=tc.z_loss)
            total = total + li / nheads
            if i == 0:
                m = mi
        loss = total
    m["loss"] = loss
    return loss, m


def loss_fn_for(cfg: ModelConfig) -> Callable:
    if cfg.is_encoder_only:
        return masked_prediction_loss
    if cfg.is_encoder_decoder:
        return seq2seq_loss
    return lm_loss
