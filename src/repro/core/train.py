"""Training for the combined scoring/proposal model (paper §6).

Key paper mechanics reproduced:

* **Random sub-loss selection** — the mean of the k head cross-entropies is
  too memory-hungry at training time, so one head is sampled uniformly per
  minibatch, giving an unbiased estimate of the full loss.  (``head_loss =
  "mean"`` is also provided for small models / ablations.)
* **Frozen vs fine-tuned base (§6.1)** — with ``freeze_base=True`` the trunk
  hidden states are stop-gradient'ed and the optimizer masks every parameter
  outside ``bpd_heads``, so the original model's quality is exactly retained.
  Head 0 is the identity (p_1 = base model), so frozen training samples the
  head index from {1..k-1}.
* Aux losses: MoE load-balance + router-z (weighted per config), logit
  z-loss, optional label smoothing.
* **Parallel scheduled sampling** (arXiv:1906.04331) — with
  ``scheduled_sampling=True`` one extra no-grad forward predicts every
  position of the gold stream at once; the conditioning prefix is then a
  per-position gold/model mixture (annealed ``ss_ratio``) so heads and
  draft students train on the prefixes they actually see at decode time.
  Targets stay gold, so base-model quality is unaffected.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.core.heads import head_apply_dynamic
from repro.models import model as model_lib
from repro.models import seq2seq as seq2seq_lib


def softmax_xent(logits, targets, *, mask=None, label_smoothing=0.0,
                 z_loss=0.0):
    """logits (..., V), targets (...,) int32; returns (loss, metrics)."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    logp_t = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0] - logz
    nll = -logp_t
    if label_smoothing:
        smooth = -(jnp.mean(logits, axis=-1) - logz)
        nll = (1 - label_smoothing) * nll + label_smoothing * smooth
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if mask is None:
        mask = jnp.ones(nll.shape, jnp.float32)
    mask = jnp.broadcast_to(mask.astype(jnp.float32), nll.shape)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    return loss, {"nll": loss, "accuracy": acc}


def _head_logits_for(params, cfg: ModelConfig, hidden, head_idx,
                     freeze_base: bool, detach_residual: bool = False):
    """Logits of one (traced-index) head over the trunk hidden states."""
    if freeze_base:
        hidden = jax.lax.stop_gradient(hidden)
    if not cfg.bpd_enabled:          # plain LM pre-training (no heads yet)
        return model_lib.project_vocab(params, cfg, hidden)
    h = head_apply_dynamic(params["bpd_heads"], cfg, hidden, head_idx,
                           identity_p1=cfg.bpd_identity_p1,
                           detach_residual=detach_residual)
    return model_lib.project_vocab(params, cfg, h)


def _sample_head(key, cfg: ModelConfig, tc: TrainConfig):
    k = cfg.bpd_k
    if tc.head_loss == "mean" or not cfg.bpd_enabled:
        return None
    lo = 1 if (tc.freeze_base and cfg.bpd_identity_p1) else 0
    return jax.random.randint(key, (), lo, k)


# ---------------------------------------------------------------------------
# Parallel scheduled sampling (arXiv:1906.04331)
# ---------------------------------------------------------------------------


def scheduled_sampling_ratio(tc: TrainConfig, step: int) -> float:
    """Host-side anneal: linear 0 -> ``tc.ss_ratio`` over
    ``tc.ss_anneal_steps`` training steps (constant when 0).  Training
    loops thread the per-step value into the jitted loss as the traced
    scalar ``batch["ss_ratio"]``; batches without the key fall back to the
    constant ``tc.ss_ratio``."""
    if not tc.scheduled_sampling:
        return 0.0
    if tc.ss_anneal_steps <= 0:
        return float(tc.ss_ratio)
    frac = min(max(step, 0) / tc.ss_anneal_steps, 1.0)
    return float(tc.ss_ratio) * frac


def _ss_ratio_for(tc: TrainConfig, batch: Dict):
    return batch["ss_ratio"] if "ss_ratio" in batch else jnp.float32(tc.ss_ratio)


def ss_mix_lm(params, cfg: ModelConfig, batch: Dict, key, ratio,
              with_pred: bool = False):
    """Mixed conditioning stream for a causal LM: ONE no-grad forward on the
    gold stream yields the model's p_1 prediction of every position in
    parallel (the trick of arXiv:1906.04331 — no sequential rollout), then
    each conditioning token except position 0 is swapped for the model's
    prediction of it with probability ``ratio``.  Targets stay gold; with
    ``with_pred`` the model-token stream (position 0 gold, then the
    model's prediction of every later position) is also returned — the
    self-distillation target stream for ``tc.ss_self_targets``."""
    tokens = batch["tokens"]
    h = model_lib.embed_inputs(params, cfg, batch)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    hidden, _, _ = model_lib.forward_hidden(params, cfg, h,
                                            positions=positions)
    hidden = hidden[:, model_lib.prefix_len(cfg, batch):, :]
    logits = _head_logits_for(params, cfg, hidden, jnp.asarray(0),
                              freeze_base=True)
    pred = jax.lax.stop_gradient(jnp.argmax(logits, axis=-1))  # predicts t+1
    model_tok = jnp.concatenate([tokens[:, :1], pred[:, :-1]], axis=1)
    swap = jax.random.bernoulli(key, ratio, tokens.shape)
    swap = swap & (jnp.arange(tokens.shape[1])[None, :] > 0)
    mixed = jnp.where(swap, model_tok, tokens).astype(tokens.dtype)
    if with_pred:
        return mixed, model_tok.astype(tokens.dtype)
    return mixed


def ss_mix_seq2seq(params, cfg: ModelConfig, batch: Dict, key, ratio,
                   enc_kvs=None, with_pred: bool = False):
    """Mixed decoder-input stream for seq2seq: like ``ss_mix_lm`` but over
    the BOS-shifted target; position 0 (BOS) always stays.  Pass the
    already-computed ``enc_kvs`` to reuse the encoder forward.  With
    ``with_pred`` also returns the model's per-position prediction of the
    target stream (``pred[t]`` predicts ``tgt[t]``) for
    ``tc.ss_self_targets``."""
    src, tgt = batch["src"], batch["tgt"]
    if enc_kvs is None:
        enc_kvs, _ = seq2seq_lib.encode(params, cfg, src)
    bos = jnp.zeros((tgt.shape[0], 1), tgt.dtype)
    dec_in = jnp.concatenate([bos, tgt[:, :-1]], axis=1)
    hidden, _ = seq2seq_lib.forward_hidden(params, cfg, dec_in, enc_kvs)
    logits = _head_logits_for(params, cfg, hidden, jnp.asarray(0),
                              freeze_base=True)
    pred = jax.lax.stop_gradient(jnp.argmax(logits, axis=-1))  # predicts tgt[t]
    model_in = jnp.concatenate([bos, pred[:, :-1]], axis=1)
    swap = jax.random.bernoulli(key, ratio, dec_in.shape)
    swap = swap & (jnp.arange(dec_in.shape[1])[None, :] > 0)
    mixed = jnp.where(swap, model_in, dec_in).astype(dec_in.dtype)
    if with_pred:
        return mixed, pred.astype(tgt.dtype)
    return mixed


# ---------------------------------------------------------------------------
# Decoder-only LM loss
# ---------------------------------------------------------------------------


def lm_loss(params, cfg: ModelConfig, tc: TrainConfig, batch: Dict, key
            ) -> Tuple[jnp.ndarray, Dict]:
    """batch: tokens (B, S) [+ patch_embeds / frame_embeds per modality].

    Head i (0-based) predicts position t+1+i from the hidden state at t.

    With ``tc.scheduled_sampling`` the conditioning stream is the
    ``ss_mix_lm`` gold/model mixture while the targets below stay gold —
    unless ``tc.ss_self_targets``, which supervises the heads with the
    frozen base's own chain predictions (the acceptance condition).
    """
    tokens = batch["tokens"]
    fwd_batch = batch
    if tc.scheduled_sampling:
        key, mix_key = jax.random.split(key)
        mixed, model_tok = ss_mix_lm(params, cfg, batch, mix_key,
                                     _ss_ratio_for(tc, batch),
                                     with_pred=True)
        fwd_batch = dict(batch, tokens=mixed)
        if tc.ss_self_targets:
            tokens = model_tok
    h = model_lib.embed_inputs(params, cfg, fwd_batch)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    hidden, moe_metrics, _ = model_lib.forward_hidden(params, cfg, h,
                                                      positions=positions)
    prefix = model_lib.prefix_len(cfg, batch)
    hidden = hidden[:, prefix:, :]                      # text positions only
    b, s, _ = hidden.shape

    if cfg.bpd_enabled and tc.head_loss == "random":
        head_idx = _sample_head(key, cfg, tc)
        logits = _head_logits_for(params, cfg, hidden, head_idx,
                                  tc.freeze_base, tc.detach_head_residual)
        # targets for head i at position t: tokens[t+1+i]
        offs = head_idx + 1
        tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + offs
        tpos_c = jnp.minimum(tpos, s - 1)
        targets = jnp.take_along_axis(tokens, tpos_c, axis=1)
        mask = (tpos < s).astype(jnp.float32)
        loss, m = softmax_xent(logits, targets, mask=mask,
                               label_smoothing=tc.label_smoothing,
                               z_loss=tc.z_loss)
        m["head_idx"] = head_idx.astype(jnp.float32)
    else:
        # mean over heads (small-model / oracle mode) or plain LM (no BPD)
        nheads = cfg.bpd_k if cfg.bpd_enabled else 1
        total, m = 0.0, {}
        for i in range(nheads):
            logits = _head_logits_for(params, cfg, hidden, jnp.asarray(i),
                                      tc.freeze_base,
                                      tc.detach_head_residual)
            tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + (i + 1)
            tpos_c = jnp.minimum(tpos, s - 1)
            targets = jnp.take_along_axis(tokens, tpos_c, axis=1)
            mask = (tpos < s).astype(jnp.float32)
            li, mi = softmax_xent(logits, targets, mask=mask,
                                  label_smoothing=tc.label_smoothing,
                                  z_loss=tc.z_loss)
            total = total + li / nheads
            if i == 0:
                m = mi
        loss = total

    for name, val in moe_metrics.items():
        m[name] = val
        if name == "moe_aux_loss":
            loss = loss + cfg.router_aux_coef * val
        if name == "moe_z_loss":
            loss = loss + cfg.router_z_coef * val
    m["loss"] = loss
    return loss, m


# ---------------------------------------------------------------------------
# Encoder-only masked prediction (hubert)
# ---------------------------------------------------------------------------


def masked_prediction_loss(params, cfg: ModelConfig, tc: TrainConfig,
                           batch: Dict, key) -> Tuple[jnp.ndarray, Dict]:
    """batch: frame_embeds (B,S,d), mask (B,S) bool, targets (B,S) int32."""
    h = model_lib.embed_inputs(params, cfg, batch)      # applies mask_embed
    hidden, _, _ = model_lib.forward_hidden(params, cfg, h, bidirectional=True)
    logits = model_lib.project_vocab(params, cfg, hidden)
    loss, m = softmax_xent(logits, batch["targets"],
                           mask=batch["mask"].astype(jnp.float32),
                           z_loss=tc.z_loss)
    m["loss"] = loss
    return loss, m


# ---------------------------------------------------------------------------
# Seq2seq (paper MT) loss
# ---------------------------------------------------------------------------


def seq2seq_loss(params, cfg: ModelConfig, tc: TrainConfig, batch: Dict, key
                 ) -> Tuple[jnp.ndarray, Dict]:
    """batch: src (B,Ss), tgt (B,St); teacher forcing with BOS-shifted tgt.

    With ``tc.scheduled_sampling`` the decoder input is the
    ``ss_mix_seq2seq`` gold/model mixture while the targets stay gold —
    unless ``tc.ss_self_targets``, which supervises the heads with the
    frozen base's own chain predictions (the acceptance condition).
    """
    src, tgt = batch["src"], batch["tgt"]
    enc_kvs, _ = seq2seq_lib.encode(params, cfg, src)
    bos = jnp.zeros((tgt.shape[0], 1), tgt.dtype)
    dec_in = jnp.concatenate([bos, tgt[:, :-1]], axis=1)
    if tc.scheduled_sampling:
        key, mix_key = jax.random.split(key)
        dec_in, ss_pred = ss_mix_seq2seq(params, cfg, batch, mix_key,
                                         _ss_ratio_for(tc, batch),
                                         enc_kvs=enc_kvs, with_pred=True)
        if tc.ss_self_targets:
            tgt = ss_pred
    hidden, _ = seq2seq_lib.forward_hidden(params, cfg, dec_in, enc_kvs)
    b, s, _ = hidden.shape

    if cfg.bpd_enabled and tc.head_loss == "random":
        head_idx = _sample_head(key, cfg, tc)
        logits = _head_logits_for(params, cfg, hidden, head_idx,
                                  tc.freeze_base, tc.detach_head_residual)
        offs = head_idx  # dec_in position t sees tgt[<t]; head i predicts tgt[t+i]
        tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + offs
        tpos_c = jnp.minimum(tpos, s - 1)
        targets = jnp.take_along_axis(tgt, tpos_c, axis=1)
        mask = (tpos < s).astype(jnp.float32)
        if "tgt_mask" in batch:
            mask = mask * jnp.take_along_axis(
                batch["tgt_mask"].astype(jnp.float32), tpos_c, axis=1)
        loss, m = softmax_xent(logits, targets, mask=mask,
                               label_smoothing=tc.label_smoothing,
                               z_loss=tc.z_loss)
        m["head_idx"] = head_idx.astype(jnp.float32)
    else:
        nheads = cfg.bpd_k if cfg.bpd_enabled else 1
        total, m = 0.0, {}
        for i in range(nheads):
            logits = _head_logits_for(params, cfg, hidden, jnp.asarray(i),
                                      tc.freeze_base,
                                      tc.detach_head_residual)
            tpos = jnp.arange(s, dtype=jnp.int32)[None, :] + i
            tpos_c = jnp.minimum(tpos, s - 1)
            targets = jnp.take_along_axis(tgt, tpos_c, axis=1)
            mask = (tpos < s).astype(jnp.float32)
            if "tgt_mask" in batch:
                mask = mask * jnp.take_along_axis(
                    batch["tgt_mask"].astype(jnp.float32), tpos_c, axis=1)
            li, mi = softmax_xent(logits, targets, mask=mask,
                                  label_smoothing=tc.label_smoothing,
                                  z_loss=tc.z_loss)
            total = total + li / nheads
            if i == 0:
                m = mi
        loss = total
    m["loss"] = loss
    return loss, m


def loss_fn_for(cfg: ModelConfig) -> Callable:
    if cfg.is_encoder_only:
        return masked_prediction_loss
    if cfg.is_encoder_decoder:
        return seq2seq_loss
    return lm_loss
