"""Pluggable decode policies: Drafter × Acceptor × BlockSchedule.

The paper's speedups hinge on *what gets proposed* and *how it is accepted*
(§3 exact match, §5.1 top-k, §5.2 distance, §5.3 minimum block size).  A
``DecodePolicy`` makes those axes first-class objects instead of enum
branches inside the decode loop:

  * ``Acceptor``   — maps (proposals, verify p_1 logits) to per-position
    accept decisions.  Built-ins: ``ExactAcceptor`` (§3), ``TopKAcceptor``
    (§5.1), ``DistanceAcceptor`` (§5.2).
  * ``BlockSchedule`` — turns the accept mask into a per-row block size k̂,
    optionally with loop-carried state.  ``StaticSchedule`` is §5.3's
    minimum block size; ``AdaptiveSchedule`` generalizes it into a dynamic
    controller that grows/shrinks a per-row cap from the running acceptance
    rate.
  * ``Drafter``    — produces the next block of k proposals from the verify
    forward's own outputs (plus optional loop-carried state).
    ``HeadsDrafter`` is the paper's prediction heads; ``InputCopyDrafter``
    drafts from the source sentence (Aggressive-Decoding-style, for the
    paper's MT setting); ``TopKTreeDrafter`` drafts top-k candidates per
    slot and picks the chain that the strongest head (p_1) also scores
    highly.

Index convention (0-based within a block; see core/verify.py):

  * ``proposals[:, i]`` proposes the token at absolute position
    ``text_len + i`` (the next unwritten position is ``text_len``).
  * Slot 0 of a fresh draft MUST be the model's own verified greedy token
    (p_1's argmax at the accepted slot): acceptance treats slot 0 as
    unconditional (k̂ ≥ 1), so a drafter that puts anything else there
    changes the decoded output.  Every built-in drafter preserves this, so
    exact-acceptance decoding stays token-identical to greedy regardless of
    the drafter — drafts change *iteration counts*, never *tokens*.

Loop-carried policy state is a ``PolicyState(drafter=…, schedule=…)`` pytree
threaded through ``BPDState`` / ``SlotBatch``.  Every state leaf must be a
batch-leading ``(B, …)`` array (or absent): ``sharding.policy.state_specs``
then shards it over the data axes like any other per-row decode state, and
the serving engine can reset single rows on admit/evict.

String names resolve through ``resolve_policy`` (see ``POLICY_BUILDERS``);
the legacy ``DecodeConfig.criterion`` strings "exact" / "topk" / "distance"
remain valid aliases for the corresponding heads-drafted policies.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DecodeConfig

I32 = jnp.int32


class PolicyState(NamedTuple):
    """Loop-carried policy state (a field of ``BPDState`` / ``SlotBatch``).

    Both fields are pytrees whose leaves are batch-leading ``(B, …)``
    arrays; ``()`` means stateless.  Kept as a NamedTuple so the pytree
    structure is stable across jit boundaries and ``state_specs`` can walk
    it like any other decode-state field.
    """

    drafter: Any = ()
    schedule: Any = ()


class DraftInputs(NamedTuple):
    """Everything one verify forward exposes to a ``Drafter``.

    ``logits`` is the full head tensor of the iteration that just verified
    the current block — reusing it keeps drafting free (no extra model
    calls), exactly like the paper's combined scoring/proposal
    formulation (§4).

    ``prev_token`` / ``aux`` are the bundle-aware model-call seam: a
    drafter backed by its own model (``core.draft.DraftModelDrafter``)
    reads its parameters from ``aux`` (the session's auxiliary
    ``ModelBundle`` params, keyed by bundle name) and uses ``prev_token``
    — the committed token at position ``text_len - 1`` — to keep its own
    loop-carried cache in sync with the verified stream.  Drafters that
    only read the verify forward ignore both.
    """

    logits: jnp.ndarray       # (B, k, K, V) all-head logits at every slot
    khat: jnp.ndarray         # (B,) accepted block size this iteration
    slot: jnp.ndarray         # (B,) accepted slot index = max(k̂ - 1, 0)
    text_len: jnp.ndarray     # (B,) text length AFTER accepting this block
    old_proposals: jnp.ndarray  # (B, k) the block that was just verified
    prev_token: Any = ()      # (B,) committed token at text_len - 1
    aux: Any = ()             # {bundle name: params} for model-backed drafters


def _gather_slot(x: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """x: (B, k, ...) gathered at per-row slot -> (B, ...)."""
    idx = slot.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.take_along_axis(x, idx, axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Acceptors (paper §3, §5.1, §5.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Acceptor:
    """Per-position acceptance rule.  Subclasses implement ``position_ok``
    on the (B, k-1) candidate slice; slot 0 is always accepted (k̂ ≥ 1).

    ``fused=True`` routes ``accepts`` through the one-pass Pallas kernel
    (``kernels.fused_verify``): the vocab-dimension argmax/top-k, the
    criterion compare, and the prefix-accept scan run as a single op that
    streams the (B, k, V) logits once instead of four separate XLA ops.
    Token-identical to the jnp path (same ``jnp.argmax`` tie-breaking);
    opt-in via ``DecodeConfig.fused_verify``.  Subclasses advertise their
    compile-time kernel variant through ``fused_spec``; ``None`` means no
    fused form exists and the jnp path is always used.
    """

    fused: bool = False

    def accepts(self, proposals: jnp.ndarray,
                p1_logits: jnp.ndarray) -> jnp.ndarray:
        """proposals (B, k) int32, p1_logits (B, k, V) -> (B, k) bool."""
        b, k = proposals.shape
        spec = self.fused_spec() if self.fused else None
        if spec is not None:
            from repro.kernels import ops

            acc, _, _, _ = ops.fused_verify(p1_logits[:, :k, :], proposals,
                                            **spec)
            return acc
        ver_logits = p1_logits[:, : k - 1, :]      # slot i-1 verifies slot i
        cand = proposals[:, 1:]
        ok = self.position_ok(cand, ver_logits)
        return jnp.concatenate([jnp.ones((b, 1), bool), ok], axis=1)

    def fused_spec(self) -> Optional[Dict]:
        """kwargs for ``kernels.ops.fused_verify`` (None: no fused form)."""
        return None

    def position_ok(self, cand: jnp.ndarray,
                    ver_logits: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ExactAcceptor(Acceptor):
    """§3: accept while the proposal equals the model's greedy token —
    output is token-identical to greedy decoding."""

    def position_ok(self, cand, ver_logits):
        return cand == jnp.argmax(ver_logits, axis=-1)

    def fused_spec(self):
        return {"criterion": "exact"}


@dataclasses.dataclass(frozen=True)
class TopKAcceptor(Acceptor):
    """§5.1: accept any proposal inside the verifier's top-k set."""

    top_k: int = 1

    def position_ok(self, cand, ver_logits):
        _, top_ids = jax.lax.top_k(ver_logits, self.top_k)
        return jnp.any(top_ids == cand[..., None], axis=-1)

    def fused_spec(self):
        return {"criterion": "topk", "top_k": self.top_k}


@dataclasses.dataclass(frozen=True)
class DistanceAcceptor(Acceptor):
    """§5.2: ordinal vocabularies — accept proposals within ``epsilon`` of
    the greedy token id."""

    epsilon: float = 0.0

    def position_ok(self, cand, ver_logits):
        return jnp.abs(cand - jnp.argmax(ver_logits, axis=-1)) <= self.epsilon

    def fused_spec(self):
        return {"criterion": "distance", "epsilon": self.epsilon}


# ---------------------------------------------------------------------------
# Block schedules (paper §5.3, generalized)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockSchedule:
    """Turns per-position accepts into a per-row block size k̂ (stateful)."""

    def init_state(self, b: int) -> Any:
        return ()

    def block_size(self, accepts: jnp.ndarray, remaining: jnp.ndarray,
                   state: Any):
        """accepts (B, k) bool, remaining (B,) int32 ->
        (k̂ (B,) int32 in [1, min(k, remaining)], new state)."""
        raise NotImplementedError


def _prefix_len(accepts: jnp.ndarray) -> jnp.ndarray:
    """Longest accepted prefix per row: (B, k) bool -> (B,) int32."""
    return jnp.sum(jnp.cumprod(accepts.astype(I32), axis=1), axis=1)


@dataclasses.dataclass(frozen=True)
class StaticSchedule(BlockSchedule):
    """§5.3 minimum block size: k̂ = max(prefix, min_block), clamped to the
    remaining budget.  Stateless — min_block=1 is the paper's default."""

    min_block: int = 1

    def block_size(self, accepts, remaining, state):
        khat = _prefix_len(accepts)
        if self.min_block > 1:
            khat = jnp.maximum(khat, min(self.min_block, accepts.shape[1]))
        return jnp.maximum(jnp.minimum(khat, remaining), 1), state


@dataclasses.dataclass(frozen=True)
class AdaptiveSchedule(BlockSchedule):
    """Dynamic §5.3: a per-row cap on k̂ driven by the running acceptance
    rate.  An EMA of k̂/k grows the cap (toward the full block) while
    acceptance is high and shrinks it (toward ``min_block``) when proposals
    keep missing — bounding the tokens a row can over-commit on workloads
    where its acceptance rate has collapsed.

    State (per row): ``rate`` f32 EMA of k̂/cap, ``cap`` int32 current cap.
    """

    min_block: int = 1
    decay: float = 0.7          # EMA decay of the acceptance-rate estimate
    grow: float = 0.8           # rate above which the cap grows by 1
    shrink: float = 0.4         # rate below which the cap shrinks by 1

    def init_state(self, b: int) -> Any:
        return {"rate": jnp.ones((b,), jnp.float32),
                "cap": jnp.full((b,), jnp.iinfo(jnp.int32).max, I32)}

    def block_size(self, accepts, remaining, state):
        k = accepts.shape[1]
        floor = max(min(self.min_block, k), 1)
        cap = jnp.clip(state["cap"], floor, k)
        accepted = jnp.minimum(jnp.maximum(_prefix_len(accepts), floor), cap)
        khat = jnp.maximum(jnp.minimum(accepted, remaining), 1)
        # rate tracks the un-clamped acceptance (the budget clamp at the end
        # of a row's generation says nothing about proposal quality)
        rate = (self.decay * state["rate"]
                + (1 - self.decay) * accepted.astype(jnp.float32)
                / cap.astype(jnp.float32))
        cap = jnp.where(rate >= self.grow, jnp.minimum(cap + 1, k),
                        jnp.where(rate <= self.shrink,
                                  jnp.maximum(cap - 1, floor), cap))
        return khat, {"rate": rate, "cap": cap}


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Drafter:
    """Produces the next block of proposals from the verify forward.

    ``init_state`` sees the decode entry point's inputs (``batch`` — e.g.
    the source sentence for seq2seq, or the padded prompt tokens in the
    serving engine's admission path) and must return a pytree of
    batch-leading ``(b, …)`` arrays, or ``()`` for stateless drafters.
    ``aux`` carries the auxiliary ``ModelBundle`` params when the caller
    has them (decode prefill, engine admission); paths that cannot supply
    params (engine init/evict, ``jax.eval_shape`` struct builders) pass
    ``()`` — model-backed drafters must produce identically-shaped state
    either way.

    ``bind`` attaches the *static* side of the session's auxiliary bundles
    (cfg / kv_chunk / backend factory) to the drafter before any tracing;
    the default is a no-op for drafters that need no second model.
    """

    def init_state(self, cfg, dec: DecodeConfig, batch: Optional[Dict],
                   b: int, aux: Any = ()) -> Any:
        return ()

    def bind(self, bundles: Dict, cfg) -> "Drafter":
        """bundles: {name: core.bundle.ModelBundle}; cfg: the PRIMARY model
        config (for cross-model compatibility checks)."""
        return self

    def tree_topology(self, block_k: int):
        """The static ``kernels.tree_mask.TreeTopology`` this drafter's
        proposals form, or None for chain drafts.  Non-None switches
        ``bpd_iteration`` to tree verification: proposals are node tokens,
        the forward runs under a tree-attention mask, and acceptance picks
        the longest accepted root-to-leaf path."""
        return None

    def draft(self, inputs: DraftInputs, state: Any):
        """-> (proposals (B, k) int32 with slot 0 = verified token, state).
        For tree drafters (``tree_topology`` non-None) slot n is the token
        of tree node n instead of chain slot n."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class HeadsDrafter(Drafter):
    """The paper's proposal mechanism: head p_{i+1}'s argmax at the accepted
    slot proposes block slot i (already computed by the verify forward)."""

    def draft(self, inputs: DraftInputs, state: Any):
        head_argmax = jnp.argmax(inputs.logits, axis=-1)        # (B, k, K)
        return _gather_slot(head_argmax, inputs.slot), state


@dataclasses.dataclass(frozen=True)
class InputCopyDrafter(Drafter):
    """Aggressive-Decoding-style drafts for seq2seq: propose the source
    tokens aligned with the next output positions (arXiv:2205.10350).

    On copy-heavy targets (the paper's MT setting; grammar correction;
    our synthetic copy task) the model's greedy output largely *is* the
    source, so source-aligned drafts verify in long blocks even when the
    prediction heads are weak or absent.  Slot 0 stays the verified greedy
    token, so exact acceptance remains lossless on any task.

    ``offset`` shifts the source index for tasks with a known alignment
    offset (output position t reads ``src[t + offset]``).
    """

    offset: int = 0

    def init_state(self, cfg, dec, batch, b, aux=()):
        if batch is None or "src" not in batch:
            raise ValueError(
                "InputCopyDrafter drafts from batch['src'] and is only "
                "meaningful for seq2seq decoding — use HeadsDrafter (or a "
                "custom drafter) for decoder-only models")
        return {"src": jnp.asarray(batch["src"], I32)}

    def draft(self, inputs: DraftInputs, state):
        src = state["src"]
        b, k = inputs.old_proposals.shape
        head_argmax = jnp.argmax(inputs.logits, axis=-1)
        verified = _gather_slot(head_argmax, inputs.slot)[:, 0]  # p_1 argmax
        # decoder position 0 is BOS, so output index = position - 1; block
        # slot i sits at position text_len + i
        out_idx = (inputs.text_len[:, None] - 1 + self.offset
                   + jnp.arange(k, dtype=I32)[None, :])
        idx = jnp.clip(out_idx, 0, src.shape[1] - 1)
        copied = jnp.take_along_axis(src, idx, axis=1)
        proposals = copied.at[:, 0].set(verified)
        return proposals, state


@dataclasses.dataclass(frozen=True)
class TopKTreeDrafter(Drafter):
    """Drafts a candidate *tree* the verifier scores in one forward (cf.
    arXiv:2404.09221's tree verification): node n at depth d with sibling
    rank r carries head p_{d+1}'s r-th top token at the accepted slot, and
    ``bpd_iteration`` runs the block under a tree-attention mask so p_1's
    logits at every node are conditioned on that node's own ancestor
    chain.  Acceptance then keeps the longest accepted root-to-leaf path —
    with ``block_k`` nodes the forward costs the same as a chain, but the
    verifier gets ``fanout`` shots at the first speculative position
    instead of one.

    The topology is ``kernels.tree_mask.default_tree``: the root (the
    verified greedy token — tree slot 0, k̂ ≥ 1) with ``fanout`` children,
    then a top-1 chain below the rank-0 child, so the classic heads chain
    is always a subtree.  Stateless and lossless under exact acceptance.
    """

    fanout: int = 4

    def tree_topology(self, block_k: int):
        from repro.kernels.tree_mask import default_tree

        return default_tree(block_k, self.fanout)

    def draft(self, inputs: DraftInputs, state):
        b, k = inputs.old_proposals.shape
        topo = self.tree_topology(k)
        head_logits = _gather_slot(inputs.logits, inputs.slot)   # (B,K,V)
        need = int(topo.ranks.max()) + 1
        _, ids = jax.lax.top_k(head_logits, need)                # (B,K,need)
        d = jnp.asarray(topo.depths)                             # head index
        r = jnp.asarray(topo.ranks)                              # rank index
        # node 0 is (depth 0, rank 0) = head p_1's argmax = the verified token
        return ids[:, d, r].astype(I32), state


# ---------------------------------------------------------------------------
# Locality-aware image decoding (arXiv:2507.01957)
# ---------------------------------------------------------------------------


class _LocalityTables(NamedTuple):
    order: np.ndarray          # (H*W,) generation slot -> raster index
    boundaries: np.ndarray     # class-end offsets (block cut points)
    next_boundary: np.ndarray  # (H*W + 1,) smallest boundary > p
    n1: np.ndarray             # (H*W,) committed-neighbor generation index
    n2: np.ndarray
    coarse_len: int            # boundaries[0] — the coarse-lattice prefix


@functools.lru_cache(maxsize=None)
def _locality_tables(height: int, width: int, stride: int) -> _LocalityTables:
    from repro.data.synthetic import locality_plan

    order, bounds, n1, n2 = locality_plan(height, width, stride)
    n = order.size
    nb = np.full(n + 1, n + (1 << 20), np.int64)   # "no boundary left"
    for p in range(n + 1):
        j = int(np.searchsorted(bounds, p, side="right"))
        if j < bounds.size:
            nb[p] = bounds[j]
    return _LocalityTables(order, bounds, nb.astype(np.int32), n1, n2,
                           int(bounds[0]))


@dataclasses.dataclass(frozen=True)
class LocalityDrafter(Drafter):
    """Locality-aware image drafts (arXiv:2507.01957).

    The token stream is an (height, width) raster serialized in the
    progressive-lattice order of ``data.synthetic.locality_plan`` (coarse
    lattice first, then non-adjacent refinement classes), so every
    refinement position has already-committed spatial neighbors — the
    drafter proposes their rounded average (bilinear-style interpolation
    on the ordinal vocabulary) instead of the heads' raster
    extrapolation, then (``window`` > 0) re-ranks the interpolation's
    ±window neighborhood by the verifier's own head logits — the spatial
    prior narrows the candidate set, the heads break the quantization
    rounding ties interpolation cannot see.  State is the committed
    stream in generation order, re-built from each verified block; slot
    0 stays the verified greedy token, so exact acceptance is lossless
    on ANY prompt (drafts change iteration counts, never tokens).
    """

    height: int = 0
    width: int = 0
    stride: int = 4
    window: int = 1

    def init_state(self, cfg, dec, batch, b, aux=()):
        n = self.height * self.width
        k = dec.block_k or getattr(cfg, "bpd_k", 1)
        buf = jnp.zeros((b, n + max(int(k), 1)), I32)
        if batch is not None and "tokens" in batch:
            toks = jnp.asarray(batch["tokens"], I32)[:, :n]
            buf = jax.lax.dynamic_update_slice(buf, toks, (0, 0))
        return {"grid": buf}

    def draft(self, inputs: DraftInputs, state):
        buf = state["grid"]
        b, k = inputs.old_proposals.shape
        cap = buf.shape[1]
        tables = _locality_tables(self.height, self.width, self.stride)
        n1 = jnp.asarray(tables.n1)
        n2 = jnp.asarray(tables.n2)
        # 1. commit the just-verified block into the generation-order buffer.
        #    Slot k̂-1 carries ``prev_token`` (the committed token at
        #    text_len - 1): in loop iterations that equals old_proposals
        #    there, and on the prefill call (old_proposals zeroed, k̂ = 1)
        #    it writes the real last prompt token.
        offs = jnp.arange(k, dtype=I32)[None, :]
        start = inputs.text_len[:, None] - inputs.khat[:, None]
        idx = jnp.clip(start + offs, 0, cap - 1)
        vals = jnp.where(offs == inputs.khat[:, None] - 1,
                         inputs.prev_token[:, None], inputs.old_proposals)
        keep = offs < inputs.khat[:, None]

        def row_commit(row, ix, v, m):
            return row.at[ix].set(jnp.where(m, v, row[ix]))

        buf = jax.vmap(row_commit)(buf, idx, vals.astype(I32), keep)
        # 2. propose: each next position interpolates its committed parents
        pos = jnp.clip(inputs.text_len[:, None] + offs, 0, n1.shape[0] - 1)
        a = jnp.take_along_axis(buf, jnp.clip(n1[pos], 0, cap - 1), axis=1)
        c = jnp.take_along_axis(buf, jnp.clip(n2[pos], 0, cap - 1), axis=1)
        proposals = (a + c + 1) // 2
        if self.window:
            vocab = inputs.logits.shape[-1]
            hl = _gather_slot(inputs.logits, inputs.slot)   # (B, heads, V)
            hidx = jnp.minimum(jnp.arange(k), hl.shape[1] - 1)
            deltas = jnp.arange(-self.window, self.window + 1, dtype=I32)
            cands = jnp.clip(proposals[..., None] + deltas, 0, vocab - 1)
            scores = jnp.take_along_axis(hl[:, hidx, :], cands, axis=-1)
            pick = jnp.argmax(scores, axis=-1)
            proposals = jnp.take_along_axis(cands, pick[..., None], -1)[..., 0]
        head_argmax = jnp.argmax(inputs.logits, axis=-1)
        verified = _gather_slot(head_argmax, inputs.slot)[:, 0]  # p_1 argmax
        proposals = proposals.at[:, 0].set(verified)
        return proposals.astype(I32), {"grid": buf}


@dataclasses.dataclass(frozen=True)
class LocalitySchedule(BlockSchedule):
    """Clamps each accepted block at the next offset-class boundary of the
    progressive-lattice order, so a block never commits positions whose
    spatial parents are still uncommitted — and every committed block
    stays spatially non-adjacent within its class.  State: a per-row
    generation cursor starting at ``start`` (the coarse prompt length in
    the canonical image workload; any other prompt length is merely a
    sub-optimal cut alignment, still lossless under exact acceptance)."""

    height: int = 0
    width: int = 0
    stride: int = 4
    start: int = 0

    def init_state(self, b: int) -> Any:
        return {"pos": jnp.full((b,), self.start, I32)}

    def block_size(self, accepts, remaining, state):
        tables = _locality_tables(self.height, self.width, self.stride)
        nb = jnp.asarray(tables.next_boundary)
        pos = state["pos"]
        room = nb[jnp.clip(pos, 0, nb.shape[0] - 1)] - pos
        khat = jnp.minimum(_prefix_len(accepts),
                           jnp.minimum(remaining, room))
        khat = jnp.maximum(khat, 1)
        return khat, {"pos": pos + khat}


# ---------------------------------------------------------------------------
# The composed policy + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodePolicy:
    """Drafter × Acceptor × BlockSchedule behind every decode path."""

    drafter: Drafter
    acceptor: Acceptor
    schedule: BlockSchedule
    name: str = "custom"

    def init_state(self, cfg, dec: DecodeConfig, batch: Optional[Dict],
                   b: int, aux: Any = ()) -> PolicyState:
        return PolicyState(
            drafter=self.drafter.init_state(cfg, dec, batch, b, aux=aux),
            schedule=self.schedule.init_state(b))

    def bind(self, bundles: Dict, cfg) -> "DecodePolicy":
        """Attach the session's auxiliary ``ModelBundle``s (static side:
        cfg / kv_chunk / backend factory) to the drafter.  A no-op for
        single-model policies; model-backed drafters validate and absorb
        their bundle here — BEFORE any tracing — so a missing or
        incompatible draft model fails at session construction."""
        drafter = self.drafter.bind(bundles or {}, cfg)
        if drafter is self.drafter:
            return self
        return dataclasses.replace(self, drafter=drafter)

    @property
    def cache_key(self):
        """Hashable structural identity for jit-cache keying.

        Two policies with equal drafter/acceptor/schedule *parameters*
        (not just equal registry names) share compiled decode entry points
        and serving functions, while ``topk(top_k=2)`` and
        ``topk(top_k=3)`` — same ``name`` — key separately.  Components
        are frozen dataclasses all the way down (a bound drafter's
        ``ModelConfig`` included), reduced here to nested (type, fields)
        tuples so the key is stable across equal-valued instances.
        """
        return policy_cache_key(self)


def policy_cache_key(obj):
    """Reduce a policy (or any of its components) to a hashable tuple.

    Frozen-dataclass components flatten to ``(type, (field, value), ...)``
    recursively; everything else must already be hashable (ints, floats,
    strings, tuples, None, callables)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,) + tuple(
            (f.name, policy_cache_key(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
    if isinstance(obj, (list, tuple)):
        return tuple(policy_cache_key(x) for x in obj)
    return obj


# name -> builder(dec) -> DecodePolicy.  The legacy criterion strings are
# aliases for the heads-drafted policies, so ``DecodeConfig.criterion`` and
# ``DecodeConfig.policy`` resolve through the same table.
POLICY_BUILDERS: Dict[str, Callable[[DecodeConfig], DecodePolicy]] = {}


def register_policy(name: str,
                    builder: Callable[[DecodeConfig], DecodePolicy]) -> None:
    if name in POLICY_BUILDERS:
        raise ValueError(f"duplicate policy registration: {name!r}")
    POLICY_BUILDERS[name] = builder


def list_policies() -> list:
    return sorted(POLICY_BUILDERS)


def resolve_policy(dec: DecodeConfig,
                   policy: Union[None, str, DecodePolicy] = None
                   ) -> DecodePolicy:
    """Resolve the policy a decode should run.

    Precedence: an explicit ``DecodePolicy`` object > an explicit name >
    ``dec.policy`` > the legacy ``dec.criterion`` alias.  Builders read
    their knobs (top_k, epsilon, min_block) off ``dec``.
    """
    if isinstance(policy, DecodePolicy):
        return policy
    name = policy or dec.policy or dec.criterion
    builder = POLICY_BUILDERS.get(name)
    if builder is None:
        raise ValueError(f"unknown decode policy {name!r}; "
                         f"registered: {list_policies()}")
    return builder(dec)


def _schedule_for(dec: DecodeConfig) -> BlockSchedule:
    return StaticSchedule(min_block=dec.min_block)


def _maybe_fused(acceptor: Acceptor, dec: DecodeConfig) -> Acceptor:
    """Honor ``DecodeConfig.fused_verify`` in the built-in builders."""
    if getattr(dec, "fused_verify", False):
        return dataclasses.replace(acceptor, fused=True)
    return acceptor


register_policy("exact", lambda dec: DecodePolicy(
    HeadsDrafter(), _maybe_fused(ExactAcceptor(), dec), _schedule_for(dec),
    name="exact"))
register_policy("topk", lambda dec: DecodePolicy(
    HeadsDrafter(), _maybe_fused(TopKAcceptor(top_k=dec.top_k), dec),
    _schedule_for(dec), name="topk"))
register_policy("distance", lambda dec: DecodePolicy(
    HeadsDrafter(), _maybe_fused(DistanceAcceptor(epsilon=dec.epsilon), dec),
    _schedule_for(dec), name="distance"))
register_policy("adaptive", lambda dec: DecodePolicy(
    HeadsDrafter(), _maybe_fused(ExactAcceptor(), dec),
    AdaptiveSchedule(min_block=dec.min_block), name="adaptive"))
register_policy("input_copy", lambda dec: DecodePolicy(
    InputCopyDrafter(), _maybe_fused(ExactAcceptor(), dec), _schedule_for(dec),
    name="input_copy"))
register_policy("topk_tree", lambda dec: DecodePolicy(
    TopKTreeDrafter(fanout=max(dec.top_k, 2)),
    _maybe_fused(ExactAcceptor(), dec), _schedule_for(dec), name="topk_tree"))


def _locality_policy(dec: DecodeConfig) -> DecodePolicy:
    h, w = dec.image_height, dec.image_width
    if h <= 0 or w <= 0:
        raise ValueError(
            "policy 'locality' needs the 2-D raster geometry: set "
            "DecodeConfig.image_height / image_width (and optionally "
            "locality_stride) to the grid shape of the token stream")
    tables = _locality_tables(h, w, dec.locality_stride)
    return DecodePolicy(
        LocalityDrafter(height=h, width=w, stride=dec.locality_stride),
        _maybe_fused(ExactAcceptor(), dec),
        LocalitySchedule(height=h, width=w, stride=dec.locality_stride,
                         start=tables.coarse_len),
        name="locality")


register_policy("locality", _locality_policy)

# the model-backed speculative drafter lives in core.draft (it pulls in the
# model stack); importing it here registers the "draft_model" policy so the
# registry is complete whenever policies are resolvable at all
from repro.core import draft as _draft  # noqa: E402,F401  (registration)
