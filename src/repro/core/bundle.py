"""ModelBundle: one model's complete decode identity.

The decode stack historically assumed exactly one parameter set + one
``ModelConfig`` + one KV cache per session.  Speculative decoding with an
independent draft model (the BPD-drafts follow-up, arXiv:2404.09221, and
the lossless-verification framing of arXiv:2205.10350) breaks that
assumption: the *verifier* stays the session's primary model, while a
*drafter* runs a second, smaller model with its own params, config,
backend and loop-carried cache.

A ``ModelBundle`` packages everything one model needs to participate in a
decode: parameters, config, the ``Backend`` factory that turns them into
embed/decode/commit/head functions, and the knobs the sharding policy
reads (``sharding.policy.param_shardings`` / ``cache_specs`` are both
keyed off ``cfg``).  ``DecodeSession`` owns a primary bundle (its
historical ``params``/``cfg`` arguments) plus optional auxiliary bundles
by name; aux params are device_put per bundle and threaded into the
jitted entry points as explicit arguments, so they shard, donate and
cache-key exactly like the primary set.

Only the *static* half of a bundle (cfg, kv_chunk, backend_factory) is
bound into policy objects (``DecodePolicy.bind``); the params flow through
``DraftInputs.aux`` as traced values so a bundle-aware drafter can run its
own forward pass inside the decode loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.config import ModelConfig


@dataclasses.dataclass(eq=False)
class ModelBundle:
    """params + config + backend factory for one model in a decode session.

    ``backend_factory`` is ``(cfg, kv_chunk) -> core.decode.Backend``; None
    means the decoder-only ``causal_lm_backend`` (consumers — e.g.
    ``core.draft.DraftModelDrafter._backend`` — apply that default when
    the bundle's static half is bound into them).  ``name`` is
    informational (the session keys bundles by the dict key it receives
    them under).
    """

    params: Any
    cfg: ModelConfig
    kv_chunk: int = 0
    backend_factory: Optional[Callable] = None
    name: str = ""
