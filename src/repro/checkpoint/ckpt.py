"""Checkpointing: pytree -> (npz arrays + msgpack metadata).

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/meta.msgpack
Supports save / restore / latest-step discovery / rotation.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Pytree = Any

_SEP = "\x1f"  # unit separator: safe key joiner (slashes appear in no keys)


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}

    def visit(path, x):
        key = _SEP.join(str(p.key) if hasattr(p, "key") else str(p.idx)
                        for p in path)
        flat[key] = np.asarray(x)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save(ckpt_dir: str, step: int, tree: Pytree, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "keys": list(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _rotate(ckpt_dir, keep)
    return path


def _rotate(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Pytree, *, step: Optional[int] = None
            ) -> Tuple[Pytree, Dict]:
    """Restore into the structure of ``template`` (shape/dtype preserved from
    the checkpoint arrays; template provides the tree structure)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    arrays = np.load(os.path.join(path, "arrays.npz"))

    flat_template, treedef = jax.tree_util.tree_flatten(template)
    flat_saved = _flatten(template)  # same key order as template traversal
    keys = list(flat_saved.keys())
    assert len(keys) == len(flat_template)
    restored = [jnp.asarray(arrays[k]) for k in keys]
    return jax.tree_util.tree_unflatten(treedef, restored), meta["extra"]
