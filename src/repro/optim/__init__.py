from repro.optim.adamw import (
    lr_scale_mask,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    freeze_mask,
    make_schedule,
    optimizer_init,
    optimizer_update,
)

__all__ = [
    "adafactor_init",
    "adafactor_update",
    "adamw_init",
    "adamw_update",
    "freeze_mask",
    "lr_scale_mask",
    "make_schedule",
    "optimizer_init",
    "optimizer_update",
]
