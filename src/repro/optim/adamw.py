"""AdamW and Adafactor, pure-JAX pytree implementations, with global-norm
clipping, parameter masking (paper §6.1 frozen-base training), and the
schedules used by the paper's Transformer recipe (inverse-sqrt warmup) plus
cosine decay."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.utils.tree import global_norm, tree_map_with_name

Pytree = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def make_schedule(tc: TrainConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    warm = max(tc.warmup_steps, 1)

    def inv_sqrt(step):
        s = jnp.maximum(step, 1).astype(jnp.float32)
        return tc.lr * jnp.minimum(s / warm, jnp.sqrt(warm / s))

    def cosine(step):
        s = step.astype(jnp.float32)
        warm_frac = jnp.minimum(s / warm, 1.0)
        prog = jnp.clip((s - warm) / jnp.maximum(tc.steps - warm, 1), 0.0, 1.0)
        return tc.lr * warm_frac * 0.5 * (1 + jnp.cos(jnp.pi * prog))

    def constant(step):
        s = step.astype(jnp.float32)
        return tc.lr * jnp.minimum(s / warm, 1.0)

    return {"inv_sqrt": inv_sqrt, "cosine": cosine, "constant": constant}[tc.schedule]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Pytree) -> Dict:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads: Pytree, state: Dict, params: Pytree, tc: TrainConfig,
                 *, schedule: Callable, mask: Optional[Pytree] = None):
    """Returns (new_params, new_state, metrics).  mask: 1.0=train, 0.0=frozen."""
    step = state["step"] + 1
    lr = schedule(step)

    gnorm = global_norm(grads)
    if tc.grad_clip > 0:
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2, eps, wd = tc.b1, tc.b2, tc.eps, tc.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p, m):
        g = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g
        nu_n = b2 * nu + (1 - b2) * jnp.square(g)
        upd = (mu_n / bc1) / (jnp.sqrt(nu_n / bc2) + eps)
        upd = upd + wd * p.astype(jnp.float32)
        if m is not None:
            # mask is a per-leaf learning-rate multiplier: 0.0 = frozen,
            # 1.0 = full lr, fractional = discriminative fine-tuning.
            p_n = p.astype(jnp.float32) - lr * m * upd
            mu_n = jnp.where(m > 0, mu_n, mu)
            nu_n = jnp.where(m > 0, nu_n, nu)
        else:
            p_n = p.astype(jnp.float32) - lr * upd
        return p_n.astype(p.dtype), mu_n, nu_n

    if mask is None:
        out = jax.tree_util.tree_map(
            lambda g, mu, nu, p: upd(g, mu, nu, p, None),
            grads, state["mu"], state["nu"], params)
    else:
        out = jax.tree_util.tree_map(
            upd, grads, state["mu"], state["nu"], params, mask)

    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory-lean option for big runs)
# ---------------------------------------------------------------------------


def adafactor_init(params: Pytree) -> Dict:
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, jnp.float32)}

    return {"v": jax.tree_util.tree_map(factored, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads: Pytree, state: Dict, params: Pytree,
                     tc: TrainConfig, *, schedule: Callable,
                     mask: Optional[Pytree] = None):
    step = state["step"] + 1
    lr = schedule(step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
    eps = 1e-30

    gnorm = global_norm(grads)
    if tc.grad_clip > 0:
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    def upd(g, v, p, m):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if "vr" in v:
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            v_n = {"vr": vr, "vc": vc}
        else:
            v_ = decay * v["v"] + (1 - decay) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v_, eps))
            v_n = {"v": v_}
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms)
        p_n = p.astype(jnp.float32) - lr * (u + tc.weight_decay * p.astype(jnp.float32))
        if m is not None:
            p_n = jnp.where(m > 0, p_n, p.astype(jnp.float32))
        return p_n.astype(p.dtype), v_n

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    flat_m = ([None] * len(flat_p) if mask is None
              else treedef.flatten_up_to(mask))
    out = [upd(g, v, p, m) for g, v, p, m in zip(flat_g, flat_v, flat_p, flat_m)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, {"v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


def optimizer_init(params: Pytree, tc: TrainConfig) -> Dict:
    return adamw_init(params) if tc.optimizer == "adamw" else adafactor_init(params)


def optimizer_update(grads, state, params, tc: TrainConfig,
                     mask: Optional[Pytree] = None):
    schedule = make_schedule(tc)
    if tc.optimizer == "adamw":
        return adamw_update(grads, state, params, tc, schedule=schedule, mask=mask)
    return adafactor_update(grads, state, params, tc, schedule=schedule, mask=mask)


def freeze_mask(params: Pytree, *, train_only_heads: bool) -> Optional[Pytree]:
    """§6.1: mask that trains only the BPD heads (1.0 = trainable)."""
    if not train_only_heads:
        return None
    return tree_map_with_name(
        lambda name, p: jnp.ones((), jnp.float32)
        if name.startswith("bpd_heads") else jnp.zeros((), jnp.float32),
        params)


def lr_scale_mask(params: Pytree, *, trunk_scale: float) -> Pytree:
    """Discriminative fine-tuning (§6.1 at small scale): heads at full lr,
    everything else at ``trunk_scale`` × lr.  At the paper's model scale the
    trunk absorbs the multi-head objective; at CPU-repro scale an unscaled
    joint update lets the future heads' gradients overwrite p_1's behaviour
    through the shared vocab projection (measured: teacher-forced p_1
    accuracy 0.99 -> 0.58 in 500 steps).  Scaling the trunk lr interpolates
    between the paper's frozen and fine-tuned settings."""
    return tree_map_with_name(
        lambda name, p: jnp.ones((), jnp.float32)
        if name.startswith("bpd_heads")
        else jnp.full((), trunk_scale, jnp.float32),
        params)
