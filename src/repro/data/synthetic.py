"""Synthetic tasks, chosen so the paper's acceptance-rate phenomenology is
reproducible on CPU in minutes:

* **Markov LM** — an order-2 Markov chain over a small vocab with a
  temperature knob: low-entropy chains are highly predictable, so trained
  BPD heads accept long blocks (the paper's "distilled data is more
  predictable" effect, in a dial we control).
* **Cipher MT** — the seq2seq analog of WMT: the target is the source under
  a fixed token substitution + reversal.  Deterministic given the source, so
  a converged model approaches k̂ → k, while an underfit one shows the
  paper's Table-1-style intermediate block sizes.
* **Ordinal sequences** — smooth integer-valued curves quantized into
  [0, 256) tokens: the "image" analog where distance-based acceptance
  (paper §5.2, Table 2) is meaningful.
* **Masked audio frames** — random frame embeddings + span masks + codebook
  targets for the hubert masked-prediction objective.

Everything is generated from numpy PRNGs with explicit seeds.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Markov LM
# ---------------------------------------------------------------------------


class MarkovLM:
    """Order-2 Markov chain over ``vocab`` symbols."""

    def __init__(self, vocab: int = 64, *, seed: int = 0, temperature: float = 0.3):
        rng = np.random.default_rng(seed)
        logits = rng.normal(size=(vocab, vocab, vocab)) / max(temperature, 1e-3)
        z = logits - logits.max(-1, keepdims=True)
        p = np.exp(z)
        self.trans = p / p.sum(-1, keepdims=True)
        self.vocab = vocab

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        toks = np.zeros((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        toks[:, 1] = rng.integers(0, self.vocab, batch)
        for t in range(2, seq_len):
            p = self.trans[toks[:, t - 2], toks[:, t - 1]]
            cum = np.cumsum(p, axis=-1)
            u = rng.random((batch, 1))
            toks[:, t] = (u < cum).argmax(-1)
        return toks

    def batches(self, *, batch: int, seq_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": self.sample(rng, batch, seq_len)}


# ---------------------------------------------------------------------------
# Cipher MT (seq2seq)
# ---------------------------------------------------------------------------


class CipherMT:
    """Target = reversed source mapped through a fixed permutation cipher."""

    def __init__(self, vocab: int = 64, *, seed: int = 0, reverse: bool = True):
        rng = np.random.default_rng(seed)
        # token 0 is reserved for BOS/PAD; permute 1..vocab-1
        perm = rng.permutation(np.arange(1, vocab))
        self.cipher = np.concatenate([[0], perm]).astype(np.int32)
        self.vocab = vocab
        self.reverse = reverse

    def make_pair(self, rng: np.random.Generator, batch: int, src_len: int):
        src = rng.integers(1, self.vocab, (batch, src_len)).astype(np.int32)
        tgt = self.cipher[src]
        if self.reverse:
            tgt = tgt[:, ::-1]
        return src, np.ascontiguousarray(tgt)

    def batches(self, *, batch: int, src_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            src, tgt = self.make_pair(rng, batch, src_len)
            yield {"src": src, "tgt": tgt}


class PhraseMT:
    """Seq2seq task with target-side subword structure: each source token
    expands deterministically into an ``expand``-token target phrase.

    This mirrors what makes the paper's MT heads work: real German targets
    are sequences of subwords where continuations within a word/phrase are
    locally predictable from the decoder's own context (the paper's §7.4
    trace accepts blocks like "Tele-sko-p_" in one step), while phrase
    boundaries require source information.  Pure cipher targets have zero
    target-side redundancy, so proposal heads have nothing learnable from a
    frozen decoder state; phrase targets restore the paper's regime.
    """

    def __init__(self, vocab: int = 64, *, expand: int = 2, seed: int = 0):
        rng = np.random.default_rng(seed)
        # token 0 reserved; each source token maps to `expand` target tokens
        self.table = rng.integers(1, vocab, (vocab, expand)).astype(np.int32)
        self.vocab = vocab
        self.expand = expand
        self.reverse = False

    def make_pair(self, rng: np.random.Generator, batch: int, src_len: int):
        src = rng.integers(1, self.vocab, (batch, src_len)).astype(np.int32)
        tgt = self.table[src].reshape(batch, src_len * self.expand)
        return src, np.ascontiguousarray(tgt)

    def gold(self, src: np.ndarray) -> np.ndarray:
        return self.table[src].reshape(src.shape[0], -1)

    def batches(self, *, batch: int, src_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            src, tgt = self.make_pair(rng, batch, src_len)
            yield {"src": src, "tgt": tgt}


# ---------------------------------------------------------------------------
# Ordinal ("super-resolution") sequences
# ---------------------------------------------------------------------------


class OrdinalCurves:
    """Token sequences quantizing smooth random curves into [0, levels)."""

    def __init__(self, levels: int = 256, *, n_waves: int = 3, seed: int = 0):
        self.levels = levels
        self.n_waves = n_waves

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        t = np.linspace(0, 1, seq_len)[None, :]
        y = np.zeros((batch, seq_len))
        for _ in range(self.n_waves):
            freq = rng.uniform(0.5, 4.0, (batch, 1))
            phase = rng.uniform(0, 2 * np.pi, (batch, 1))
            amp = rng.uniform(0.2, 1.0, (batch, 1))
            y += amp * np.sin(2 * np.pi * freq * t + phase)
        y = (y - y.min(1, keepdims=True))
        y = y / np.maximum(y.max(1, keepdims=True), 1e-9)
        return np.clip((y * (self.levels - 1)).round(), 0,
                       self.levels - 1).astype(np.int32)

    def batches(self, *, batch: int, seq_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": self.sample(rng, batch, seq_len)}


# ---------------------------------------------------------------------------
# Masked audio frames (hubert-style)
# ---------------------------------------------------------------------------


class MaskedFrames:
    """Frame embeddings whose codebook id is a deterministic function of the
    frame (so the masked-prediction task is learnable): embedding = codeword
    + small noise; target = codeword index."""

    def __init__(self, d_model: int, codebook: int = 504, *, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.codebook = rng.normal(size=(codebook, d_model)).astype(np.float32)
        self.nc = codebook
        self.d = d_model

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int,
               *, mask_prob: float = 0.08, span: int = 10):
        ids = rng.integers(0, self.nc, (batch, seq_len))
        emb = self.codebook[ids] + 0.1 * rng.normal(
            size=(batch, seq_len, self.d)).astype(np.float32)
        mask = np.zeros((batch, seq_len), bool)
        n_starts = max(1, int(mask_prob * seq_len))
        for b in range(batch):
            starts = rng.integers(0, max(seq_len - span, 1), n_starts)
            for s in starts:
                mask[b, s:s + span] = True
        return {"frame_embeds": emb.astype(np.float32),
                "mask": mask, "targets": ids.astype(np.int32)}

    def batches(self, *, batch: int, seq_len: int, seed: int = 0, **kw
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield self.sample(rng, batch, seq_len, **kw)
