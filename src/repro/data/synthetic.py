"""Synthetic tasks, chosen so the paper's acceptance-rate phenomenology is
reproducible on CPU in minutes:

* **Markov LM** — an order-2 Markov chain over a small vocab with a
  temperature knob: low-entropy chains are highly predictable, so trained
  BPD heads accept long blocks (the paper's "distilled data is more
  predictable" effect, in a dial we control).
* **Cipher MT** — the seq2seq analog of WMT: the target is the source under
  a fixed token substitution + reversal.  Deterministic given the source, so
  a converged model approaches k̂ → k, while an underfit one shows the
  paper's Table-1-style intermediate block sizes.
* **Ordinal sequences** — smooth integer-valued curves quantized into
  [0, 256) tokens: the "image" analog where distance-based acceptance
  (paper §5.2, Table 2) is meaningful.
* **Ordinal fields** — the 2-D raster variant (smooth images), serialized
  either row-major or in the locality-aware progressive-lattice order
  (``locality_plan``) consumed by the ``locality`` decode policy.
* **Masked audio frames** — random frame embeddings + span masks + codebook
  targets for the hubert masked-prediction objective.

Everything is generated from numpy PRNGs with explicit seeds.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Markov LM
# ---------------------------------------------------------------------------


class MarkovLM:
    """Order-2 Markov chain over ``vocab`` symbols."""

    def __init__(self, vocab: int = 64, *, seed: int = 0, temperature: float = 0.3):
        rng = np.random.default_rng(seed)
        logits = rng.normal(size=(vocab, vocab, vocab)) / max(temperature, 1e-3)
        z = logits - logits.max(-1, keepdims=True)
        p = np.exp(z)
        self.trans = p / p.sum(-1, keepdims=True)
        self.vocab = vocab

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        toks = np.zeros((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        toks[:, 1] = rng.integers(0, self.vocab, batch)
        for t in range(2, seq_len):
            p = self.trans[toks[:, t - 2], toks[:, t - 1]]
            cum = np.cumsum(p, axis=-1)
            u = rng.random((batch, 1))
            toks[:, t] = (u < cum).argmax(-1)
        return toks

    def batches(self, *, batch: int, seq_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": self.sample(rng, batch, seq_len)}


# ---------------------------------------------------------------------------
# Cipher MT (seq2seq)
# ---------------------------------------------------------------------------


class CipherMT:
    """Target = reversed source mapped through a fixed permutation cipher."""

    def __init__(self, vocab: int = 64, *, seed: int = 0, reverse: bool = True):
        rng = np.random.default_rng(seed)
        # token 0 is reserved for BOS/PAD; permute 1..vocab-1
        perm = rng.permutation(np.arange(1, vocab))
        self.cipher = np.concatenate([[0], perm]).astype(np.int32)
        self.vocab = vocab
        self.reverse = reverse

    def make_pair(self, rng: np.random.Generator, batch: int, src_len: int):
        src = rng.integers(1, self.vocab, (batch, src_len)).astype(np.int32)
        tgt = self.cipher[src]
        if self.reverse:
            tgt = tgt[:, ::-1]
        return src, np.ascontiguousarray(tgt)

    def batches(self, *, batch: int, src_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            src, tgt = self.make_pair(rng, batch, src_len)
            yield {"src": src, "tgt": tgt}


class PhraseMT:
    """Seq2seq task with target-side subword structure: each source token
    expands deterministically into an ``expand``-token target phrase.

    This mirrors what makes the paper's MT heads work: real German targets
    are sequences of subwords where continuations within a word/phrase are
    locally predictable from the decoder's own context (the paper's §7.4
    trace accepts blocks like "Tele-sko-p_" in one step), while phrase
    boundaries require source information.  Pure cipher targets have zero
    target-side redundancy, so proposal heads have nothing learnable from a
    frozen decoder state; phrase targets restore the paper's regime.
    """

    def __init__(self, vocab: int = 64, *, expand: int = 2, seed: int = 0):
        rng = np.random.default_rng(seed)
        # token 0 reserved; each source token maps to `expand` target tokens
        self.table = rng.integers(1, vocab, (vocab, expand)).astype(np.int32)
        self.vocab = vocab
        self.expand = expand
        self.reverse = False

    def make_pair(self, rng: np.random.Generator, batch: int, src_len: int):
        src = rng.integers(1, self.vocab, (batch, src_len)).astype(np.int32)
        tgt = self.table[src].reshape(batch, src_len * self.expand)
        return src, np.ascontiguousarray(tgt)

    def gold(self, src: np.ndarray) -> np.ndarray:
        return self.table[src].reshape(src.shape[0], -1)

    def batches(self, *, batch: int, src_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            src, tgt = self.make_pair(rng, batch, src_len)
            yield {"src": src, "tgt": tgt}


# ---------------------------------------------------------------------------
# Ordinal ("super-resolution") sequences
# ---------------------------------------------------------------------------


class OrdinalCurves:
    """Token sequences quantizing smooth random curves into [0, levels)."""

    def __init__(self, levels: int = 256, *, n_waves: int = 3, seed: int = 0):
        self.levels = levels
        self.n_waves = n_waves

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        t = np.linspace(0, 1, seq_len)[None, :]
        y = np.zeros((batch, seq_len))
        for _ in range(self.n_waves):
            freq = rng.uniform(0.5, 4.0, (batch, 1))
            phase = rng.uniform(0, 2 * np.pi, (batch, 1))
            amp = rng.uniform(0.2, 1.0, (batch, 1))
            y += amp * np.sin(2 * np.pi * freq * t + phase)
        y = (y - y.min(1, keepdims=True))
        y = y / np.maximum(y.max(1, keepdims=True), 1e-9)
        return np.clip((y * (self.levels - 1)).round(), 0,
                       self.levels - 1).astype(np.int32)

    def batches(self, *, batch: int, seq_len: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": self.sample(rng, batch, seq_len)}


# ---------------------------------------------------------------------------
# 2-D ordinal fields + the locality-aware generation order
# ---------------------------------------------------------------------------


def _locality_parents(y, x, off_y, off_x, half, height, width):
    """Committed-lattice neighbor pair for a refinement-class position."""
    if off_y and off_x:            # (half, half): diagonal lattice parents
        cands = [(y - half, x - half), (y - half, x + half),
                 (y + half, x - half), (y + half, x + half)]
    elif off_y:                    # (half, 0): vertical lattice parents
        cands = [(y - half, x), (y + half, x)]
    else:                          # (0, half): horizontal lattice parents
        cands = [(y, x - half), (y, x + half)]
    ok = [(a, b) for a, b in cands if 0 <= a < height and 0 <= b < width]
    if not ok:
        ok = [(y, x)]
    if len(ok) == 1:
        ok = ok * 2
    return ok[0], ok[1]


def locality_plan(height: int, width: int, stride: int):
    """Progressive-lattice generation order for an (height, width) raster
    (arXiv:2507.01957-style locality-aware ordering) plus the drafting
    tables the ``locality`` decode policy consumes.

    Phase 0 emits the coarse lattice (y % stride == 0 and x % stride == 0)
    in raster order; each refinement level ``cur = stride, stride/2, …, 2``
    then emits three offset classes — (half, half), (half, 0), (0, half)
    with ``half = cur // 2`` — each in raster order.  Within a class,
    consecutive positions are >= cur >= 2 apart in both axes, so every
    parallel block cut inside one class is spatially NON-adjacent, and
    every class member has already-committed lattice neighbors to
    interpolate from.

    Returns ``(order, boundaries, n1, n2)``:
      * ``order``      (H*W,) int32 — raster index of each generation slot;
      * ``boundaries`` int32 — class-end offsets into the generation order
        (the block-schedule cut points; ``boundaries[0]`` is the coarse
        prefix length);
      * ``n1, n2``     (H*W,) int32 — GENERATION indices of the two
        committed spatial neighbors each position interpolates between
        (strictly earlier phases for every refinement position; coarse
        positions extrapolate from their up/left lattice neighbors).
    """
    if stride < 1 or (stride & (stride - 1)):
        raise ValueError(
            f"locality stride must be a power of two >= 1, got {stride}")
    gen_of = np.full((height, width), -1, np.int64)
    order, boundaries, n1, n2 = [], [], [], []

    def emit(step, off_y, off_x, half):
        for y in range(off_y, height, step):
            for x in range(off_x, width, step):
                if gen_of[y, x] >= 0:
                    continue
                g = len(order)
                gen_of[y, x] = g
                order.append(y * width + x)
                if half == 0:      # coarse lattice: extrapolate up/left
                    up = gen_of[y - step, x] if y >= step else g
                    left = gen_of[y, x - step] if x >= step else g
                    a = up if up != g else left
                    b = left if left != g else a
                    n1.append(max(int(a) if a != g else g - 1, 0))
                    n2.append(max(int(b) if b != g else g - 1, 0))
                else:
                    (ay, ax), (by, bx) = _locality_parents(
                        y, x, off_y, off_x, half, height, width)
                    n1.append(max(int(gen_of[ay, ax]), 0))
                    n2.append(max(int(gen_of[by, bx]), 0))
        boundaries.append(len(order))

    emit(stride, 0, 0, 0)                       # coarse lattice, raster
    cur = stride
    while cur > 1:
        half = cur // 2
        for off_y, off_x in ((half, half), (half, 0), (0, half)):
            emit(cur, off_y, off_x, half)
        cur = half
    return (np.asarray(order, np.int32), np.asarray(boundaries, np.int32),
            np.asarray(n1, np.int32), np.asarray(n2, np.int32))


def locality_order(height: int, width: int, stride: int):
    """(order, boundaries) of ``locality_plan`` — the serialization used by
    ``OrdinalField(order="locality")`` and the ``locality`` decode policy."""
    order, boundaries, _, _ = locality_plan(height, width, stride)
    return order, boundaries


class OrdinalField:
    """2-D smooth integer fields — the raster-image analog of
    ``OrdinalCurves``: sums of low-frequency 2-D sinusoids quantized to
    [0, levels).  ``order`` picks the serialization of the (H, W) grid
    into a token stream: ``"raster"`` (row-major autoregression) or
    ``"locality"`` (progressive-lattice refinement, ``locality_plan``) —
    the training stream for the ``locality`` decode policy, where every
    position is predictable by *interpolating* committed neighbors instead
    of extrapolating the raster scan.

    ``bilinear=True`` samples the waves on the coarse stride lattice only
    and bilinearly upsamples to the full grid before quantizing — the
    fields become piecewise-bilinear, so every refinement position IS the
    (continuous) midpoint of its lattice parents up to quantization.
    This is the locally-smooth regime locality-aware decoding targets
    (natural images behave this way at fine scales); free-running waves
    keep full high-frequency detail and make interpolation approximate.
    """

    def __init__(self, levels: int = 32, height: int = 16, width: int = 16,
                 *, n_waves: int = 3, stride: int = 4, order: str = "raster",
                 bilinear: bool = False, seed: int = 0):
        if order not in ("raster", "locality"):
            raise ValueError(
                f"OrdinalField order must be 'raster' or 'locality', "
                f"got {order!r}")
        self.levels, self.height, self.width = levels, height, width
        self.n_waves, self.stride, self.order_name = n_waves, stride, order
        self.bilinear = bilinear
        ord_idx, bounds, _, _ = locality_plan(height, width, stride)
        self.gen_index = ord_idx                # generation slot -> raster
        self.boundaries = bounds
        self.coarse_len = int(bounds[0])
        inv = np.empty(ord_idx.size, np.int64)
        inv[ord_idx] = np.arange(ord_idx.size)
        self.raster_index = inv                 # raster -> generation slot

    def _waves(self, rng: np.random.Generator, batch: int,
               ys: np.ndarray, xs: np.ndarray) -> np.ndarray:
        yy = (ys / max(self.height - 1, 1))[None, :, None]
        xx = (xs / max(self.width - 1, 1))[None, None, :]
        z = np.zeros((batch, ys.size, xs.size))
        # bilinear mode keeps meaningful variation BETWEEN lattice knots
        # (the waves are only sampled there): at the default stride the
        # band below spans roughly one knot-to-knot period, so the raster
        # twin cannot trivially extrapolate the scan while refinement
        # positions remain exact midpoints of their parents
        lo, hi = (0.35, 1.05) if self.bilinear else (0.3, 1.2)
        for _ in range(self.n_waves):
            fy = rng.uniform(lo, hi, (batch, 1, 1))
            fx = rng.uniform(lo, hi, (batch, 1, 1))
            phase = rng.uniform(0, 2 * np.pi, (batch, 1, 1))
            amp = rng.uniform(0.3, 1.0, (batch, 1, 1))
            z += amp * np.sin(2 * np.pi * (fy * yy + fx * xx) + phase)
        return z

    def sample_grid(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        if self.bilinear:
            # waves on the stride lattice -> bilinear upsample (edge clamp
            # past the last knot) -> quantize: piecewise-bilinear fields
            s = self.stride
            ly = np.arange(0, self.height, s)
            lx = np.arange(0, self.width, s)
            z = self._waves(rng, batch, ly, lx)
            fy = np.minimum(np.arange(self.height) / s, ly.size - 1)
            fx = np.minimum(np.arange(self.width) / s, lx.size - 1)
            y0 = np.floor(fy).astype(int)
            y1 = np.minimum(y0 + 1, ly.size - 1)
            x0 = np.floor(fx).astype(int)
            x1 = np.minimum(x0 + 1, lx.size - 1)
            wy = (fy - y0)[None, :, None]
            wx = (fx - x0)[None, None, :]
            z = ((1 - wy) * (1 - wx) * z[:, y0][:, :, x0]
                 + (1 - wy) * wx * z[:, y0][:, :, x1]
                 + wy * (1 - wx) * z[:, y1][:, :, x0]
                 + wy * wx * z[:, y1][:, :, x1])
        else:
            z = self._waves(rng, batch, np.arange(self.height),
                            np.arange(self.width))
        z = z - z.min((1, 2), keepdims=True)
        z = z / np.maximum(z.max((1, 2), keepdims=True), 1e-9)
        return np.clip((z * (self.levels - 1)).round(), 0,
                       self.levels - 1).astype(np.int32)

    def serialize(self, grid: np.ndarray) -> np.ndarray:
        flat = grid.reshape(grid.shape[0], -1)
        if self.order_name == "locality":
            return np.ascontiguousarray(flat[:, self.gen_index])
        return flat

    def to_grid(self, tokens: np.ndarray) -> np.ndarray:
        """Invert ``serialize``: token stream(s) back to (B, H, W)."""
        toks = np.asarray(tokens)[:, :self.height * self.width]
        if self.order_name == "locality":
            toks = toks[:, self.raster_index]
        return toks.reshape(-1, self.height, self.width)

    def sample(self, rng: np.random.Generator, batch: int,
               seq_len: Optional[int] = None) -> np.ndarray:
        toks = self.serialize(self.sample_grid(rng, batch))
        return toks if seq_len is None else toks[:, :seq_len]

    def batches(self, *, batch: int, seq_len: Optional[int] = None,
                seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": self.sample(rng, batch, seq_len)}


# ---------------------------------------------------------------------------
# Masked audio frames (hubert-style)
# ---------------------------------------------------------------------------


class MaskedFrames:
    """Frame embeddings whose codebook id is a deterministic function of the
    frame (so the masked-prediction task is learnable): embedding = codeword
    + small noise; target = codeword index."""

    def __init__(self, d_model: int, codebook: int = 504, *, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.codebook = rng.normal(size=(codebook, d_model)).astype(np.float32)
        self.nc = codebook
        self.d = d_model

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int,
               *, mask_prob: float = 0.08, span: int = 10):
        ids = rng.integers(0, self.nc, (batch, seq_len))
        emb = self.codebook[ids] + 0.1 * rng.normal(
            size=(batch, seq_len, self.d)).astype(np.float32)
        mask = np.zeros((batch, seq_len), bool)
        n_starts = max(1, int(mask_prob * seq_len))
        for b in range(batch):
            starts = rng.integers(0, max(seq_len - span, 1), n_starts)
            for s in starts:
                mask[b, s:s + span] = True
        return {"frame_embeds": emb.astype(np.float32),
                "mask": mask, "targets": ids.astype(np.int32)}

    def batches(self, *, batch: int, seq_len: int, seed: int = 0, **kw
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            yield self.sample(rng, batch, seq_len, **kw)
