from repro.data.pipeline import prefetch, stub_frontend_inputs, take, to_device
from repro.data.synthetic import CipherMT, MarkovLM, MaskedFrames, OrdinalCurves

__all__ = [
    "CipherMT",
    "MarkovLM",
    "MaskedFrames",
    "OrdinalCurves",
    "prefetch",
    "stub_frontend_inputs",
    "take",
    "to_device",
]
