"""Batching / sharding pipeline.

Host-side numpy batches -> device arrays, with optional sharding onto a mesh
(batch dim over the data axis).  Includes a deterministic prefetching
iterator and helpers to build the per-modality stub inputs (the VLM patch /
audio frame embeddings mandated as stubs by the brief).
"""
from __future__ import annotations

import collections
import itertools
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def to_device(batch: Dict[str, np.ndarray], sharding=None) -> Dict:
    def put(x):
        if sharding is not None:
            return jax.device_put(x, sharding)
        return jnp.asarray(x)

    return {k: put(v) for k, v in batch.items()}


def prefetch(it: Iterator[Dict], depth: int = 2, sharding=None) -> Iterator[Dict]:
    """Simple synchronous-transfer prefetch queue (CPU container: the value
    is overlap of host batch synthesis with device compute)."""
    queue: collections.deque = collections.deque()
    for batch in it:
        queue.append(to_device(batch, sharding))
        if len(queue) >= depth:
            yield queue.popleft()
    while queue:
        yield queue.popleft()


def stub_frontend_inputs(cfg: ModelConfig, rng: np.random.Generator,
                         batch: int, text_len: int) -> Dict[str, np.ndarray]:
    """Per the brief, modality frontends are stubs: precomputed patch/frame
    embeddings of the right shape."""
    out: Dict[str, np.ndarray] = {}
    if cfg.modality == "vision_text" and cfg.num_patch_tokens:
        out["patch_embeds"] = rng.normal(
            size=(batch, cfg.num_patch_tokens, cfg.d_model)).astype(np.float32)
    out["tokens"] = rng.integers(0, cfg.vocab_size,
                                 (batch, text_len)).astype(np.int32)
    return out


def take(it: Iterator, n: int):
    return list(itertools.islice(it, n))
