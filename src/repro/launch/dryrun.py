"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture × input shape × mesh) combination this lowers and
compiles the corresponding step function on the production mesh
(16×16 = 256 chips single-pod; 2×16×16 = 512 chips multi-pod), prints
``memory_analysis()`` / ``cost_analysis()``, extracts the collective traffic
from the compiled HLO, and writes one JSON record per combination for the
roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first backend init):
import os
# 512 placeholder devices for the production mesh; expensive LLVM codegen
# passes disabled (pure CPU-backend compile-time saving — verified to leave
# cost_analysis flops/bytes and the HLO collectives unchanged).
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_llvm_disable_expensive_passes=true")

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import (  # noqa: E402
    INPUT_SHAPES,
    DecodeConfig,
    ModelConfig,
    TrainConfig,
    get_config,
)
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.models import model as model_lib  # noqa: E402
from repro.optim import optimizer_init  # noqa: E402
from repro.sharding import (  # noqa: E402
    batch_specs,
    named,
    param_specs,
    state_specs,
)
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the compiled HLO.

    Handles scalar results (``%x = bf16[8,128] all-gather(...)``), tuple
    results (``%x = (f32[16,16], f32[16,16]) all-to-all(...)``) and async
    ``-start`` forms (whose ``-done`` twin carries no new traffic)."""
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    count: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    op_re = re.compile(
        r"=\s+.*?\b(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = op_re.search(stripped)
        if not m:
            continue
        known = m.group(1)
        total = 0
        for dt, dims in _SHAPE_RE.findall(stripped[: m.start(1)]):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[known] += total
        count[known] += 1
    out_nonzero = {k: v for k, v in out.items() if v}
    return {"bytes_by_op": out_nonzero,
            "counts": {k: v for k, v in count.items() if v},
            "total_bytes": sum(out.values())}


def active_params(cfg: ModelConfig, n_total: int) -> int:
    """Active parameter count for MODEL_FLOPS (MoE: routed experts scaled by
    top-k/E)."""
    if cfg.mlp_type != "moe":
        return n_total
    ff = cfg.d_ff
    gated = 3 if cfg.activation in ("silu", "geglu") else 2
    expert_params = cfg.num_layers * cfg.num_experts * gated * cfg.d_model * ff
    active_expert = expert_params * cfg.num_experts_per_tok / cfg.num_experts
    return int(n_total - expert_params + active_expert)


def model_flops(cfg: ModelConfig, n_active: int, tokens: int) -> float:
    return 6.0 * n_active * tokens


# ---------------------------------------------------------------------------


def build_lowering(cfg: ModelConfig, shape_name: str, mesh, *,
                   serve_bf16: bool = False, remat: bool = False):
    """Construct (jitted_fn, arg_structs, arg_shardings) for one combo.

    serve_bf16 casts the stored parameters to bf16 for the inference kinds
    (standard serving practice — halves weight residency and read traffic;
    measured as a §Perf iteration, baseline keeps the training dtype)."""
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    if serve_bf16 and kind != "train":
        cfg = cfg.replace(param_dtype="bfloat16")
    if remat and kind == "train":
        cfg = cfg.replace(remat=True)
    b, s = spec["global_batch"], spec["seq_len"]
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(lambda: model_lib.init(key, cfg))
    p_specs = param_specs(params_struct, mesh)
    p_shard = named(mesh, p_specs)

    # long-context PREFILL uses the chunked (flash-style) attention so the
    # (Sq, Sk) score tensor never materializes.  Decode keeps the plain
    # einsum: with q = block_k tiny the score tensor is (B, H, k, L) — small —
    # and chunk-reshaping a length-sharded KV cache would force GSPMD to
    # replicate it (measured: 87 GB of involuntary all-gather per step).
    kv_chunk = 2048 if (s > 8192 and kind != "decode") else 0

    batch = steps_lib.input_specs(cfg, shape_name)
    b_specs = batch_specs(mesh, batch)
    b_shard = named(mesh, b_specs)

    if kind == "train":
        tc = TrainConfig(global_batch=b, seq_len=s)
        opt_struct = jax.eval_shape(lambda p: optimizer_init(p, tc), params_struct)
        # optimizer state mirrors param sharding (mu/nu/v); scalars replicated
        o_shard = {
            k: (named(mesh, param_specs(v, mesh))
                if k in ("mu", "nu", "v") else NamedSharding(mesh, P()))
            for k, v in opt_struct.items()
        }
        key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
        fn = steps_lib.make_train_step(cfg, tc)
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, o_shard, b_shard, NamedSharding(mesh, P())),
            out_shardings=(p_shard, o_shard, None),
        )
        args = (params_struct, opt_struct, batch, key_struct)
        return jitted, args

    dec = DecodeConfig(max_new_tokens=64, block_k=cfg.bpd_k if cfg.bpd_enabled else 1)

    if kind == "prefill":
        fn = steps_lib.make_prefill_step(cfg, dec, kv_chunk=kv_chunk)
        if cfg.is_encoder_only:
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            return jitted, (params_struct, batch)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        return jitted, (params_struct, batch)

    # decode: one BPD iteration (serve_step) — loop-state specs come from the
    # same sharding.policy.state_specs builder the DecodeSession uses
    state_struct = steps_lib.serve_state_struct(cfg, dec, batch=b, seq_len=s,
                                                max_new=64)
    st_specs = state_specs(cfg, state_struct, mesh, batch_size=b)
    st_shard = named(mesh, st_specs)
    fn = steps_lib.make_serve_step(cfg, dec, seq_len=s, max_new=64,
                                   kv_chunk=kv_chunk)
    jitted = jax.jit(fn, in_shardings=(p_shard, st_shard),
                     out_shardings=st_shard)
    return jitted, (params_struct, state_struct)


# ---------------------------------------------------------------------------


def run_combo(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
              *, verbose: bool = True, serve_bf16: bool = False,
              remat: bool = False) -> Optional[Dict]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}_{shape_name}_{mesh_name}"
    if serve_bf16:
        tag += "_bf16serve"
    if remat:
        tag += "_remat"
    cfg = steps_lib.adapt_config(get_config(arch), shape_name)
    if cfg is None:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "encoder-only: no autoregressive decode"}
        _write(out_dir, tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: SKIPPED (encoder-only decode)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    spec = INPUT_SHAPES[shape_name]
    t0 = time.time()
    with mesh:
        jitted, args = build_lowering(cfg, shape_name, mesh,
                                      serve_bf16=serve_bf16, remat=remat)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model_lib.init(jax.random.PRNGKey(0), cfg))))
    n_active = active_params(cfg, n_params)
    # convention: fwd = 2*N*D, fwd+bwd (train) = 6*N*D
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        mult = 6.0
    elif spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        mult = 2.0
    else:
        tokens = spec["global_batch"] * (cfg.bpd_k if cfg.bpd_enabled else 1)
        mult = 2.0
    m_flops = mult * n_active * tokens

    # cost_analysis() reports the PER-DEVICE SPMD module (verified: a 4-way
    # sharded matmul reports 1/4 of the full flops), so the roofline terms
    # divide by single-chip peak numbers, not by the mesh size.
    hlo_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    hlo_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    compute_s = hlo_flops / PEAK_FLOPS_BF16
    memory_s = hlo_bytes / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    def _mem_attr(name):
        try:
            return int(getattr(mem, name))
        except Exception:
            return None

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "chips": n_chips,
        "kind": spec["kind"],
        "sliding_window": cfg.sliding_window,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "n_params": n_params, "n_active_params": n_active,
        "hlo_flops": hlo_flops, "hlo_bytes": hlo_bytes,
        "model_flops": m_flops,
        "flops_convention": "2nd-fwd-6nd-train",
        "useful_flops_ratio": (m_flops / (hlo_flops * n_chips))
        if hlo_flops else None,
        "collectives": coll,
        "roofline": dict(terms, bottleneck=bottleneck),
        "memory_analysis": {
            "argument_size_bytes": _mem_attr("argument_size_in_bytes"),
            "output_size_bytes": _mem_attr("output_size_in_bytes"),
            "temp_size_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_size_bytes": _mem_attr("generated_code_size_in_bytes"),
        },
    }
    _write(out_dir, tag, rec)
    if verbose:
        print(f"[dryrun] {tag}: OK chips={n_chips} "
              f"flops={hlo_flops:.3e} bytes={hlo_bytes:.3e} "
              f"coll={coll['total_bytes']:.3e}B "
              f"roofline={bottleneck} "
              f"(C={compute_s*1e3:.2f}ms M={memory_s*1e3:.2f}ms "
              f"X={collective_s*1e3:.2f}ms) "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: {rec['memory_analysis']}")
    return rec


def run_handoff(arch: str, out_dir: str, *, verbose: bool = True) -> Dict:
    """Lower the disaggregated prefill→decode KV handoff on the multi-pod
    ``("pod","data","model")`` mesh and measure it.

    Two numbers the serving design stands on:

      * the **handoff transfer** — ``attach`` moves one prefill-packet row
        (pod-axis sharded, ``sharding.policy.packet_specs``) into the
        pod×data-sharded slot slab; the sharding-constrained lowering's
        collective bytes ARE that device-to-device transfer;
      * the **donate_argnums HBM claim** — the slot state is donated, so
        attach/step must alias their output state onto the input buffers
        instead of double-buffering the KV slab.  Verified from the
        compiled ``input_output_alias`` table with a before/after buffer
        accounting row (donated vs. no-donation lowering of the SAME
        attach).
    """
    from repro.serving.session import DecodeSession
    from repro.serving.types import EngineConfig

    tag = f"{arch}_handoff_pod2x16x16"
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    dec = DecodeConfig(max_new_tokens=32, block_k=cfg.bpd_k or 4)
    mesh = make_production_mesh(multi_pod=True)
    pod, data = mesh.shape["pod"], mesh.shape["data"]
    # slot slab shards pod×data; prefill width shards the pod axis alone
    ecfg = EngineConfig(num_slots=pod * data, max_prompt_len=32,
                        max_new_cap=32, prefill_slots=2 * pod)
    params = model_lib.init(jax.random.PRNGKey(0), cfg)

    def lower_pair(donate: bool):
        with mesh:
            sess = DecodeSession(params, cfg, dec, mesh=mesh, donate=donate)
            fns = sess.serving_fns(ecfg)
            state = jax.eval_shape(fns.init, jnp.zeros((), jnp.int32))
            w = ecfg.prefill_slots
            prompts = jax.ShapeDtypeStruct((w, ecfg.max_prompt_len), jnp.int32)
            plens = jax.ShapeDtypeStruct((w,), jnp.int32)
            pkt = jax.eval_shape(fns.prefill, sess.params, sess.aux_params,
                                 prompts, plens, prompts)
            scalar = jax.ShapeDtypeStruct((), jnp.int32)
            jit_of = lambda f: getattr(f, "_jitted", f)  # noqa: E731
            pre = jit_of(fns.prefill).lower(
                sess.params, sess.aux_params, prompts, plens,
                prompts).compile()
            att = jit_of(fns.attach).lower(
                state, pkt, scalar, scalar, scalar).compile()
        return pre, att, state

    t0 = time.time()
    pre, att, state = lower_pair(donate=True)
    _, att_nodon, _ = lower_pair(donate=False)
    t_compile = time.time() - t0

    att_hlo = att.as_text()
    # the compiled alias table is the proof of donation: every aliased
    # (output, input-param) pair reuses the input buffer in place.  Each
    # table entry ends in "must-alias)" / "may-alias)".
    alias_pairs = (len(re.findall(r"(?:must|may)-alias\)", att_hlo))
                   if "input_output_alias" in att_hlo else 0)
    state_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(state))

    def _sizes(compiled):
        m = compiled.memory_analysis()
        get = lambda n: int(getattr(m, n, 0) or 0)  # noqa: E731
        return {"argument_size_bytes": get("argument_size_in_bytes"),
                "output_size_bytes": get("output_size_in_bytes"),
                "temp_size_bytes": get("temp_size_in_bytes"),
                "alias_size_bytes": get("alias_size_in_bytes")}

    don, nodon = _sizes(att), _sizes(att_nodon)
    # peak live bytes for one attach = args + outputs + temps − aliased
    # (aliased outputs reuse argument buffers); the donation saving is the
    # drop in that total between the two lowerings of the SAME function
    peak = lambda s: (s["argument_size_bytes"] + s["output_size_bytes"]  # noqa: E731
                      + s["temp_size_bytes"] - s["alias_size_bytes"])
    rec = {
        "arch": arch, "mesh": "pod2x16x16", "status": "ok",
        "kind": "handoff",
        "chips": int(np.prod(mesh.devices.shape)),
        "prefill_slots": ecfg.prefill_slots, "num_slots": ecfg.num_slots,
        "compile_s": round(t_compile, 2),
        "prefill_collectives": collective_bytes(pre.as_text()),
        "handoff_collectives": collective_bytes(att_hlo),
        "donate": {
            "state_bytes_global": state_bytes,
            "alias_pairs_in_hlo": alias_pairs,
            "with_donation": don,
            "without_donation": nodon,
            "peak_live_bytes_with": peak(don),
            "peak_live_bytes_without": peak(nodon),
            "hbm_saving_bytes": peak(nodon) - peak(don),
        },
    }
    _write(out_dir, tag, rec)
    if verbose:
        d = rec["donate"]
        print(f"[dryrun] {tag}: OK handoff_coll="
              f"{rec['handoff_collectives']['total_bytes']:.3e}B "
              f"alias_pairs={d['alias_pairs_in_hlo']} "
              f"state={d['state_bytes_global']:.3e}B "
              f"peak live {d['peak_live_bytes_without']:.3e}B -> "
              f"{d['peak_live_bytes_with']:.3e}B "
              f"(saves {d['hbm_saving_bytes']:.3e}B)")
    return rec


def _write(out_dir: str, tag: str, rec: Dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="lower inference kinds with bf16 params (§Perf #2)")
    ap.add_argument("--remat", action="store_true",
                    help="per-block activation checkpointing for train (§Perf #4)")
    ap.add_argument("--handoff", action="store_true",
                    help="lower the disaggregated prefill→decode KV handoff "
                         "(attach) on the multi-pod mesh: measures the "
                         "device-to-device transfer bytes and verifies the "
                         "donate_argnums HBM claim (smoke config)")
    args = ap.parse_args()

    if args.handoff:
        run_handoff(args.arch or "granite-3-8b", args.out)
        return

    from repro.configs import ASSIGNED

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                tag = f"{arch}_{shape_name}_{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] {tag}: cached")
                            continue
                try:
                    run_combo(arch, shape_name, mp, args.out,
                              serve_bf16=args.serve_bf16, remat=args.remat)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append(tag)
                    _write(args.out, tag,
                           {"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "status": "error",
                            "error": f"{type(e).__name__}: {e}"})
    if failures:
        print(f"FAILURES ({len(failures)}): {failures}")
        raise SystemExit(1)
    print("dry-run complete: all combinations lowered and compiled")


if __name__ == "__main__":
    main()
